#!/usr/bin/env python3
"""Check that markdown cross-references in README.md and docs/ resolve.

Validates every inline link `[text](target)` whose target is a relative
path (external http(s) links and pure anchors are skipped; anchors on
relative paths are checked against the target file's headings). Exits
non-zero listing each broken link. Run from the repo root:

    python scripts/check_doc_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REPO = Path(__file__).resolve().parent.parent


def heading_anchors(md: Path) -> set:
    anchors = set()
    for line in md.read_text().splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            slug = re.sub(r"[^\w\- ]", "", m.group(1).lower())
            anchors.add(slug.strip().replace(" ", "-"))
    return anchors


def check_file(md: Path) -> list:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in heading_anchors(md):
                errors.append(f"{md}: broken anchor {target}")
            continue
        path_part, _, anchor = target.partition("#")
        resolved = (md.parent / path_part).resolve()
        if not resolved.exists():
            errors.append(f"{md}: broken link {target}")
        elif anchor and resolved.suffix == ".md" \
                and anchor not in heading_anchors(resolved):
            errors.append(f"{md}: broken anchor {target}")
    return errors


def main() -> int:
    files = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
    errors = []
    for md in files:
        if md.exists():
            errors.extend(check_file(md))
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} broken link(s)")
        return 1
    print(f"checked {len(files)} files: all links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
