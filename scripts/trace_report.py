#!/usr/bin/env python3
"""Render a latency breakdown table from a JSONL trace file.

Reads the per-request traces written by `--trace-out` (repro.obs,
docs/observability.md) and attributes each trace's wall time to three
buckets:

  queue      hop-0 "queue" spans — time waiting for a flush slot
  compute    hop-0 "serve" spans — time inside the engine flush
  escalation all hop>0 spans — re-queue + re-serve time spent on
             guardrail escalations and failover requeues

Per-trace the three buckets tile the end-to-end duration exactly (the
span model closes each segment where the next begins), so the table's
rows sum to the latency column. Usage:

    PYTHONPATH=src python scripts/trace_report.py traces.jsonl
    PYTHONPATH=src python scripts/trace_report.py traces.jsonl --kind chunk
    PYTHONPATH=src python scripts/trace_report.py traces.jsonl \\
        --chrome-trace timeline.json    # open in ui.perfetto.dev

`--chrome-trace` re-exports the span trees as a Chrome-trace JSON
timeline (repro.obs.timeline, docs/observability.md) and validates the
result: schema per event phase, plus the span-tiling invariant — each
request's child spans must still sum to its end-to-end duration.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load(path: str):
    traces = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                traces.append(json.loads(line))
    return traces


def breakdown(trace: dict) -> dict:
    """Attribute one trace's spans to queue/compute/escalation ms."""
    out = {"queue_ms": 0.0, "compute_ms": 0.0, "escalation_ms": 0.0}
    for span in trace.get("spans", ()):
        if span.get("parent_id") is None:     # root span == e2e latency
            continue
        dur = (span["t1"] - span["t0"]) * 1e3
        hop = span.get("attrs", {}).get("hop", 0)
        if hop > 0:
            out["escalation_ms"] += dur
        elif span["name"] == "queue":
            out["queue_ms"] += dur
        else:
            out["compute_ms"] += dur
    out["total_ms"] = trace.get("duration_s", 0.0) * 1e3
    out["hops"] = trace.get("hops", 0)
    out["status"] = trace.get("status", "")
    return out


def percentile(values, q):
    if not values:
        return 0.0
    xs = sorted(values)
    idx = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
    return xs[idx]


def render(rows, out=sys.stdout):
    cols = ("segment", "p50 ms", "p95 ms", "p99 ms", "mean ms", "share")
    widths = [max(len(c), 12) for c in cols]
    widths[0] = max(widths[0], *(len(r["segment"]) for r in rows))
    line = "  ".join(f"{{:<{w}}}" if i == 0 else f"{{:>{w}}}"
                     for i, w in enumerate(widths))
    print(line.format(*cols), file=out)
    print(line.format(*("-" * w for w in widths)), file=out)
    for r in rows:
        print(line.format(r["segment"], f"{r['p50']:.2f}",
                          f"{r['p95']:.2f}", f"{r['p99']:.2f}",
                          f"{r['mean']:.2f}", f"{r['share']:.1%}"),
              file=out)


def report(traces, kind=None, out=sys.stdout):
    if kind:
        traces = [t for t in traces if t.get("kind") == kind]
    if not traces:
        print("no traces" + (f" of kind {kind!r}" if kind else ""),
              file=out)
        return 1
    bds = [breakdown(t) for t in traces]
    total = sum(b["total_ms"] for b in bds) or 1.0
    rows = []
    for seg, key in (("queue wait", "queue_ms"),
                     ("compute", "compute_ms"),
                     ("escalation/requeue", "escalation_ms"),
                     ("end-to-end", "total_ms")):
        vals = [b[key] for b in bds]
        rows.append({
            "segment": seg,
            "p50": percentile(vals, 0.50),
            "p95": percentile(vals, 0.95),
            "p99": percentile(vals, 0.99),
            "mean": sum(vals) / len(vals),
            "share": sum(vals) / total,
        })
    n_err = sum(1 for b in bds if b["status"] == "error")
    n_hopped = sum(1 for b in bds if b["hops"] > 0)
    print(f"{len(bds)} trace(s)"
          + (f", kind={kind}" if kind else "")
          + f": {n_hopped} escalated/requeued, {n_err} error(s)",
          file=out)
    render(rows, out=out)
    return 0


def export_chrome(traces, out_path: str) -> int:
    """Write the traces as a validated Chrome-trace/Perfetto JSON."""
    from repro.obs.timeline import validate_chrome_trace, write_chrome_trace
    doc = write_chrome_trace(out_path, traces)
    verdict = validate_chrome_trace(doc)
    print(f"chrome trace: {verdict['n_events']} events, "
          f"{verdict['n_async_trees']} request tree(s) -> {out_path} "
          "(open in ui.perfetto.dev or chrome://tracing)")
    if not verdict["ok"]:
        print(f"VALIDATION FAILED: {verdict['n_schema_errors']} schema "
              f"error(s), {verdict['tiling_violations']} tiling "
              f"violation(s), {verdict['sum_violations']} span-sum "
              f"violation(s)", file=sys.stderr)
        for err in verdict["schema_errors"]:
            print(f"  {err}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace_file", help="JSONL trace file (--trace-out)")
    ap.add_argument("--kind", default=None,
                    help="only report traces of this kind "
                         "(e.g. request, chunk)")
    ap.add_argument("--chrome-trace", metavar="OUT", default=None,
                    help="also export the span trees as Chrome-trace "
                         "JSON for ui.perfetto.dev / chrome://tracing")
    args = ap.parse_args(argv)
    if not Path(args.trace_file).exists():
        print(f"no such file: {args.trace_file}", file=sys.stderr)
        return 2
    traces = load(args.trace_file)
    rc = report(traces, kind=args.kind)
    if args.chrome_trace is not None:
        kept = ([t for t in traces if t.get("kind") == args.kind]
                if args.kind else traces)
        rc = max(rc, export_chrome(kept, args.chrome_trace))
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
