#!/usr/bin/env python3
"""Live terminal console for a running `launch serve` fleet.

Polls the Prometheus text exposition written by `--metrics-out` (and,
optionally, the alert JSONL written by `--alerts-out`) and renders a
top-style view: per-replica queue depth, fleet tier mix, request/pool
counters, latency quantiles, SLO + anomaly status, and the most recent
alerts. Pure stdlib — point it at the files, no server required:

    PYTHONPATH=src python -m repro.launch.serve --workload so3 --server \\
        --replicas 4 --metrics-out /tmp/metrics.prom \\
        --alerts-out /tmp/alerts.jsonl &
    python scripts/obs_top.py /tmp/metrics.prom --alerts /tmp/alerts.jsonl

Use `--once` for a single snapshot (no screen clearing) — handy in
scripts and CI.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
from pathlib import Path

# `name{k="v",k2="v2"} value` or `name value` (exposition format,
# src/repro/obs/export.py); label values never contain quotes here.
_SAMPLE = re.compile(r'^([A-Za-z_:][A-Za-z0-9_:]*)(\{[^}]*\})?\s+(\S+)$')
_LABEL = re.compile(r'([A-Za-z_][A-Za-z0-9_]*)="([^"]*)"')


def parse_exposition(text: str):
    """-> (samples, exported_at) where samples maps
    (name, frozenset(labels.items())) -> float."""
    samples, exported_at = {}, None
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            if line.startswith("# exported_at"):
                try:
                    exported_at = float(line.split()[-1])
                except ValueError:
                    pass
            continue
        m = _SAMPLE.match(line)
        if not m:
            continue
        name, raw_labels, raw_value = m.groups()
        try:
            value = float(raw_value)
        except ValueError:
            continue
        labels = dict(_LABEL.findall(raw_labels or ""))
        samples[(name, frozenset(labels.items()))] = value
    return samples, exported_at


def select(samples, name, **where):
    """All (labels, value) for `name` whose labels include `where`."""
    out = []
    for (n, key), value in samples.items():
        if n != name:
            continue
        labels = dict(key)
        if all(labels.get(k) == v for k, v in where.items()):
            out.append((labels, value))
    return out


def _bar(value, scale, width=24):
    n = 0 if scale <= 0 else min(width, int(round(width * value / scale)))
    return "#" * n + "." * (width - n)


def tail_alerts(path, n=8):
    try:
        lines = Path(path).read_text().splitlines()
    except OSError:
        return []
    out = []
    for line in lines[-n:]:
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return out


def render(samples, exported_at, alerts, out=sys.stdout):
    now = time.time()
    age = "?" if exported_at is None else f"{now - exported_at:.1f}s ago"
    print(f"== repro fleet health == (export {age})", file=out)

    # per-replica queue depth
    depths = sorted(select(samples, "cluster_queue_depth"),
                    key=lambda lv: lv[0].get("replica", ""))
    if depths:
        peak = max(1.0, max(v for _, v in depths))
        print("\nqueue depth (per replica):", file=out)
        for labels, v in depths:
            r = labels.get("replica", "?")
            print(f"  r{r:>2} {_bar(v, peak)} {v:.0f}", file=out)

    # fleet tier mix
    tiers = sorted(select(samples, "cluster_replicas"),
                   key=lambda lv: lv[0].get("tier", ""))
    if tiers:
        mix = "  ".join(f"{la.get('tier', '?')}x{v:.0f}"
                        for la, v in tiers if v > 0)
        print(f"\ntier mix: {mix}", file=out)

    # request + pool counters
    reqs = select(samples, "serve_requests_total")
    if reqs:
        by_event = {}
        for labels, v in reqs:
            ev = labels.get("event", "?")
            by_event[ev] = by_event.get(ev, 0.0) + v
        line = "  ".join(f"{k}={v:.0f}" for k, v in sorted(by_event.items()))
        print(f"\nrequests: {line}", file=out)
    pool = select(samples, "pool_events_total")
    if pool:
        by_event = {}
        for labels, v in pool:
            ev = labels.get("event", "?")
            by_event[ev] = by_event.get(ev, 0.0) + v
        line = "  ".join(f"{k}={v:.0f}" for k, v in sorted(by_event.items()))
        print(f"pool events: {line}", file=out)

    # latency quantiles (summary-style samples carry a quantile label)
    lat = select(samples, "serve_request_latency_seconds", kind="request")
    qs = {la["quantile"]: v for la, v in lat if "quantile" in la}
    if qs:
        line = "  ".join(f"p{float(q) * 100:.0f}={v * 1e3:.1f}ms"
                         for q, v in sorted(qs.items(), key=lambda i:
                                            float(i[0])))
        print(f"latency (request): {line}", file=out)

    # SLO + anomaly status
    slos = sorted(select(samples, "slo_breached"),
                  key=lambda lv: lv[0].get("slo", ""))
    if slos:
        print("\nSLOs:", file=out)
        for labels, v in slos:
            mark = "BREACH" if v else "ok"
            print(f"  {labels.get('slo', '?'):<22} {mark}", file=out)
    anomalies = sorted(select(samples, "anomaly_active"),
                       key=lambda lv: lv[0].get("detector", ""))
    active = [la.get("detector", "?") for la, v in anomalies if v]
    if anomalies:
        print("anomalies: " + (", ".join(active) if active else "none"),
              file=out)

    # alert feed tail
    if alerts:
        print("\nrecent alerts:", file=out)
        for a in alerts:
            print(f"  [{a.get('severity', '?'):<8}] "
                  f"{a.get('name', '?'):<22} {a.get('message', '')}",
                  file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("metrics_file",
                    help="Prometheus text file written by --metrics-out")
    ap.add_argument("--alerts", default=None, metavar="PATH",
                    help="alert JSONL written by --alerts-out")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="refresh period in seconds")
    ap.add_argument("--once", action="store_true",
                    help="render a single snapshot and exit")
    ap.add_argument("--iterations", type=int, default=0,
                    help="stop after this many refreshes (0 = forever)")
    args = ap.parse_args(argv)

    i = 0
    while True:
        try:
            text = Path(args.metrics_file).read_text()
        except OSError:
            text = ""
        samples, exported_at = parse_exposition(text)
        alerts = tail_alerts(args.alerts) if args.alerts else []
        if not args.once:
            sys.stdout.write("\x1b[2J\x1b[H")   # clear + home
        if samples:
            render(samples, exported_at, alerts)
        else:
            print(f"waiting for metrics at {args.metrics_file} ...")
        i += 1
        if args.once or (args.iterations and i >= args.iterations):
            return 0
        try:
            time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    raise SystemExit(main())
