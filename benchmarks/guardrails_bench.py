"""Guardrails benchmark: seeded poison/stall/drift chaos (ISSUE 8).

The claim under test: the runtime health layer (``repro.guardrails`` +
the cluster's tiered escalation, circuit breaker, and stall watchdog)
turns physics/numerics failures into *typed, recoverable* outcomes with
negligible cost on the clean path — a caller never receives a silent
NaN, no request is lost to a quarantine, and an escalated re-run is
bit-identical to asking the higher tier directly.

Scenarios:

1. **Escalation correctness** — a mixed-precision fleet (two w4a8
   traffic replicas behind a hair-trigger force envelope + one w8a8
   escalation replica, all quantized from the same weights): every
   request flags suspect at w4a8 and transparently re-runs at w8a8.
   Each delivered result must carry its ``EscalationRecord`` trail and
   be **bit-identical** to a direct batch-of-1 call on a reference w8a8
   engine built from the same serving tree (escalation replicas run
   singleton flushes precisely to make this hold).
2. **NaN poison** — seeded traffic with a poison fraction (NaN
   coordinates, dense path — the path NaN propagates through) into a
   guarded single-tier pool: every poison resolves a typed
   :class:`GuardrailViolation`, every clean request delivers finite,
   zero results with non-finite payloads delivered anywhere.
3. **Stall + quarantine** — engine-lock stalls (the ``sessions.faults``
   failure mode) injected under live traffic on a watchdog-enabled
   pool: every injected stall detected, the sick replica quarantined +
   cold-restarted, and **zero requests lost** — expropriated work
   fails over to survivors and resolves.
4. **Detector overhead A/B** — the same engine with detectors on
   (non-finite + calibrated envelope, ~1% of molecules flagging) vs an
   all-off :class:`GuardrailConfig`: median per-batch latency ratio
   must stay under 1.10x. Timing-gated, so full-size runs only
   (``smoke_ok=False``).
5. **Guarded MD session** — a tiered pool running chunked MD under the
   per-checkpoint monitors: a sane ``drift_limit`` completes clean,
   and an absurd one (1e-12 eV) escalates the chunk one precision tier
   (session telemetry records it) and then fails **typed** from the
   escalated tier — never a garbage trajectory delivered as "done".

Run:  PYTHONPATH=src python benchmarks/guardrails_bench.py
          [--requests 160] [--escalation-mols 32] [--stalls 2]
          [--overhead-batches 200] [--json BENCH_guardrails.json]
          [--smoke]

Writes a ``repro.bench/1`` document (benchmarks/schema.py); the runner
drives the same measurement through :func:`run`.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

# devices must be forced before jax initializes (cluster_bench has the
# full rationale); under ``benchmarks.run`` the parent already committed
# the count into the child environment, so this is a no-op there.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import jax          # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema                                  # noqa: E402
from benchmarks.schema import Metric                           # noqa: E402
from repro.cluster import ClusterConfig, ClusterPool           # noqa: E402
from repro.guardrails import (ForceEnvelope, GuardrailConfig,  # noqa: E402
                              GuardrailViolation)
from repro.md.engine import MDConfig                           # noqa: E402
from repro.models import so3krates as so3                      # noqa: E402
from repro.server.scheduler import (RequestHandle,             # noqa: E402
                                    RequestTimeout)
from repro.serving import (Graph, QuantizedEngine,             # noqa: E402
                           ServeConfig)
from repro.serving.qparams import quantize_so3_params          # noqa: E402
from repro.sessions import SessionConfig, SessionManager       # noqa: E402

WAIT_S = 1200.0
BUCKET = 16


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w4a8",
                    choices=["fp32", "w8a8", "w4a8"],
                    help="traffic (primary) tier; escalation runs one "
                         "tier above it")
    ap.add_argument("--escalation-mols", type=int, default=32,
                    help="scenario 1: molecules forced through the "
                         "escalation ladder and bit-compared")
    ap.add_argument("--requests", type=int, default=160,
                    help="scenario 2: total requests in the poison mix")
    ap.add_argument("--poison-every", type=int, default=40,
                    help="scenario 2: every Nth request is NaN-poisoned")
    ap.add_argument("--stalls", type=int, default=2,
                    help="scenario 3: injected engine-lock stalls "
                         "(keep in sync with the committed >= gate)")
    ap.add_argument("--stall-traffic", type=int, default=8,
                    help="scenario 3: background requests per stall")
    ap.add_argument("--overhead-batches", type=int, default=200,
                    help="scenario 4: timed batches per A/B arm")
    ap.add_argument("--md-steps", type=int, default=60,
                    help="scenario 5: session length (multiple of 20)")
    ap.add_argument("--atoms", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=4,
                    help="scenario 2 pool size")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--json", default="BENCH_guardrails.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--workdir", default="/tmp/guardrails_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: same zero-NaN/zero-loss/"
                         "bit-identity gates, overhead gate skipped")
    return ap


def apply_smoke(args) -> None:
    args.escalation_mols = 6
    args.requests = 24
    args.poison_every = 8
    args.overhead_batches = 20
    args.md_steps = 40


def _graph(n_species, n=12, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return Graph(species=rng.integers(0, n_species, n).astype(np.int32),
                 coords=rng.uniform(0, side, size=(n, 3)).astype(np.float32))


def _poison(n_species, n=12, seed=0):
    g = _graph(n_species, n, seed)
    coords = g.coords.copy()
    coords[0] = np.nan
    return Graph(species=g.species, coords=coords)


def _nonfinite(r) -> bool:
    return not (np.isfinite(np.asarray(r.energy)).all()
                and np.isfinite(np.asarray(r.forces)).all())


def scenario_escalation(model_cfg, params, serve4, serve8, args) -> dict:
    """Mixed-tier fleet, hair-trigger envelope: every request escalates
    w4a8 -> w8a8 and must match a direct w8a8 run bit-for-bit."""
    hair = GuardrailConfig(
        envelope=ForceEnvelope(limits=((BUCKET, 1e-9),)))
    qp4 = quantize_so3_params(params, serve4.mode)
    qp8 = quantize_so3_params(params, serve8.mode)
    engines = [
        QuantizedEngine.from_quantized(model_cfg, qp4, serve4,
                                       guardrails=hair),
        QuantizedEngine.from_quantized(model_cfg, qp4, serve4,
                                       guardrails=hair),
        QuantizedEngine.from_quantized(model_cfg, qp8, serve8),
    ]
    ref = QuantizedEngine.from_quantized(model_cfg, qp8, serve8)
    bit_mismatches = missing = nonfinite = 0
    lat = []
    with ClusterPool(engines, ClusterConfig(
            n_replicas=3, max_batch=4, deadline_ms=2.0, warmup=False,
            max_escalations=1)) as pool:
        graphs = [_graph(model_cfg.n_species, n=args.atoms, seed=100 + i)
                  for i in range(args.escalation_mols)]
        handles = [pool.submit(g) for g in graphs]
        for g, h in zip(graphs, handles):
            r = h.result(timeout=WAIT_S)
            lat.append(h.latency_s)
            if _nonfinite(r):
                nonfinite += 1
            if not r.escalations:
                missing += 1
                continue
            direct = ref.infer_batch([g])[0]
            if not (r.energy == direct.energy
                    and np.array_equal(np.asarray(r.forces),
                                       np.asarray(direct.forces))):
                bit_mismatches += 1
        st = pool.stats()["guardrails"]
    out = {
        "n_mols": args.escalation_mols,
        "bit_mismatches": bit_mismatches,
        "missing_escalations": missing,
        "nonfinite_delivered": nonfinite,
        "n_flagged": st["n_flagged"],
        "n_escalated": st["n_escalated"],
        "escalated_p50_ms": float(np.percentile(lat, 50) * 1e3),
    }
    print(f"escalation: {args.escalation_mols} mols, "
          f"{st['n_escalated']} escalated, {bit_mismatches} bit "
          f"mismatches, {missing} missing records")
    return out


def scenario_poison(model_cfg, params, serve4, args) -> dict:
    """Seeded NaN poison through a guarded single-tier pool: typed
    errors for poison, finite results for everything else."""
    qp4 = quantize_so3_params(params, serve4.mode)
    n_poison = args.requests // args.poison_every
    nonfinite = untyped = lost = clean_ok = typed = 0
    with ClusterPool.from_quantized(
            model_cfg, qp4, serve4,
            cluster=ClusterConfig(n_replicas=args.replicas, max_batch=4,
                                  deadline_ms=2.0, warmup=False)) as pool:
        handles = []
        for i in range(args.requests):
            poisoned = i % args.poison_every == args.poison_every - 1
            g = (_poison(model_cfg.n_species, n=args.atoms, seed=i)
                 if poisoned
                 else _graph(model_cfg.n_species, n=args.atoms, seed=i))
            handles.append((poisoned, pool.submit(g)))
        for poisoned, h in handles:
            try:
                r = h.result(timeout=WAIT_S)
            except GuardrailViolation:
                typed += 1
                if not poisoned:
                    untyped += 1     # a clean request must never flag here
                continue
            except RequestTimeout:
                lost += 1
                continue
            if _nonfinite(r):
                nonfinite += 1
            if poisoned:
                untyped += 1         # poison delivered as a result
            else:
                clean_ok += 1
    out = {
        "n_requests": args.requests,
        "n_poison": n_poison,
        "typed_errors": typed,
        "poison_untyped": untyped,
        "nonfinite_delivered": nonfinite,
        "requests_lost": lost,
        "clean_delivered": clean_ok,
    }
    print(f"poison: {args.requests} requests ({n_poison} poisoned) -> "
          f"{typed} typed errors, {nonfinite} non-finite delivered, "
          f"{lost} lost")
    return out


def scenario_stall(model_cfg, params, serve8, args) -> dict:
    """Injected engine-lock stalls under traffic: watchdog detects each
    one, quarantines + respawns, and no request is lost."""
    qp8 = quantize_so3_params(params, serve8.mode)
    detected_target = args.stalls
    lost = nonfinite = 0
    # warmup=True: the watchdog cannot tell a first-flush compile from a
    # stall, so a watchdog fleet pre-compiles (docs/guardrails.md)
    with ClusterPool.from_quantized(
            model_cfg, qp8, serve8,
            cluster=ClusterConfig(n_replicas=2, max_batch=4,
                                  deadline_ms=2.0, warmup=True,
                                  stall_timeout_s=0.3,
                                  watchdog_interval_s=0.05,
                                  probation_s=0.2,
                                  max_quarantines=args.stalls + 1)
            ) as pool:
        for k in range(args.stalls):
            idx = k % 2
            deadline = time.monotonic() + WAIT_S
            while (not pool._replicas[idx].accepting
                   and time.monotonic() < deadline):
                time.sleep(0.02)   # previous round's probation
            rep = pool._replicas[idx]
            rep.inject_stall(30.0)
            pinned = RequestHandle(
                _graph(model_cfg.n_species, n=args.atoms, seed=900 + k),
                time.monotonic(), bucket_capacity=BUCKET)
            if not rep.try_submit(pinned):
                raise SystemExit("FAIL: stall target refused admission")
            background = [pool.submit(_graph(model_cfg.n_species,
                                             n=args.atoms,
                                             seed=1000 + 50 * k + i))
                          for i in range(args.stall_traffic)]
            for h in [pinned] + background:
                try:
                    if _nonfinite(h.result(timeout=WAIT_S)):
                        nonfinite += 1
                except BaseException:
                    lost += 1
            deadline = time.monotonic() + WAIT_S
            while (pool.stats()["guardrails"]["n_stalls_detected"] < k + 1
                   and time.monotonic() < deadline):
                time.sleep(0.05)
        st = pool.stats()["guardrails"]
    out = {
        "stalls_injected": detected_target,
        "stalls_detected": st["n_stalls_detected"],
        "n_quarantined": st["n_quarantined"],
        "n_respawned": st["n_respawned"],
        "requests_lost": lost,
        "nonfinite_delivered": nonfinite,
        "per_stall_traffic": args.stall_traffic,
    }
    print(f"stall: {detected_target} injected, "
          f"{st['n_stalls_detected']} detected, "
          f"{st['n_respawned']} respawned, {lost} requests lost")
    return out


def scenario_overhead(model_cfg, params, serve4, args) -> dict:
    """A/B the detectors' clean-path cost: guarded (envelope calibrated
    on this engine's own traffic, ~1% of molecules poisoned so flags
    actually fire) vs an all-off config, identical batches."""
    qp4 = quantize_so3_params(params, serve4.mode)
    plain = QuantizedEngine.from_quantized(
        model_cfg, qp4, serve4,
        guardrails=GuardrailConfig(check_finite=False))
    cal = plain.infer_batch([_graph(model_cfg.n_species, n=args.atoms,
                                    seed=i) for i in range(4)])
    guarded = QuantizedEngine.from_quantized(
        model_cfg, qp4, serve4,
        guardrails=GuardrailConfig(
            check_finite=True, envelope=ForceEnvelope.calibrate(cal)))
    batches = []
    for b in range(args.overhead_batches):
        batch = []
        for j in range(4):
            i = 4 * b + j
            batch.append(_poison(model_cfg.n_species, n=args.atoms, seed=i)
                         if i % 100 == 99
                         else _graph(model_cfg.n_species, n=args.atoms,
                                     seed=i))
        batches.append(batch)

    def arm(engine, on_flag):
        for batch in batches[:3]:                      # warm / compile
            engine.infer_batch(batch, on_flag=on_flag)
        ts = []
        for batch in batches:
            t0 = time.perf_counter()
            engine.infer_batch(batch, on_flag=on_flag)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    plain_s = arm(plain, None)          # inactive config: no checks run
    guarded_s = arm(guarded, "mark")
    ratio = guarded_s / plain_s
    out = {
        "batches": args.overhead_batches,
        "batch_size": 4,
        "flag_rate": 0.01,
        "plain_p50_ms": plain_s * 1e3,
        "guarded_p50_ms": guarded_s * 1e3,
        "overhead_x": ratio,
        "flagged": guarded.guard_snapshot()["flagged_nonfinite"],
    }
    print(f"overhead: plain {plain_s * 1e3:.2f} ms/batch, guarded "
          f"{guarded_s * 1e3:.2f} ms/batch -> {ratio:.3f}x")
    return out


def scenario_md_session(model_cfg, params, serve_md, args, root) -> dict:
    """Chunked MD under the checkpoint monitors on a tiered pool: a
    sane drift limit completes; an absurd one escalates then fails
    typed from the escalated tier."""
    cluster = ClusterConfig(n_replicas=2, max_batch=4, deadline_ms=5.0,
                            warmup=False)
    tier_plan = {serve_md.mode: 1, "w8a8" if serve_md.mode == "w4a8"
                 else "fp32": 1}
    rng = np.random.default_rng(7)
    n = args.atoms
    side = (n / 0.1) ** (1.0 / 3.0)
    sp = rng.integers(0, model_cfg.n_species, n).astype(np.int32)
    co = rng.uniform(0, side, size=(n, 3)).astype(np.float32)
    masses = np.full(n, 12.0, np.float32)
    done = escalation_typed = nonfinite_frames = 0
    n_escalations = 0
    with ClusterPool.from_tiers(model_cfg, params=params, serve=serve_md,
                                tier_plan=tier_plan,
                                cluster=cluster) as pool:
        mgr = SessionManager(pool, os.path.join(root, "md_ok"))
        s = mgr.start(sp, co, masses, seed=5, config=SessionConfig(
            n_steps=args.md_steps, chunk_steps=20, record_every=10,
            md=MDConfig(mode=serve_md.mode, dt_fs=0.25, record_every=10,
                        drift_limit=10.0)))
        if s.wait(WAIT_S) == "done":
            done = 1
        nonfinite_frames = sum(
            1 for f in s.collected
            if not np.isfinite(np.asarray(f.e_tot)).all())
        mgr.close()

        mgr2 = SessionManager(pool, os.path.join(root, "md_drift"))
        s2 = mgr2.start(sp, co, masses, seed=5, config=SessionConfig(
            n_steps=args.md_steps, chunk_steps=20, record_every=10,
            max_escalations=1,
            md=MDConfig(mode=serve_md.mode, dt_fs=0.25, record_every=10,
                        drift_limit=1e-12)))
        try:
            s2.wait(WAIT_S)
        except GuardrailViolation as e:
            if (e.reason == "energy_drift"
                    and s2.n_escalations >= 1):
                escalation_typed = 1
        n_escalations = s2.n_escalations
        mgr2.close()
    out = {
        "md_steps": args.md_steps,
        "clean_session_done": done,
        "nonfinite_frames": nonfinite_frames,
        "drift_session_escalations": n_escalations,
        "drift_escalation_typed": escalation_typed,
    }
    print(f"md session: clean done={bool(done)}, drift session "
          f"escalated {n_escalations}x then failed "
          f"typed={bool(escalation_typed)}")
    return out


def collect(args) -> dict:
    if args.mode == "fp32":
        raise SystemExit("--mode fp32 has no tier above it to escalate "
                         "to; the guardrails bench needs a quantized "
                         "primary tier (w4a8 or w8a8)")
    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=4,
                                    n_layers=args.layers, n_rbf=4,
                                    dir_bits=6, cutoff=3.0)
    # dense path: the one NaN coordinates propagate through (the sparse
    # host edge build drops NaN-distance pairs) — poison must be seen
    serve4 = ServeConfig(mode=args.mode, bucket_sizes=(BUCKET,),
                         max_batch=4, path="dense")
    esc_mode = "w8a8" if args.mode == "w4a8" else "fp32"
    serve8 = dataclasses.replace(serve4, mode=esc_mode)
    serve_md = ServeConfig(mode=args.mode, bucket_sizes=(BUCKET,),
                           max_batch=4)
    params = so3.init_params(jax.random.PRNGKey(0), model_cfg)
    os.makedirs(args.workdir, exist_ok=True)
    root = os.path.join(args.workdir, f"run_{int(time.time() * 1e3)}")
    print(f"mode={args.mode} (escalates to {esc_mode}) "
          f"backend={jax.default_backend()} "
          f"devices={len(jax.devices())} requests={args.requests} "
          f"stalls={args.stalls}")
    record = {
        "benchmark": "guardrails_chaos",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "n_cores": os.cpu_count() or 1,
        "mode": args.mode,
        "escalation_mode": esc_mode,
        "feat": args.feat,
        "n_layers": args.layers,
        "n_atoms": args.atoms,
        "n_replicas": args.replicas,
        "escalation": scenario_escalation(model_cfg, params, serve4,
                                          serve8, args),
        "poison": scenario_poison(model_cfg, params, serve4, args),
        "stall": scenario_stall(model_cfg, params, serve8, args),
        "overhead": scenario_overhead(model_cfg, params, serve4, args),
        "md_session": scenario_md_session(model_cfg, params, serve_md,
                                          args, root),
        "smoke": args.smoke,
    }
    record["nonfinite_delivered_total"] = (
        record["escalation"]["nonfinite_delivered"]
        + record["poison"]["nonfinite_delivered"]
        + record["stall"]["nonfinite_delivered"]
        + record["md_session"]["nonfinite_frames"])
    record["requests_lost_total"] = (record["poison"]["requests_lost"]
                                     + record["stall"]["requests_lost"])
    return record


def metrics_from_record(record: dict) -> list:
    """Normalize into gated metrics. Every count gate is hard and
    size-independent (a silent NaN or a lost request is a correctness
    bug at any scale), so they gate smoke runs too; the overhead ratio
    is timing and only means something at full size."""
    esc, po, stl = record["escalation"], record["poison"], record["stall"]
    ov, md = record["overhead"], record["md_session"]
    return [
        Metric("guardrail_nonfinite_delivered",
               float(record["nonfinite_delivered_total"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("guardrail_requests_lost",
               float(record["requests_lost_total"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("guardrail_escalation_bit_mismatches",
               float(esc["bit_mismatches"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("guardrail_escalations_missing",
               float(esc["missing_escalations"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("guardrail_poison_untyped", float(po["poison_untyped"]),
               "count", kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("guardrail_stalls_detected",
               float(stl["stalls_detected"]), "count", kind="hard",
               gate={"op": "ge", "bound": 2.0}),
        Metric("guardrail_md_clean_session_done",
               float(md["clean_session_done"]), "bool", kind="hard",
               gate={"op": "eq", "bound": 1.0}),
        Metric("guardrail_md_drift_escalation_typed",
               float(md["drift_escalation_typed"]), "bool", kind="hard",
               gate={"op": "eq", "bound": 1.0}),
        Metric("guardrail_overhead_x", ov["overhead_x"], "x",
               kind="hard", gate={"op": "le", "bound": 1.10},
               smoke_ok=False),
        Metric("guardrail_escalated_p50_ms", esc["escalated_p50_ms"],
               "ms", direction="lower"),
        Metric("guardrail_replicas_respawned",
               float(stl["n_respawned"]), "count", kind="info"),
        Metric("guardrail_typed_errors", float(po["typed_errors"]),
               "count", kind="info"),
    ]


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead). All zero-loss/typed-delivery claims hold at smoke size;
    only the overhead ratio is full-size-only."""
    esc, po, stl = record["escalation"], record["poison"], record["stall"]
    md = record["md_session"]
    fails = []
    if record["nonfinite_delivered_total"] != 0:
        fails.append(f"{record['nonfinite_delivered_total']} non-finite "
                     "results delivered (must be 0)")
    if record["requests_lost_total"] != 0:
        fails.append(f"{record['requests_lost_total']} requests lost "
                     "(must be 0)")
    if esc["bit_mismatches"] != 0:
        fails.append(f"{esc['bit_mismatches']} escalated results differ "
                     "from the direct higher-tier run (must be "
                     "bit-identical)")
    if esc["missing_escalations"] != 0:
        fails.append(f"{esc['missing_escalations']} flagged results "
                     "delivered without an escalation record")
    if po["poison_untyped"] != 0:
        fails.append(f"{po['poison_untyped']} poison requests not "
                     "resolved as typed GuardrailViolation")
    if stl["stalls_detected"] < stl["stalls_injected"]:
        fails.append(f"only {stl['stalls_detected']}/"
                     f"{stl['stalls_injected']} injected stalls detected")
    if not md["clean_session_done"]:
        fails.append("guarded MD session with a sane drift limit did "
                     "not complete")
    if not md["drift_escalation_typed"]:
        fails.append("drifting MD session did not escalate a tier and "
                     "fail typed")
    if not record["smoke"] \
            and record["overhead"]["overhead_x"] > 1.10:
        fails.append(f"detector overhead "
                     f"{record['overhead']['overhead_x']:.3f}x > 1.10x")
    if fails:
        raise SystemExit("FAIL: " + "; ".join(fails))
    print(f"PASS: zero non-finite delivered, zero lost, "
          f"{esc['n_escalated']} bit-identical escalations, "
          f"{stl['stalls_detected']} stalls recovered, overhead "
          f"{record['overhead']['overhead_x']:.3f}x")


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.mode in ("fp32", "w8a8", "w4a8"):
        args.mode = config.mode
    if config.smoke:
        apply_smoke(args)
    if config.replicas > 1:
        args.replicas = config.replicas
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        result = schema.ExperimentResult(
            experiment={"domain": "guardrails", "mode": args.mode,
                        "path": "dense", "replicas": args.replicas,
                        "devices": len(jax.devices()),
                        "smoke": args.smoke},
            fingerprint=(f"guardrails:{args.mode}:dense:r{args.replicas}"
                         f":d{len(jax.devices())}"),
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/guardrails_bench.py"))
        print(f"\nwrote {args.json}")
    check(record)


if __name__ == "__main__":
    main()
