"""Sessions benchmark: streaming MD through injected faults (ISSUE 7).

The claim under test: a long MD trajectory run as chunked session work
through ``repro.sessions`` keeps the cluster's robustness story intact
*for stateful work* — the session survives an in-flight replica kill, a
mid-trajectory rolling weight swap, a corrupted (bitflipped) newest
checkpoint, and a full process restart, and still delivers **every
frame exactly as an uninterrupted run of the same seed would have**:
zero lost frames, replayed frames bit-identical to their first
delivery, final state equal to the uninterrupted reference to <= 1e-6
(deterministic chunk replay makes it bit-identical on CPU), and an
energy-drift ratio vs the reference within the MD domain's existing 2x
conservation gate (in practice ~1.00: same trajectory).

Scenarios:

1. **Uninterrupted reference** — one w8a8 session of ``--steps`` NVE
   steps on a fresh 2-replica pool: steps/s, drift rate, checkpoint
   cadence. This is the trajectory the chaos run must reproduce.
2. **Interleaving** — a second session streams on the same pool while
   seeded one-shot inference replays against it: one-shot p50/p99 and
   zero lost requests required (chunks hold a replica for whole
   ``chunk_steps`` blocks; admission must still serve both tenants).
3. **Seeded chaos** — the acceptance scenario: the same trajectory
   under a fault schedule of an in-flight replica kill, a rolling
   ``swap_artifact`` (weight-identical artifact, new version tag — the
   rolling-swap *mechanics* fire while keeping the reference
   comparison meaningful), an engine-lock stall, and a bitflipped
   newest checkpoint; then a simulated process death (cancel) and
   ``SessionManager.resume_all()`` on a fresh manager. Frame-loss,
   replay-mismatch, final-state-diff, drift-ratio, faults-engaged and
   checkpoints-restored all gate **hard** — they are size-independent,
   so they gate smoke runs too.

The model is deliberately tiny (the MD bench owns model-scale claims;
this bench owns robustness claims, which do not depend on feat width)
so the full-size >= 2000-step trajectory stays tractable on the 1-core
reference container.

Run:  PYTHONPATH=src python benchmarks/sessions_bench.py
          [--steps 2400] [--chunk-steps 200] [--replicas 2]
          [--json BENCH_sessions.json] [--smoke]

Writes a ``repro.bench/1`` document (benchmarks/schema.py); the runner
drives the same measurement through :func:`run`.
"""
from __future__ import annotations

import argparse
import os
import time

# devices must be forced before jax initializes (cluster_bench has the
# full rationale); under ``benchmarks.run`` the parent already committed
# the count into the child environment, so this is a no-op there.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()

import jax          # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema                                  # noqa: E402
from benchmarks.schema import Metric                           # noqa: E402
from repro.cluster import ClusterConfig, ClusterPool           # noqa: E402
from repro.md import energy_drift_rate                         # noqa: E402
from repro.md.engine import MDConfig                           # noqa: E402
from repro.models import so3krates as so3                      # noqa: E402
from repro.server.artifact import save_artifact                # noqa: E402
from repro.serving import Graph, ServeConfig                   # noqa: E402
from repro.sessions import (FaultInjector, FaultSpec,          # noqa: E402
                            SessionConfig, SessionManager)

WAIT_S = 1200.0


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w8a8",
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--steps", type=int, default=2400,
                    help="NVE steps per session (acceptance: >= 2000)")
    ap.add_argument("--chunk-steps", type=int, default=200)
    ap.add_argument("--record-every", type=int, default=100)
    ap.add_argument("--checkpoint-every", type=int, default=3,
                    help="checkpoint cadence in chunks (3 keeps the "
                         "chaos geometry honest: with the kill point at "
                         "chunk 7 the in-flight 8th chunk completes "
                         "without writing a fresh checkpoint over the "
                         "corrupted step_6, so resume must fall back)")
    ap.add_argument("--atoms", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--oneshots", type=int, default=24,
                    help="one-shot requests interleaved in scenario 2")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--dt-fs", type=float, default=0.25)
    ap.add_argument("--json", default="BENCH_sessions.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--workdir", default="/tmp/sessions_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: short trajectory, same hard "
                         "zero-loss/determinism gates")
    return ap


def apply_smoke(args) -> None:
    args.steps = 500
    args.chunk_steps = 50
    args.record_every = 25
    args.oneshots = 8


def _molecule(n, n_species, seed=21, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return (rng.integers(0, n_species, n).astype(np.int32),
            rng.uniform(0, side, size=(n, 3)).astype(np.float32),
            np.full(n, 12.0, np.float32))


def _drift(frames, dt_fs, record_every, n_atoms):
    """Drift-rate fit over a session's streamed frame series (dedup by
    global index, replica lane 0, uniform spacing assumed — sessions
    enforce chunk/record alignment so every frame is on-grid)."""
    by_idx = {f.index: float(np.asarray(f.e_tot)[0]) for f in frames}
    e = np.asarray([by_idx[i] for i in sorted(by_idx)])
    return energy_drift_rate(e, dt_fs, record_every, n_atoms)


def collect(args) -> dict:
    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=4,
                                    n_layers=args.layers, n_rbf=4,
                                    dir_bits=6, cutoff=3.0)
    serve = ServeConfig(mode=args.mode, bucket_sizes=(16,), max_batch=4)
    cluster = ClusterConfig(n_replicas=args.replicas, max_batch=4,
                            warmup=False, max_queue=64)
    scfg = SessionConfig(
        n_steps=args.steps, chunk_steps=args.chunk_steps,
        record_every=args.record_every,
        checkpoint_every=args.checkpoint_every,
        md=MDConfig(mode=args.mode, dt_fs=args.dt_fs,
                    record_every=args.record_every))
    if args.steps % args.chunk_steps != 0:
        raise SystemExit("--steps must be a multiple of --chunk-steps "
                         "(the frame-accounting below assumes full "
                         "chunks)")
    if scfg.n_chunks < 10:
        raise SystemExit(f"fault schedule needs >= 10 chunks (faults at "
                         f"boundaries 2-6, kill point 7, and the 8th "
                         f"chunk must be neither final nor a checkpoint "
                         f"boundary so the corrupted step_6 stays the "
                         f"newest checkpoint); {args.steps}/"
                         f"{args.chunk_steps} gives {scfg.n_chunks}")
    sp, co, masses = _molecule(args.atoms, model_cfg.n_species)
    n_frames = scfg.n_chunks * scfg.frames_per_chunk
    os.makedirs(args.workdir, exist_ok=True)
    run_tag = str(int(time.time() * 1e3))
    root = os.path.join(args.workdir, f"run_{run_tag}")
    print(f"mode={args.mode} backend={jax.default_backend()} "
          f"devices={len(jax.devices())} steps={args.steps} "
          f"chunks={scfg.n_chunks}x{args.chunk_steps} "
          f"frames={n_frames} replicas={args.replicas}")

    def fresh_pool():
        return ClusterPool.from_config(model_cfg, serve=serve,
                                       cluster=cluster)

    # 1. uninterrupted reference + 2. interleaving on the same pool -------
    with fresh_pool() as pool:
        mgr = SessionManager(pool, os.path.join(root, "ref"))
        t0 = time.monotonic()
        ref = mgr.start(sp, co, masses, config=scfg, seed=8,
                        session_id="traj")
        assert ref.wait(WAIT_S) == "done"
        ref_span = time.monotonic() - t0
        mgr.close()
        ref_drift = _drift(ref.collected, args.dt_fs, args.record_every,
                           args.atoms)
        reference = {
            "n_steps": args.steps, "span_s": ref_span,
            "steps_per_s": args.steps / ref_span,
            "n_frames": ref.frames_emitted,
            "n_checkpoints": ref.n_checkpoints,
            "drift_ev_per_atom_ps": ref_drift,
        }
        print(f"reference: {args.steps} steps in {ref_span:.1f}s "
              f"({reference['steps_per_s']:.0f} steps/s), "
              f"{ref.n_checkpoints} checkpoints, drift "
              f"{ref_drift:.2e} eV/atom/ps")

        # interleave: a second session + one-shot traffic, one pool ------
        pool.reset_stats()
        mgr = SessionManager(pool, os.path.join(root, "interleave"))
        s2 = mgr.start(sp, co, masses, config=scfg, seed=9)
        rng = np.random.default_rng(43)
        handles = []
        for i in range(args.oneshots):
            handles.append(pool.submit(
                Graph(species=sp, coords=co + 0.01 * i)))
            time.sleep(float(rng.exponential(0.02)))
        results = [h.result(timeout=WAIT_S) for h in handles]
        assert s2.wait(WAIT_S) == "done"
        mgr.close()
        lat = np.asarray([h.latency_s for h in handles])
        st = pool.stats()
        interleave = {
            "n_oneshots": len(handles),
            "n_completed": len(results),
            "n_lost": len(handles) - len(results),
            "oneshot_p50_ms": float(np.percentile(lat, 50) * 1e3),
            "oneshot_p99_ms": float(np.percentile(lat, 99) * 1e3),
            "session_steps": s2.steps_done,
            "chunks": st["chunks"],
        }
        print(f"interleave: {len(results)}/{len(handles)} one-shots "
              f"(p99 {interleave['oneshot_p99_ms']:.1f} ms) beside a "
              f"{s2.steps_done}-step session")

    # 3. seeded chaos: kill + swap + stall + corrupt + restart ------------
    with fresh_pool() as pool:
        art = os.path.join(root, "weights_v2.rpa")
        save_artifact(art, pool._replicas[0].engine)
        schedule = [
            FaultSpec(kind="kill_replica", at_chunk=2, mode="in_flight"),
            FaultSpec(kind="swap_artifact", at_chunk=4,
                      artifact_path=art, swap_warmup=False),
            FaultSpec(kind="stall", at_chunk=5, stall_s=0.05),
            FaultSpec(kind="corrupt_checkpoint", at_chunk=6,
                      corruption="bitflip"),
        ]
        faults = FaultInjector(schedule, pool, seed=8)
        mgr = SessionManager(pool, os.path.join(root, "chaos"),
                             faults=faults)
        t0 = time.monotonic()
        # simulated process death at the end of chunk 7: the cancel is
        # raised from on_frame — which runs on the session's driver
        # thread — so the driver deterministically stops before chunk 8
        # regardless of how fast chunks complete. At that point the
        # newest checkpoint on disk is the corrupted step_6, so the
        # resume below must detect it and fall back to step_3.
        kill_frame = 7 * scfg.frames_per_chunk - 1
        holder = {}

        def kill_at_boundary(f):
            if f.index >= kill_frame and "s" in holder:
                holder["s"].cancel()

        s = mgr.start(sp, co, masses, config=scfg, seed=8,
                      session_id="traj", on_frame=kill_at_boundary)
        holder["s"] = s
        mgr.close()                       # joins the driver thread
        if s.status == "failed":
            raise SystemExit(f"FAIL: chaos session failed before the "
                             f"kill point: {s.error!r}")
        pre = {f.index: f for f in s.collected}
        counts = faults.counts()

        mgr2 = SessionManager(pool, os.path.join(root, "chaos"))
        resumed = mgr2.resume_all()
        if len(resumed) != 1:
            raise SystemExit(f"FAIL: resume_all found {len(resumed)} "
                             "sessions (expected 1)")
        r = resumed[0]
        assert r.wait(WAIT_S) == "done"
        chaos_span = time.monotonic() - t0
        resume_stats = mgr2.stats()
        mgr2.close()
        pool_stats = pool.stats()
        post = {f.index: f for f in r.collected}

    frames_lost = n_frames - len(set(pre) | set(post))
    replay_mismatch = sum(
        1 for i in set(pre) & set(post)
        if not np.array_equal(np.asarray(pre[i].e_tot),
                              np.asarray(post[i].e_tot)))
    final_diff = max(
        float(np.abs(np.asarray(getattr(r.state, leaf))
                     - np.asarray(getattr(ref.state, leaf))).max())
        for leaf in ("coords", "veloc"))
    merged = list(pre.values()) + [f for i, f in post.items()
                                   if i not in pre]
    chaos_drift = _drift(merged, args.dt_fs, args.record_every,
                         args.atoms)
    drift_ratio = abs(chaos_drift) / max(abs(ref_drift), 1e-12)
    versions = {f.artifact_version for f in merged}
    faults_engaged = (counts["kill_replica"] >= 1
                      and counts["swap_artifact"] >= 1
                      and counts["corrupt_checkpoint"] >= 1)
    chaos = {
        "schedule": [{"kind": f.kind, "at_chunk": f.at_chunk,
                      "mode": f.mode} for f in schedule],
        "fault_counts": counts,
        "faults_engaged": faults_engaged,
        "n_frames_expected": n_frames,
        "n_frames_pre": len(pre), "n_frames_post": len(post),
        "frames_lost": frames_lost,
        "replay_overlap": len(set(pre) & set(post)),
        "replay_mismatch": replay_mismatch,
        "final_state_max_diff": final_diff,
        "drift_ev_per_atom_ps": chaos_drift,
        "drift_ratio_chaos_vs_ref": drift_ratio,
        "artifact_versions_seen": len(versions),
        "checkpoints_restored": resume_stats["checkpoints_restored"],
        "chunks_requeued": pool_stats["chunks"]["n_requeued"],
        "chunk_retries": s.n_retries + r.n_retries,
        "n_live_after": pool_stats["n_live"],
        "span_s": chaos_span,
    }
    print(f"chaos: {counts['total']} faults, "
          f"{len(pre)}+{len(post)} frames "
          f"({chaos['replay_overlap']} replayed, {frames_lost} lost, "
          f"{replay_mismatch} mismatched), final-state max|diff| "
          f"{final_diff:.1e}, drift ratio {drift_ratio:.2f}x, "
          f"{chaos['checkpoints_restored']} checkpoint restored")

    return {
        "benchmark": "session_fault_tolerance",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "n_cores": os.cpu_count() or 1,
        "mode": args.mode,
        "feat": args.feat,
        "n_layers": args.layers,
        "n_atoms": args.atoms,
        "n_steps": args.steps,
        "chunk_steps": args.chunk_steps,
        "record_every": args.record_every,
        "checkpoint_every": args.checkpoint_every,
        "n_replicas": args.replicas,
        "reference": reference,
        "interleave": interleave,
        "chaos": chaos,
        "smoke": args.smoke,
    }


def metrics_from_record(record: dict) -> list:
    """Normalize the rich record into gated metrics (benchmarks.schema).

    Every chaos gate is **hard** and size-independent — losing a frame,
    diverging from the reference trajectory, or resuming without ever
    touching a checkpoint is a correctness bug at any trajectory length,
    so they gate smoke runs too. The drift-ratio bound is the MD
    domain's existing 2x conservation gate. Throughput/latency rows are
    informational (the MD and cluster benches own those claims)."""
    ch, il, ref = record["chaos"], record["interleave"], record["reference"]
    ms = [
        Metric("session_frames_lost", float(ch["frames_lost"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("session_replay_mismatch", float(ch["replay_mismatch"]),
               "count", kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("session_final_state_diff", ch["final_state_max_diff"],
               "", kind="hard", gate={"op": "le", "bound": 1e-6}),
        Metric("session_drift_ratio_chaos_vs_ref",
               ch["drift_ratio_chaos_vs_ref"], "x", kind="hard",
               gate={"op": "le", "bound": 2.0}),
        Metric("session_faults_engaged",
               1.0 if ch["faults_engaged"] else 0.0, "bool", kind="hard",
               gate={"op": "eq", "bound": 1.0}),
        Metric("session_checkpoints_restored",
               float(ch["checkpoints_restored"]), "count", kind="hard",
               gate={"op": "ge", "bound": 1.0}),
        Metric("interleave_oneshots_lost", float(il["n_lost"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("session_steps_per_s", ref["steps_per_s"], "steps/s"),
        Metric("interleave_oneshot_p99_ms", il["oneshot_p99_ms"], "ms",
               direction="lower"),
        Metric("session_chunks_requeued", float(ch["chunks_requeued"]),
               "count", kind="info"),
        Metric("session_chunk_retries", float(ch["chunk_retries"]),
               "count", kind="info"),
        Metric("session_artifact_versions_seen",
               float(ch["artifact_versions_seen"]), "count", kind="info"),
    ]
    return ms


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead). Unlike the throughput benches these hold at smoke size,
    so the standalone CLI asserts them on every run."""
    ch, il = record["chaos"], record["interleave"]
    fails = []
    if ch["frames_lost"] != 0:
        fails.append(f"lost {ch['frames_lost']} frames through the fault "
                     "schedule (must be 0)")
    if ch["replay_mismatch"] != 0:
        fails.append(f"{ch['replay_mismatch']} replayed frames differed "
                     "from their first delivery (replay must be "
                     "deterministic)")
    if ch["final_state_max_diff"] > 1e-6:
        fails.append(f"final state diverged "
                     f"{ch['final_state_max_diff']:.2e} from the "
                     "uninterrupted reference (> 1e-6)")
    if ch["drift_ratio_chaos_vs_ref"] > 2.0:
        fails.append(f"chaos-run drift {ch['drift_ratio_chaos_vs_ref']:.2f}x "
                     "the reference (> 2x MD conservation gate)")
    if not ch["faults_engaged"]:
        fails.append(f"fault schedule did not fully engage "
                     f"({ch['fault_counts']}) — scenario did not test "
                     "anything")
    if ch["checkpoints_restored"] < 1:
        fails.append("resume never restored a checkpoint")
    if il["n_lost"] != 0:
        fails.append(f"interleaving lost {il['n_lost']} one-shot requests")
    if fails:
        raise SystemExit("FAIL: " + "; ".join(fails))
    print(f"PASS: zero frame loss and final-state diff "
          f"{ch['final_state_max_diff']:.1e} through "
          f"{ch['fault_counts']['total']} injected faults + restart "
          f"(drift ratio {ch['drift_ratio_chaos_vs_ref']:.2f}x)")


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.mode in ("fp32", "w8a8", "w4a8"):
        args.mode = config.mode
    if config.smoke:
        apply_smoke(args)
    if config.replicas > 1:
        args.replicas = config.replicas
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        result = schema.ExperimentResult(
            experiment={"domain": "sessions", "mode": args.mode,
                        "path": "sparse", "replicas": args.replicas,
                        "devices": len(jax.devices()),
                        "smoke": args.smoke},
            fingerprint=(f"sessions:{args.mode}:sparse:r{args.replicas}"
                         f":d{len(jax.devices())}"),
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/sessions_bench.py"))
        print(f"\nwrote {args.json}")
    check(record)


if __name__ == "__main__":
    main()
