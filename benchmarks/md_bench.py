"""MD throughput benchmark: legacy per-step host loop vs device-resident
scan (ISSUE 3).

The claim under test: at MD step counts, per-step host work — neighbour
lists rebuilt in numpy, energies/forces round-tripped through host
arrays, dispatch and padding glue on every force call — multiplies into
the wall clock, and a velocity-Verlet loop that stays on device (skin
neighbour lists rebuilt under ``lax.cond``, forces from the quantized
sparse forward inside ``lax.scan``) buys that overhead back without
touching the physics.

Two lanes per mode (fp32 and w8a8), same molecule, same initial state,
same dt:

* **legacy** — the pre-PR way to drive MD with the quantized model: a
  python velocity-Verlet loop calling ``QuantizedEngine.infer_batch``
  every step (host edge-list build, padding, numpy round-trips
  included).
* **device** — ``repro.md.MDEngine``: the same physics inside
  ``lax.scan`` with Verlet-skin lists, host contact only at record
  checkpoints.

Speed never at the cost of conservation: both lanes record total energy
on the same trajectory and the bench reports the drift rate of each
(the fast path must stay within 2x of legacy — the **hard** gate
``benchmarks.run --diff-baselines`` enforces) plus the skin-rebuild
frequency, so the neighbour-list reuse is visibly not skipping physics.

Run:  PYTHONPATH=src python benchmarks/md_bench.py [--bucket 64]
          [--modes fp32 w8a8] [--steps 300] [--repeats 3]
          [--replicas 8] [--json BENCH_md.json] [--smoke]

Writes a ``repro.bench/1`` document (benchmarks/schema.py) with
per-mode steps/sec both lanes, speedup, drift rates, rebuild stats and
replica-batch throughput so the perf trajectory is tracked across PRs;
the runner drives the same measurement through :func:`run`. ``--smoke``
shrinks everything for CI.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema
from benchmarks.schema import Metric


def make_molecule(n_atoms, n_species, density, seed):
    rng = np.random.default_rng(seed)
    side = (n_atoms / density) ** (1.0 / 3.0)
    return (rng.integers(0, n_species, n_atoms).astype(np.int32),
            rng.uniform(0, side, size=(n_atoms, 3)).astype(np.float32))


def legacy_host_loop(engine, species, coords, veloc, masses, dt_fs,
                     n_steps, record_every):
    """Pre-PR MD: velocity-Verlet on the host, one ``infer_batch`` per
    step (neighbour list rebuilt host-side every step inside the
    engine's dispatch). Returns (coords, veloc, energy records)."""
    from repro.md.nve import _FS
    from repro.serving import Graph
    dt = dt_fs * _FS
    inv_m = (1.0 / masses)[:, None]
    r, v = coords.copy(), veloc.copy()
    res = engine.infer_batch([Graph(species, r)])[0]
    f = res.forces
    energies = []
    for step in range(1, n_steps + 1):
        v_half = v + 0.5 * dt * f * inv_m
        r = r + dt * v_half
        res = engine.infer_batch([Graph(species, r)])[0]
        f = res.forces
        v = v_half + 0.5 * dt * f * inv_m
        if step % record_every == 0 or step == n_steps:
            e_kin = 0.5 * float(np.sum(masses[:, None] * v ** 2))
            energies.append(res.energy + e_kin)
    return r, v, np.asarray(energies)


def bench_mode(mode, model_cfg, params, n, args):
    import jax
    from repro.md import (MDConfig, MDEngine, energy_drift_rate,
                          pad_replicas)
    from repro.serving import QuantizedEngine, ServeConfig
    species, coords = make_molecule(n, model_cfg.n_species, args.density,
                                    seed=n)
    masses = np.full(n, 12.011, np.float32)
    dt, rec_every = args.dt_fs, args.record_every

    # legacy rides the standard bucket ladder: smallest standard cap
    # that holds the molecule (a 24-atom smoke molecule gets the 32
    # bucket, not a pathological 24-cap shape class)
    cap = next((c for c in (16, 32, 64, 128) if n <= c), n)
    serve = ServeConfig(mode=mode, bucket_sizes=(cap,), max_batch=8,
                        path="sparse")
    legacy_engine = QuantizedEngine(model_cfg, params, serve)
    md_engine = MDEngine(model_cfg, params,
                         md=MDConfig(mode=mode, dt_fs=dt,
                                     record_every=rec_every))

    spec_b, co_b, mask_b = pad_replicas(species, coords, 1)
    state0 = md_engine.init_state(jax.random.PRNGKey(7), spec_b, co_b,
                                  mask_b, masses, args.temperature_K)
    veloc0 = np.asarray(state0.veloc[0])

    # warm both lanes (compile + first dispatch)
    legacy_host_loop(legacy_engine, species, coords, veloc0, masses, dt,
                     2, rec_every)
    state = state0
    state, _ = md_engine.run(state, spec_b, mask_b, masses,
                             n_steps=args.steps, record_every=rec_every)

    # interleaved timing so machine drift hits both lanes equally
    t_leg, t_dev = [], []
    rebuilds = steps_counted = 0
    for _ in range(args.repeats):
        t0 = time.time()
        _, _, e_leg = legacy_host_loop(legacy_engine, species, coords,
                                       veloc0, masses, dt, args.steps,
                                       rec_every)
        t_leg.append((time.time() - t0) / args.steps)
        # n_rebuilds in records is cumulative since init_state; the
        # per-run delta is what the rebuild-frequency stat needs
        n_before = int(state.nlist.n_rebuilds)
        t0 = time.time()
        state, rec_dev = md_engine.run(state, spec_b, mask_b, masses,
                                       n_steps=args.steps,
                                       record_every=rec_every)
        t_dev.append((time.time() - t0) / args.steps)
        rebuilds += rec_dev["n_rebuilds"] - n_before
        steps_counted += args.steps
    # drift fit wants uniformly spaced samples: drop any tail record
    # (the legacy trajectory is deterministic, so the last repeat's
    # energy record stands for all of them)
    n_uniform = args.steps // rec_every
    drift_leg = energy_drift_rate(e_leg[:n_uniform], dt, rec_every, n)

    # drift of the device lane on the *same* trajectory as legacy: fresh
    # state from the same initial conditions
    state_d = md_engine.init_state(jax.random.PRNGKey(7), spec_b, co_b,
                                   mask_b, masses, args.temperature_K)
    _, rec_same = md_engine.run(state_d, spec_b, mask_b, masses,
                                n_steps=args.steps, record_every=rec_every)
    drift_dev = energy_drift_rate(rec_same["e_tot"][:n_uniform, 0], dt,
                                  rec_every, n)

    # replica batching: amortized steps/sec for a padded replica bucket
    R = args.replicas
    spec_r, co_r, mask_r = pad_replicas(species, coords, R)
    masses_r = np.broadcast_to(masses, (R, n))
    st_r = md_engine.init_state(jax.random.PRNGKey(8), spec_r, co_r,
                                mask_r, masses_r, args.temperature_K)
    st_r, _ = md_engine.run(st_r, spec_r, mask_r, masses_r,
                            n_steps=args.steps, record_every=rec_every)
    t0 = time.time()
    st_r, _ = md_engine.run(st_r, spec_r, mask_r, masses_r,
                            n_steps=args.steps, record_every=rec_every)
    t_rep = (time.time() - t0) / args.steps

    tl, td = min(t_leg), min(t_dev)
    out = {
        "mode": mode,
        "n_atoms": n,
        "bucket": cap,
        "legacy_steps_per_s": 1.0 / tl,
        "device_steps_per_s": 1.0 / td,
        "speedup_device_vs_legacy": tl / td,
        "legacy_ms_per_step": tl * 1e3,
        "device_ms_per_step": td * 1e3,
        "legacy_drift_ev_per_atom_ps": drift_leg,
        "device_drift_ev_per_atom_ps": drift_dev,
        "drift_ratio_device_vs_legacy": (
            abs(drift_dev) / max(abs(drift_leg), 1e-12)),
        "edge_capacity": state0.nlist.edge_capacity,
        "n_rebuilds": int(rebuilds),
        "rebuild_interval_steps": steps_counted / max(int(rebuilds), 1),
        "replicas": R,
        "replica_batch_steps_per_s": 1.0 / t_rep,
        "replica_steps_per_s": R / t_rep,
    }
    return out


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[24, 48, 64],
                    help="molecule sizes to sweep (each rides the "
                         "smallest standard bucket that holds it)")
    ap.add_argument("--modes", nargs="+", default=["fp32", "w8a8"],
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--dt-fs", type=float, default=0.25)
    ap.add_argument("--record-every", type=int, default=50)
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--temperature-K", type=float, default=300.0)
    ap.add_argument("--feat", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--json", default="BENCH_md.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: tiny molecule, few steps")
    return ap


def apply_smoke(args) -> None:
    args.sizes = [24]
    args.steps, args.repeats, args.replicas = 40, 1, 2
    args.record_every = 20


def collect(args) -> dict:
    """Run the full measurement; returns the domain's rich record."""
    import jax
    from repro.models import so3krates as so3
    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=8,
                                    n_layers=args.layers, n_rbf=8,
                                    dir_bits=6, cutoff=3.0)
    params = so3.init_params(jax.random.PRNGKey(0), model_cfg)

    print(f"sizes={args.sizes} steps={args.steps} dt={args.dt_fs}fs "
          f"repeats={args.repeats} backend={jax.default_backend()}")
    print(f"{'atoms':>6} {'bucket':>6} {'mode':>6} {'legacy st/s':>12} "
          f"{'device st/s':>12} {'speedup':>8} {'drift ratio':>12} "
          f"{'rebuild every':>14}")
    rows = []
    for n in args.sizes:
        for mode in args.modes:
            row = bench_mode(mode, model_cfg, params, n, args)
            rows.append(row)
            print(f"{n:>6} {row['bucket']:>6} {mode:>6} "
                  f"{row['legacy_steps_per_s']:>12.1f} "
                  f"{row['device_steps_per_s']:>12.1f} "
                  f"{row['speedup_device_vs_legacy']:>7.2f}x "
                  f"{row['drift_ratio_device_vs_legacy']:>11.2f}x "
                  f"{row['rebuild_interval_steps']:>11.1f} st")

    return {
        "benchmark": "md_device_scan_vs_host_loop",
        "backend": jax.default_backend(),
        "sizes": args.sizes,
        "density": args.density,
        "dt_fs": args.dt_fs,
        "n_steps": args.steps,
        "record_every": args.record_every,
        "repeats": args.repeats,
        "feat": args.feat,
        "n_layers": args.layers,
        "temperature_K": args.temperature_K,
        "smoke": args.smoke,
        "rows": rows,
    }


def metrics_from_record(record: dict) -> list:
    """Normalize the rich record into gated metrics (benchmarks.schema).

    The drift ratio is the domain's correctness number — conservation of
    the device lane relative to the legacy lane on the same trajectory —
    so it gates **hard** at the bench's own 2x acceptance bound even on
    smoke runs. The >= 1.5x speedup floor is also hard, but only off
    smoke (``smoke_ok=False``): a 40-step run on a loaded CI box cannot
    fairly amortize the scan's dispatch."""
    ms = []
    for row in record["rows"]:
        key = f"[n{row['n_atoms']},{row['mode']}]"
        ms.append(Metric(f"drift_ratio_device_vs_legacy{key}",
                         row["drift_ratio_device_vs_legacy"], "x",
                         kind="hard", gate={"op": "le", "bound": 2.0}))
        ms.append(Metric(f"speedup_device_vs_legacy{key}",
                         row["speedup_device_vs_legacy"], "x",
                         kind="hard", gate={"op": "ge", "bound": 1.5},
                         smoke_ok=False))
        ms.append(Metric(f"device_steps_per_s{key}",
                         row["device_steps_per_s"], "steps/s"))
        ms.append(Metric(f"legacy_steps_per_s{key}",
                         row["legacy_steps_per_s"], "steps/s",
                         kind="info"))
        ms.append(Metric(f"replica_steps_per_s{key}",
                         row["replica_steps_per_s"], "steps/s"))
        ms.append(Metric(f"rebuild_interval_steps{key}",
                         row["rebuild_interval_steps"], "steps",
                         kind="info"))
    return ms


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead); skipped on smoke-sized runs like the legacy CLI did."""
    rows = record["rows"]
    worst_speed = min(r["speedup_device_vs_legacy"] for r in rows)
    worst_drift = max(r["drift_ratio_device_vs_legacy"] for r in rows)
    if worst_drift > 2.0:
        raise SystemExit(
            f"FAIL: device-lane drift {worst_drift:.2f}x legacy (> 2x) — "
            "the skin list is changing the physics")
    print(f"drift check PASS: device drift within {worst_drift:.2f}x of "
          "legacy on the same trajectory (every size/mode)")
    full64 = [r for r in rows if r["n_atoms"] >= 64]
    small = [r for r in rows if r["n_atoms"] < 64]
    if small:
        s = min(r["speedup_device_vs_legacy"] for r in small)
        print(f"host-overhead regime (< 64 atoms): device >= {s:.1f}x")
    if full64:
        s = min(r["speedup_device_vs_legacy"] for r in full64)
        if s >= 5.0:
            print(f"PASS: device-resident scan >= 5x at the 64-atom "
                  f"bucket ({s:.1f}x)")
        else:
            print(f"NOTE: device scan {s:.1f}x at a full 64-atom bucket "
                  "(the 5x target assumes host overhead dominates the "
                  "force call; with the bucket full, the forward itself "
                  "is ~3/4 of a legacy step on this 2-core CPU — the "
                  "ratio widens as the bucket empties, the forward gets "
                  "faster, or on TPU)")
    if worst_speed < 1.5:
        raise SystemExit(
            f"FAIL: device path only {worst_speed:.2f}x the legacy loop "
            "(< 1.5x) — the scan path has regressed")


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record).
    ``config.mode`` may be a '+'-joined sweep (the default suite runs
    ``fp32+w8a8`` in one process so both lanes share the molecule)."""
    args = parser().parse_args([])
    args.json = ""
    modes = [m for m in config.mode.split("+")
             if m in ("fp32", "w8a8", "w4a8")]
    if modes:
        args.modes = modes
    if config.smoke:
        apply_smoke(args)
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        mode = "+".join(args.modes)
        result = schema.ExperimentResult(
            experiment={"domain": "md", "mode": mode, "path": "sparse",
                        "replicas": 1, "devices": 1, "smoke": args.smoke},
            fingerprint=f"md:{mode}:sparse:r1:d1",
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/md_bench.py"))
        print(f"\nwrote {args.json}")
    if args.smoke:
        print("NOTE: smoke-sized run; speed/drift claims not exercised")
        return
    check(record)


if __name__ == "__main__":
    main()
