"""Online-serving benchmark: dynamic micro-batching vs per-request
dispatch, plus packed-artifact cold start (ISSUE 4).

The claim under test: under load, forming micro-batches behind a small
deadline beats serving each request as it arrives — per-request
dispatch saturates at ``1 / service_time`` while the batched engine
amortizes one compiled dispatch over up to ``max_batch`` molecules —
and the latency cost of waiting for peers is bounded by the batching
deadline. Both strategies are the *same* scheduler
(``repro.server.MicroBatchScheduler``) over the *same* engine on
identical seeded Poisson traffic; the baseline is simply
``max_batch=1, deadline_ms=0`` (flush every request immediately), so
the comparison isolates batch formation — not engine, queueing, or
measurement differences.

Method:

1. **Calibrate** — measure the per-request service time, giving the
   sequential capacity ``C = 1/t`` (req/s) a per-request server can
   sustain.
2. **Offered-load sweep** — replay Poisson traffic at multiples of C
   (default 0.6x and 3.0x: below and far above sequential capacity)
   through both strategies, recording p50/p95/p99 latency, throughput,
   queue depth, and achieved batch occupancy. Latency is measured from
   each request's *scheduled* arrival (no coordinated omission).
3. **Artifact cold start** — at deploy scale (weight-dominated model),
   time engine construction from fp32 (quantization pass) vs from the
   packed artifact (``repro.server.artifact``), and compare on-disk
   bytes vs fp32 param bytes. The W4A8 artifact must be >= 3x smaller
   (a **hard** gate in ``benchmarks.run --diff-baselines``, full-size
   runs only — compression is size-dependent).

Run:  PYTHONPATH=src python benchmarks/server_bench.py [--mode w8a8]
          [--requests 150] [--loads 0.6 3.0] [--deadline-ms 25]
          [--json BENCH_server.json] [--smoke]

Writes a ``repro.bench/1`` document (benchmarks/schema.py) so the perf
trajectory is tracked across PRs; the runner drives the same
measurement through :func:`run`. ``--smoke`` shrinks everything for CI
and skips the acceptance assertions (tracked via the committed
BENCH_server.json from the reference machine).
"""
from __future__ import annotations

import argparse
import time

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema
from benchmarks.schema import Metric


def run_strategy(engine, sched_cfg, traffic, rate):
    """One open-loop replay; returns the latency/throughput summary +
    batching + dispatch telemetry for the phase alone."""
    from repro.server import MicroBatchScheduler, run_open_loop
    engine.reset_stats()            # phase-local dispatch counters
    with MicroBatchScheduler(engine, sched_cfg) as sched:
        res = run_open_loop(sched, traffic, rate_rps=rate)
        stats = sched.stats()
    out = res.summary()
    out["submit_lag_p99_ms"] = res.submit_lag_p99_ms
    out["mean_batch"] = stats.get("mean_batch", 0.0)
    out["max_queue_depth"] = stats.get("max_queue_depth", 0)
    out["n_flushes"] = stats.get("n_flushes", 0)
    out["flush_reasons"] = stats.get("flush_reasons", {})
    out["dispatch"] = stats["engine_dispatch"]
    return out


def bench_artifact(mode, feat, vec_feat, n_layers, path):
    """Deploy-scale cold-start + size comparison for one mode."""
    import jax
    from repro.models import so3krates as so3
    from repro.serving import QuantizedEngine, ServeConfig
    from repro.serving.qparams import fp32_bytes as fp32_nbytes_of
    from repro.server import load_engine, save_artifact
    model_cfg = so3.So3kratesConfig(feat=feat, vec_feat=vec_feat,
                                    n_layers=n_layers)
    serve = ServeConfig(mode=mode, bucket_sizes=(32, 64), max_batch=16)
    params = so3.init_params(jax.random.PRNGKey(0), model_cfg)
    fp32_b = fp32_nbytes_of(params)

    # fp32 route: what every process start paid before artifacts —
    # build the engine from the fp32 tree (full quantization pass)
    t0 = time.monotonic()
    src = QuantizedEngine(model_cfg, params, serve)
    cold_fp32 = time.monotonic() - t0

    file_bytes = save_artifact(path, src)

    t0 = time.monotonic()
    load_engine(path)
    cold_art = time.monotonic() - t0
    mem = src.memory_report()
    return {
        "mode": mode,
        "feat": feat, "vec_feat": vec_feat, "n_layers": n_layers,
        "fp32_bytes": fp32_b,
        "serving_bytes": mem["served_bytes"],
        "artifact_file_bytes": file_bytes,
        "artifact_compression_x": fp32_b / file_bytes,
        "cold_start_fp32_s": cold_fp32,
        "cold_start_artifact_s": cold_art,
        "cold_start_speedup": cold_fp32 / max(cold_art, 1e-9),
    }


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w8a8",
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--loads", type=float, nargs="+", default=[0.6, 3.0],
                    help="offered load as multiples of the calibrated "
                         "sequential (per-request) capacity")
    ap.add_argument("--deadline-ms", type=float, default=25.0)
    ap.add_argument("--sched-batch", type=int, default=8)
    ap.add_argument("--buckets", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--feat", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--deploy-feat", type=int, default=128,
                    help="feat of the weight-dominated model for the "
                         "artifact size/cold-start section")
    ap.add_argument("--json", default="BENCH_server.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--artifact-path", default="/tmp/server_bench_model.npz")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: few requests, tiny deploy model, "
                         "no acceptance assertions")
    return ap


def apply_smoke(args) -> None:
    args.requests = 24
    args.loads = [1.0, 2.5]
    args.deploy_feat = 64


def collect(args) -> dict:
    """Run the full measurement; returns the domain's rich record."""
    from repro.models import so3krates as so3
    from repro.serving import QuantizedEngine, ServeConfig
    from repro.server import (MicroBatchScheduler, RateStage,
                              SchedulerConfig, SizeClass, TrafficConfig,
                              calibrate_service_time, make_step_traffic,
                              make_traffic, run_open_loop, stage_summaries)

    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=8,
                                    n_layers=args.layers, n_rbf=8,
                                    dir_bits=6, cutoff=3.0)
    serve = ServeConfig(mode=args.mode, bucket_sizes=tuple(args.buckets),
                        max_batch=max(args.sched_batch, 8))
    engine = QuantizedEngine.from_config(model_cfg, serve=serve, seed=0)
    t_warm = engine.warmup()
    engine.reset_stats()            # warmup dispatches don't belong to phases
    t_req = calibrate_service_time(engine)
    cap_rps = 1.0 / t_req
    print(f"mode={args.mode} backend={engine.backend} "
          f"buckets={args.buckets} warmup={t_warm:.1f}s")
    print(f"calibration: per-request service {t_req * 1e3:.1f} ms -> "
          f"sequential capacity {cap_rps:.1f} req/s")

    if args.buckets[0] + 1 > args.buckets[-1]:   # single-bucket ladder
        size_mix = (SizeClass(6, args.buckets[0], 1.0),)
    else:
        size_mix = (SizeClass(6, args.buckets[0], 0.5),
                    SizeClass(args.buckets[0] + 1, args.buckets[-1], 0.5))
    per_request_cfg = SchedulerConfig(max_batch=1, deadline_ms=0.0,
                                      warmup=False)
    dynamic_cfg = SchedulerConfig(max_batch=args.sched_batch,
                                  deadline_ms=args.deadline_ms,
                                  warmup=False)

    print(f"{'load':>6} {'offered':>9} {'strategy':>12} {'p50':>8} "
          f"{'p95':>8} {'p99':>8} {'thruput':>9} {'batch':>6} {'queue':>6}")
    loads = []
    for load in args.loads:
        rate = load * cap_rps
        traffic = make_traffic(TrafficConfig(
            rate_rps=rate, n_requests=args.requests, size_mix=size_mix,
            n_species=model_cfg.n_species, seed=int(load * 1000)))
        row = {"load_factor": load, "offered_rps": rate}
        for name, cfg in (("per_request", per_request_cfg),
                          ("dynamic", dynamic_cfg)):
            r = run_strategy(engine, cfg, traffic, rate)
            row[name] = r
            print(f"{load:>5.1f}x {rate:>7.1f}/s {name:>12} "
                  f"{r['p50_ms']:>7.1f}m {r['p95_ms']:>7.1f}m "
                  f"{r['p99_ms']:>7.1f}m {r['throughput_rps']:>7.1f}/s "
                  f"{r['mean_batch']:>6.2f} {r['max_queue_depth']:>6}")
        row["throughput_gain_dynamic"] = (
            row["dynamic"]["throughput_rps"]
            / row["per_request"]["throughput_rps"])
        row["p99_gain_dynamic"] = (row["per_request"]["p99_ms"]
                                   / row["dynamic"]["p99_ms"])
        loads.append(row)

    # -- step-ramp overload/recovery scenario (shared generator with
    # cluster_bench: repro.server.make_step_traffic) -----------------------
    D = max(args.requests / (4.2 * cap_rps), 0.25)
    stages = [RateStage(0.6 * cap_rps, D),    # cruise below capacity
              RateStage(3.0 * cap_rps, D),    # overload burst
              RateStage(0.6 * cap_rps, D)]    # recovery
    ramp_traffic = make_step_traffic(stages, size_mix=size_mix,
                                     n_species=model_cfg.n_species, seed=7)
    ramp = None
    if ramp_traffic:
        engine.reset_stats()
        with MicroBatchScheduler(engine, dynamic_cfg) as sched:
            ramp_res = run_open_loop(sched, ramp_traffic)
        per_stage = stage_summaries(ramp_res, stages)
        print("\nstep ramp (dynamic batching; latency attributed to the "
              "stage each request *arrived* in):")
        for st, row in zip(stages, per_stage):
            p99 = row.get("p99_ms", float("nan"))
            print(f"  {st.rate_rps:>7.1f} req/s for {st.duration_s:.2f}s: "
                  f"{row['n_offered']:>4} offered, p99 {p99:>8.1f} ms")
        ramp = {
            "stages": [{"rate_rps": st.rate_rps, "duration_s": st.duration_s}
                       for st in stages],
            "per_stage": per_stage,
            "overall": ramp_res.summary(),
        }

    print("\nartifact (deploy-scale, weight-dominated model):")
    artifacts = []
    for mode in ("w8a8", "w4a8"):
        a = bench_artifact(mode, args.deploy_feat, args.deploy_feat // 4,
                           3, args.artifact_path)
        artifacts.append(a)
        print(f"  {mode}: fp32 {a['fp32_bytes'] / 1e6:.2f} MB -> artifact "
              f"{a['artifact_file_bytes'] / 1e6:.2f} MB "
              f"({a['artifact_compression_x']:.2f}x smaller); cold start "
              f"{a['cold_start_fp32_s']:.2f}s (quantize) -> "
              f"{a['cold_start_artifact_s']:.2f}s (packed, "
              f"{a['cold_start_speedup']:.1f}x)")

    return {
        "benchmark": "server_dynamic_microbatching",
        "backend": engine.backend,
        "mode": args.mode,
        "feat": args.feat,
        "n_layers": args.layers,
        "buckets": list(args.buckets),
        "n_requests": args.requests,
        "deadline_ms": args.deadline_ms,
        "sched_batch": args.sched_batch,
        "per_request_service_ms": t_req * 1e3,
        "sequential_capacity_rps": cap_rps,
        "loads": loads,
        "ramp": ramp,
        "artifacts": artifacts,
        "smoke": args.smoke,
    }


def metrics_from_record(record: dict) -> list:
    """Normalize the rich record into gated metrics (benchmarks.schema).

    Load-sweep metric names carry the load factor, so a smoke run (which
    sweeps different factors) simply produces differently-named soft
    metrics rather than fake comparisons against full-size numbers.
    The batching claim itself — dynamic throughput must beat per-request
    at an overload factor — is a **hard** gate at > 1x.
    """
    ms = [Metric("sequential_capacity_rps",
                 record["sequential_capacity_rps"], "req/s")]
    for row in record["loads"]:
        key = f"[x{row['load_factor']:g}]"
        overloaded = row["load_factor"] >= 1.0
        ms.append(Metric(f"throughput_gain_dynamic{key}",
                         row["throughput_gain_dynamic"], "x",
                         kind="hard" if overloaded else "info",
                         gate=({"op": "ge", "bound": 1.0}
                               if overloaded else None)))
        ms.append(Metric(f"p99_gain_dynamic{key}", row["p99_gain_dynamic"],
                         "x", kind="info"))
        ms.append(Metric(f"dynamic_throughput_rps{key}",
                         row["dynamic"]["throughput_rps"], "req/s"))
        ms.append(Metric(f"dynamic_p99_ms{key}", row["dynamic"]["p99_ms"],
                         "ms", direction="lower"))
    if record.get("ramp"):
        ms.append(Metric("ramp_p99_ms", record["ramp"]["overall"]["p99_ms"],
                         "ms", direction="lower"))
    for a in record["artifacts"]:
        mode = a["mode"]
        if mode == "w4a8":
            # compression is deterministic byte accounting, but the
            # ratio depends on model size: gate it hard only at the
            # full-size deploy scale (smoke shrinks deploy_feat)
            ms.append(Metric(f"artifact_compression_x[{mode}]",
                             a["artifact_compression_x"], "x", kind="hard",
                             gate={"op": "ge", "bound": 3.0},
                             smoke_ok=False))
        else:
            ms.append(Metric(f"artifact_compression_x[{mode}]",
                             a["artifact_compression_x"], "x", kind="info"))
        ms.append(Metric(f"cold_start_speedup[{mode}]",
                         a["cold_start_speedup"], "x"))
        ms.append(Metric(f"artifact_file_bytes[{mode}]",
                         float(a["artifact_file_bytes"]), "bytes",
                         kind="info"))
    return ms


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead); skipped on smoke-sized runs like the legacy CLI did."""
    loads = record["loads"]
    artifacts = record["artifacts"]
    high = max(loads, key=lambda r: r["load_factor"])
    gain = high["throughput_gain_dynamic"]
    if gain <= 1.0:
        raise SystemExit(
            f"FAIL: dynamic batching throughput gain {gain:.2f}x <= 1 at "
            f"{high['load_factor']}x offered load — micro-batching is not "
            "paying for its batching delay")
    print(f"PASS: dynamic batching {gain:.2f}x per-request throughput at "
          f"{high['load_factor']}x sequential capacity "
          f"(p99 {high['p99_gain_dynamic']:.1f}x lower)")
    w4 = next(a for a in artifacts if a["mode"] == "w4a8")
    if w4["artifact_compression_x"] < 3.0:
        raise SystemExit(
            f"FAIL: w4a8 artifact only {w4['artifact_compression_x']:.2f}x "
            "smaller than fp32 (< 3x)")
    print(f"PASS: w4a8 packed artifact {w4['artifact_compression_x']:.2f}x "
          "smaller than the fp32 params")


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.mode in ("fp32", "w8a8", "w4a8"):
        args.mode = config.mode
    if config.smoke:
        apply_smoke(args)
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        result = schema.ExperimentResult(
            experiment={"domain": "server", "mode": args.mode,
                        "path": "auto", "replicas": 1, "devices": 1,
                        "smoke": args.smoke},
            fingerprint=f"server:{args.mode}:auto:r1:d1",
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/server_bench.py"))
        print(f"\nwrote {args.json}")
    if args.smoke:
        print("NOTE: smoke-sized run; acceptance claims not exercised")
        return
    check(record)


if __name__ == "__main__":
    main()
