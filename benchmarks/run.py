"""Benchmark harness entrypoint: one section per paper table/figure plus the
roofline report. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--section tables|roofline|kernels]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "tables", "roofline", "kernels"])
    args = ap.parse_args()
    from benchmarks import paper_tables, roofline, kernel_bench
    if args.section in ("all", "tables"):
        paper_tables.main()
    if args.section in ("all", "roofline"):
        roofline.main()
    if args.section in ("all", "kernels"):
        kernel_bench.main()


if __name__ == '__main__':
    main()
