"""Unified experiment runner with regression gates (ROADMAP item 5).

Enumerates experiment configs (domain x mode x path x replicas/devices,
see ``benchmarks.experiments``), runs each in a subprocess with its own
environment (XLA device counts must be committed before jax imports —
this is what lets one invocation bench 1-device serving *and* the
4-forced-host-device cluster), collects everything into one
``repro.bench/1`` document (``benchmarks.schema``), and optionally
diffs it against the committed ``BENCH_baselines.json`` with
core-count-aware tolerance gates: hard gates (drift ratio, LEE,
zero-drop/zero-loss counts, byte accounting) fail the run on any
machine at any size; soft perf gates (throughput, latency, speedup)
apply a relative band and only compare on matching core counts.

    # CI: smoke-size every domain, enforce the hard gates
    PYTHONPATH=src python -m benchmarks.run --smoke --diff-baselines

    # full suite on the reference machine, refresh the committed docs
    PYTHONPATH=src python -m benchmarks.run --write-domain-docs
    PYTHONPATH=src python -m benchmarks.run --refresh-baselines

    # re-gate an existing results document without rerunning anything
    PYTHONPATH=src python -m benchmarks.run --diff-only --results out.json

Exit codes: 0 clean, 1 an experiment crashed, 2 a regression gate
failed. See docs/experiments.md for axes, schema, and gate policy.
The legacy paper-table / roofline analysis sections remain available
via ``--section tables|roofline|kernels``.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks import experiments, schema


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="unified experiment runner with regression gates")
    ap.add_argument("--domains", nargs="+",
                    choices=sorted(experiments.DOMAINS),
                    help="subset of domains (default: all five)")
    ap.add_argument("--modes", nargs="+",
                    choices=["fp32", "w8a8", "w4a8"],
                    help="expand the quantization-mode axis")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized runs (soft perf gates are skipped; "
                         "hard correctness gates still apply)")
    ap.add_argument("--out", default="BENCH_experiments.json",
                    help="combined results document path")
    ap.add_argument("--work-dir", default="/tmp/repro_experiments",
                    help="scratch dir for per-experiment config/result JSON")
    ap.add_argument("--timeout-s", type=float, default=3600.0,
                    help="per-experiment subprocess timeout")
    ap.add_argument("--diff-baselines", action="store_true",
                    help="gate the results against --baselines; exit 2 "
                         "on regression")
    ap.add_argument("--baselines", default=experiments.BASELINES_PATH)
    ap.add_argument("--refresh-baselines", action="store_true",
                    help="derive --baselines from the committed per-domain "
                         "BENCH_*.json documents and exit")
    ap.add_argument("--write-domain-docs", action="store_true",
                    help="after a full (non-smoke) run, rewrite each "
                         "domain's committed BENCH_*.json from the results")
    ap.add_argument("--list", action="store_true",
                    help="print the enumerated configs and exit")
    ap.add_argument("--extra", default=None,
                    help="JSON dict of bench-arg overrides applied to every "
                         "config (tests use this to shrink below smoke size)")
    # re-gate an existing document without running anything
    ap.add_argument("--diff-only", action="store_true",
                    help="load --results and gate it against --baselines")
    ap.add_argument("--results", default=None,
                    help="results document for --diff-only")
    # internal: the subprocess-isolated child entrypoint
    ap.add_argument("--run-one", metavar="CONFIG_JSON", default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--result-out", metavar="RESULT_JSON", default=None,
                    help=argparse.SUPPRESS)
    # legacy analysis sections (paper tables / roofline CSV harness)
    ap.add_argument("--section", default=None,
                    choices=["tables", "roofline", "kernels"],
                    help="legacy analysis sections; kernels now also runs "
                         "as a domain of the experiment runner")
    return ap


def _diff(doc, args, expected=None) -> int:
    baselines = schema.load_baselines(args.baselines)
    report = schema.diff_against_baselines(doc, baselines,
                                           expected_fingerprints=expected)
    print(f"\n-- regression gates vs {args.baselines} --")
    print(report.render())
    if not report.ok:
        print("REGRESSION: one or more gates failed", file=sys.stderr)
        return 2
    print("all gates clean")
    return 0


def main(argv=None) -> int:
    args = _parser().parse_args(argv)

    if args.section:
        # legacy CSV harness sections, untouched by the runner
        if args.section == "tables":
            from benchmarks import paper_tables
            paper_tables.main()
        elif args.section == "roofline":
            from benchmarks import roofline
            roofline.main()
        else:
            from benchmarks import kernel_bench
            kernel_bench.main([])
        return 0

    if args.run_one:
        # child process: env (devices, threads) already committed by the
        # parent; run exactly one config and write its result
        with open(args.run_one) as f:
            config = experiments.ExperimentConfig.from_json(json.load(f))
        result = experiments.run_config_inprocess(config)
        with open(args.result_out, "w") as f:
            json.dump(result.to_json(), f, indent=2)
        return 0

    if args.refresh_baselines:
        baselines = experiments.refresh_baselines(args.domains)
        with open(args.baselines, "w") as f:
            json.dump(baselines, f, indent=2)
        n = sum(len(e["metrics"]) for e in baselines["gates"].values())
        print(f"wrote {args.baselines}: {len(baselines['gates'])} "
              f"experiments, {n} gated metrics")
        return 0

    if args.diff_only:
        if not args.results:
            print("--diff-only needs --results", file=sys.stderr)
            return 1
        doc = schema.load_document(args.results)
        return _diff(doc, args,
                     expected=[r["fingerprint"] for r in doc["results"]])

    extra = json.loads(args.extra) if args.extra else None
    configs = experiments.enumerate_experiments(
        domains=args.domains, modes=args.modes, smoke=args.smoke,
        extra=extra)
    if args.list:
        for c in configs:
            print(f"{c.fingerprint}  devices={c.devices} smoke={c.smoke}")
        return 0

    try:
        doc = experiments.run_suite(configs, args.work_dir, args.timeout_s)
    except experiments.ExperimentFailed as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    schema.write_document(args.out, doc)
    print(f"\nwrote {args.out} ({len(doc['results'])} experiments)")

    if args.write_domain_docs:
        if args.smoke:
            print("refusing --write-domain-docs on a --smoke run: the "
                  "committed documents are full-size reference numbers",
                  file=sys.stderr)
            return 1
        by_domain = {}
        for r in doc["results"]:
            by_domain.setdefault(r["experiment"]["domain"], []).append(r)
        for domain, results in by_domain.items():
            path = experiments.domain_document_path(domain)
            schema.write_document(path, {
                "schema": schema.SCHEMA_VERSION,
                "generated_by": experiments.DOMAINS[domain]["module"],
                "results": results})
            print(f"wrote {path}")

    if args.diff_baselines:
        return _diff(doc, args, expected=[c.fingerprint for c in configs])
    return 0


if __name__ == "__main__":
    sys.exit(main())
