"""Experiment enumeration + subprocess isolation for the unified runner.

The runner's job (ROADMAP item 5, in the shape of
``Liyang90/xla``'s ``experiment_runner.py``): enumerate experiment
configs over the repo's axes —

    domain:   serving | md | server | cluster | kernels | sessions
              | guardrails | obs
    mode:     fp32 | w8a8 | w4a8 (or a "+"-joined sweep run in-script)
    path:     dense | sparse | auto | dense+sparse
    replicas: replica-ladder ceiling (cluster)
    devices:  JAX device count the experiment needs

— run each config in its **own subprocess** with its own environment,
and collect every result into one ``repro.bench/1`` document
(:mod:`benchmarks.schema`).

Subprocess isolation is not hygiene theater: ``XLA_FLAGS
--xla_force_host_platform_device_count`` must be set *before* the
process imports jax, so benching a 1-device serving config and a
4-forced-device cluster config in one invocation is only possible if
each runs in a fresh interpreter. It also means one experiment's
compilation cache, thread pool, or crash cannot leak into the next.

Each domain's bench script exposes ``run(config) ->
(list[Metric], record)`` (keeping its standalone CLI); the registry
below maps domains to those modules and to the committed per-domain
BENCH documents that ``--refresh-baselines`` derives the gate table
from.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

from benchmarks import schema

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# domain -> (bench module, committed per-domain document)
DOMAINS: Dict[str, Dict[str, str]] = {
    "serving": {"module": "benchmarks.serving_bench",
                "document": "BENCH_serving.json"},
    "md": {"module": "benchmarks.md_bench", "document": "BENCH_md.json"},
    "server": {"module": "benchmarks.server_bench",
               "document": "BENCH_server.json"},
    "cluster": {"module": "benchmarks.cluster_bench",
                "document": "BENCH_cluster.json"},
    "kernels": {"module": "benchmarks.kernel_bench",
                "document": "BENCH_kernels.json"},
    "sessions": {"module": "benchmarks.sessions_bench",
                 "document": "BENCH_sessions.json"},
    "guardrails": {"module": "benchmarks.guardrails_bench",
                   "document": "BENCH_guardrails.json"},
    "obs": {"module": "benchmarks.obs_bench",
            "document": "BENCH_obs.json"},
}
DOMAIN_ORDER = ("serving", "md", "server", "cluster", "kernels",
                "sessions", "guardrails", "obs")

BASELINES_PATH = "BENCH_baselines.json"


@dataclasses.dataclass
class ExperimentConfig:
    """One cell of the experiment grid.

    ``extra`` holds per-run overrides of the bench script's CLI defaults
    (e.g. ``{"requests": 10}``) — used by tests to shrink runs below
    even smoke size. It is deliberately excluded from the fingerprint:
    the fingerprint identifies *what* is measured, smoke/extra say *how
    small* the measurement is, and smoke-size hard gates must still find
    their full-size baseline entry.
    """
    domain: str
    mode: str = "w8a8"
    path: str = "-"
    replicas: int = 1
    devices: int = 1
    smoke: bool = False
    extra: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.domain not in DOMAINS:
            raise ValueError(f"unknown domain {self.domain!r} "
                             f"(have {sorted(DOMAINS)})")

    @property
    def fingerprint(self) -> str:
        return (f"{self.domain}:{self.mode}:{self.path}"
                f":r{self.replicas}:d{self.devices}")

    def to_json(self) -> Dict:
        return {"domain": self.domain, "mode": self.mode, "path": self.path,
                "replicas": self.replicas, "devices": self.devices,
                "smoke": self.smoke, "extra": dict(self.extra)}

    @classmethod
    def from_json(cls, d: Dict) -> "ExperimentConfig":
        return cls(domain=d["domain"], mode=d.get("mode", "w8a8"),
                   path=d.get("path", "-"),
                   replicas=int(d.get("replicas", 1)),
                   devices=int(d.get("devices", 1)),
                   smoke=bool(d.get("smoke", False)),
                   extra=dict(d.get("extra", {})))

    def env(self) -> Dict[str, str]:
        """Child-process environment: device count forced before jax can
        initialize, thread counts pinned so runs are comparable."""
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        flags.append(
            f"--xla_force_host_platform_device_count={self.devices}")
        env["XLA_FLAGS"] = " ".join(flags)
        n = str(os.cpu_count() or 1)
        for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                    "MKL_NUM_THREADS"):
            env.setdefault(var, n)
        src = os.path.join(REPO_ROOT, "src")
        env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src)
        return env


def enumerate_experiments(domains: Optional[Sequence[str]] = None,
                          modes: Optional[Sequence[str]] = None,
                          smoke: bool = False,
                          extra: Optional[Dict] = None
                          ) -> List[ExperimentConfig]:
    """The default experiment suite: one config per (domain, mode) cell.

    Without ``--modes`` this is exactly the committed-baseline suite —
    the eight domains at their reference configurations (serving runs
    dense+sparse internally, md sweeps fp32+w8a8, cluster runs the
    1/2/4 replica ladder on 4 forced host devices, sessions runs the
    fault-schedule trajectory on a 2-replica pool, guardrails runs the
    poison/stall/drift chaos suite on 4 forced host devices, obs runs
    the traced chaos replay + overhead A/B on the same 4-device
    layout). ``modes`` expands the quantization axis for the per-mode
    domains.
    """
    domains = list(domains) if domains else list(DOMAIN_ORDER)
    unknown = [d for d in domains if d not in DOMAINS]
    if unknown:
        raise ValueError(f"unknown domain(s) {unknown} "
                         f"(have {sorted(DOMAINS)})")
    extra = dict(extra or {})
    out: List[ExperimentConfig] = []
    for d in domains:
        if d == "serving":
            for m in (modes or ["w8a8"]):
                out.append(ExperimentConfig(d, m, "dense+sparse",
                                            smoke=smoke, extra=extra))
        elif d == "md":
            mode = "+".join(modes) if modes else "fp32+w8a8"
            out.append(ExperimentConfig(d, mode, "sparse", smoke=smoke,
                                        extra=extra))
        elif d == "server":
            for m in (modes or ["w8a8"]):
                out.append(ExperimentConfig(d, m, "auto", smoke=smoke,
                                            extra=extra))
        elif d == "cluster":
            for m in (modes or ["w8a8"]):
                out.append(ExperimentConfig(d, m, "auto", replicas=4,
                                            devices=4, smoke=smoke,
                                            extra=extra))
        elif d == "kernels":
            out.append(ExperimentConfig(d, "-", "-", smoke=smoke,
                                        extra=extra))
        elif d == "sessions":
            for m in (modes or ["w8a8"]):
                out.append(ExperimentConfig(d, m, "sparse", replicas=2,
                                            devices=2, smoke=smoke,
                                            extra=extra))
        elif d == "guardrails":
            # w4a8 primary tier (escalates to w8a8); poison needs the
            # dense path — see benchmarks/guardrails_bench.py
            for m in (modes or ["w4a8"]):
                out.append(ExperimentConfig(d, m, "dense", replicas=4,
                                            devices=4, smoke=smoke,
                                            extra=extra))
        elif d == "obs":
            # chaos tracing on a 4-replica mixed-tier pool; w4a8
            # primary so poison escalates — see benchmarks/obs_bench.py
            for m in (modes or ["w4a8"]):
                out.append(ExperimentConfig(d, m, "dense", replicas=4,
                                            devices=4, smoke=smoke,
                                            extra=extra))
    return out


# -- in-process execution (runs inside the isolated child) -------------------

def run_config_inprocess(config: ExperimentConfig) -> schema.ExperimentResult:
    """Import the domain module and run it — called from the child
    process the runner spawned (``benchmarks.run --run-one``), where the
    environment (XLA device count, thread pins) is already committed."""
    module = importlib.import_module(DOMAINS[config.domain]["module"])
    t0 = time.monotonic()
    metrics, record = module.run(config)
    return schema.ExperimentResult(
        experiment=config.to_json(),
        fingerprint=config.fingerprint,
        hardware=schema.hardware_context(),
        metrics=list(metrics),
        duration_s=time.monotonic() - t0,
        detail=record)


# -- subprocess orchestration ------------------------------------------------

class ExperimentFailed(RuntimeError):
    pass


def run_experiment(config: ExperimentConfig, work_dir: str,
                   timeout_s: float = 3600.0) -> schema.ExperimentResult:
    """Run one config in a fresh interpreter with its own env; stream
    the child's output; return its result."""
    os.makedirs(work_dir, exist_ok=True)
    tag = config.fingerprint.replace(":", "_").replace("+", "-")
    cfg_path = os.path.join(work_dir, f"{tag}.config.json")
    res_path = os.path.join(work_dir, f"{tag}.result.json")
    with open(cfg_path, "w") as f:
        json.dump(config.to_json(), f)
    cmd = [sys.executable, "-m", "benchmarks.run",
           "--run-one", cfg_path, "--result-out", res_path]
    print(f"\n=== [{config.fingerprint}] devices={config.devices} "
          f"smoke={config.smoke} ===", flush=True)
    proc = subprocess.run(cmd, env=config.env(), cwd=REPO_ROOT,
                          timeout=timeout_s)
    if proc.returncode != 0:
        raise ExperimentFailed(
            f"experiment {config.fingerprint} exited "
            f"{proc.returncode} (see output above)")
    with open(res_path) as f:
        return schema.ExperimentResult.from_json(json.load(f))


def run_suite(configs: Sequence[ExperimentConfig], work_dir: str,
              timeout_s: float = 3600.0) -> Dict:
    """Run every config subprocess-isolated, in order, and collect one
    schema-valid document. A config that crashes fails the suite — a
    bench that cannot run is a regression, not a gap in the report."""
    results = [run_experiment(c, work_dir, timeout_s) for c in configs]
    doc = schema.bench_document(results, generated_by="benchmarks.run")
    schema.validate_document(doc)
    return doc


# -- baselines ---------------------------------------------------------------

def domain_document_path(domain: str, root: str = REPO_ROOT) -> str:
    return os.path.join(root, DOMAINS[domain]["document"])


def refresh_baselines(domains: Optional[Sequence[str]] = None,
                      root: str = REPO_ROOT) -> Dict:
    """Derive the gate table from the committed per-domain documents.

    Workflow (docs/experiments.md): regenerate the per-domain
    BENCH_*.json on the reference machine (standalone bench CLIs or
    ``benchmarks.run --write-domain-docs``), eyeball the numbers, then
    run this and commit both."""
    domains = list(domains) if domains else list(DOMAIN_ORDER)
    docs = []
    for d in domains:
        path = domain_document_path(d, root)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"no committed document for domain {d!r} at {path}; run "
                f"the {d} bench first")
        docs.append(schema.load_document(path))
    return schema.baselines_from_documents(
        docs, source=[DOMAINS[d]["document"] for d in domains])
