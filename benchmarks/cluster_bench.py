"""Cluster benchmark: replica-count scaling, hot swap, failover (ISSUE 5).

The claim under test: once micro-batching has taken the single engine to
its ceiling (BENCH_server.json), the way to keep scaling is replicas —
``repro.cluster`` fans identical replayed traffic out across N
device-pinned engines behind the shape-aware JSQ router, and sustained
throughput under overload grows with N (>= 2x at 4 replicas vs 1 on the
reference container) while failure and weight rollout stay invisible to
clients.

Scenarios (all on seeded traffic, identical across replica counts):

1. **Scaling curve** — calibrate the single-replica sequential service
   time, then replay the same heavily-overloaded open-loop stream
   (default 6x the sequential capacity) at 1/2/4 replicas: sustained
   throughput, p50/p99 latency, routing balance.
2. **Step ramp** (shared generator with server_bench:
   ``repro.server.make_step_traffic``) — cruise / overload burst /
   recovery at the max replica count, per-stage latency attribution.
3. **Hot swap** — mid-replay rolling ``swap_artifact`` to a second set
   of weights: zero dropped/erroring requests required, per-replica
   pause times and version mix recorded.
4. **Failover** — mid-replay ``kill_replica(mode="in_flight")``: zero
   lost requests required, requeue counts recorded.

The zero-drop / zero-loss counts are **hard** regression gates in
``benchmarks.run --diff-baselines`` (they hold at any size, so they
gate smoke runs too); the scaling speedup gates hard only on full-size
runs, at the core-count-scaled bound recorded in the baseline.

On CPU the devices are simulated (``--xla_force_host_platform_device_
count``, set automatically before jax import unless already present in
XLA_FLAGS). Throughput scaling on CPU comes from overlapping per-replica
host work and XLA execution across cores, and is therefore **bounded by
the core count**: all simulated devices share one XLA CPU executor
pool, so a machine with C cores can show at most ~C/1.4x (measured;
the single-replica baseline already keeps ~1.4 threads busy between
productive work and executor spin — see docs/cluster.md). The >= 2x
acceptance gate is enforced where the hardware can express it
(>= 4 cores, or real TPU devices); on smaller containers the gate
scales down (>= 1.25x on 2 cores: replica scaling must be real, the
ceiling just sits lower) and the JSON records the core count next to
the curve so the number is never read out of context.

Run:  PYTHONPATH=src python benchmarks/cluster_bench.py
          [--replicas 1 2 4] [--requests 240] [--load 6.0]
          [--json BENCH_cluster.json] [--smoke]

Writes a ``repro.bench/1`` document (benchmarks/schema.py); the runner
drives the same measurement through :func:`run`. ``--smoke`` shrinks
everything for CI and skips the acceptance assertions (tracked via the
committed BENCH_cluster.json from the reference container).
"""
from __future__ import annotations

import argparse
import os
import threading
import time

# devices must be forced before jax initializes; on TPU this flag only
# affects the (unused) host platform and is harmless. Under
# ``benchmarks.run`` the parent already committed the count into the
# child environment, so this is a no-op there.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import jax          # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema                                  # noqa: E402
from benchmarks.schema import Metric                           # noqa: E402
from repro.models import so3krates as so3                      # noqa: E402
from repro.serving import QuantizedEngine, ServeConfig         # noqa: E402
from repro.server import (RateStage, SizeClass,                # noqa: E402
                          calibrate_service_time, draw_graphs,
                          make_step_traffic, make_traffic, run_open_loop,
                          save_artifact, stage_summaries, TrafficConfig)
from repro.cluster import ClusterConfig, ClusterPool           # noqa: E402


def make_pool(model_cfg, qparams, fp32_nbytes, serve, n, args,
              max_queue=None):
    cluster = ClusterConfig(n_replicas=n, max_batch=args.sched_batch,
                            deadline_ms=args.deadline_ms,
                            max_queue=max_queue)
    return ClusterPool.from_quantized(model_cfg, qparams, serve, cluster,
                                      fp32_nbytes=fp32_nbytes)


def replay(pool, traffic, rate=None):
    pool.reset_stats()
    res = run_open_loop(pool, traffic, rate_rps=rate)
    stats = pool.stats()
    out = res.summary()
    out["mean_batch"] = stats.get("mean_batch", 0.0)
    out["max_queue_depth"] = stats.get("max_queue_depth", 0)
    out["n_flushes"] = stats.get("n_flushes", 0)
    out["routed_per_replica"] = stats["router"]["routed_per_replica"]
    out["n_requeued"] = stats["router"]["n_requeued"]
    out["dispatch"] = stats["engine_dispatch"]
    return out, res


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w8a8",
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--replicas", type=int, nargs="+", default=[1, 2, 4],
                    help="replica counts for the scaling curve")
    ap.add_argument("--requests", type=int, default=360,
                    help="requests in the scaling-curve replay")
    ap.add_argument("--load", type=float, default=12.0,
                    help="offered load as a multiple of single-replica "
                         "*sequential* capacity — must exceed the largest "
                         "pool's *batched* capacity (~ sched_batch x "
                         "n_replicas x parallel speedup / batch "
                         "amortization), so every pool is saturated and "
                         "the measured throughput is its drain rate")
    ap.add_argument("--deadline-ms", type=float, default=25.0)
    ap.add_argument("--sched-batch", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3,
                    help="replays per scaling row (best is kept: the "
                         "2-core reference container is noisy)")
    ap.add_argument("--buckets", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--feat", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--json", default="BENCH_cluster.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--artifact-dir", default="/tmp/cluster_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: few requests, 2-replica ceiling, "
                         "no acceptance assertions")
    return ap


def apply_smoke(args) -> None:
    args.requests = 60
    args.replicas = [1, 2]


def collect(args) -> dict:
    """Run the full measurement; returns the domain's rich record."""
    n_dev = len(jax.devices())
    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=8,
                                    n_layers=args.layers, n_rbf=8,
                                    dir_bits=6, cutoff=3.0)
    serve = ServeConfig(mode=args.mode, bucket_sizes=tuple(args.buckets),
                        max_batch=max(args.sched_batch, 8))
    base = QuantizedEngine.from_config(model_cfg, serve=serve, seed=0)
    fp32_nbytes = base.memory_report()["fp32_bytes"]
    t_warm = base.warmup()
    t_req = calibrate_service_time(base, args.buckets)
    cap_rps = 1.0 / t_req
    rate = args.load * cap_rps
    print(f"mode={args.mode} backend={jax.default_backend()} "
          f"devices={n_dev} buckets={args.buckets} warmup={t_warm:.1f}s")
    print(f"calibration: per-request service {t_req * 1e3:.1f} ms -> "
          f"sequential capacity {cap_rps:.1f} req/s; offered "
          f"{rate:.1f} req/s ({args.load}x)")

    size_mix = (SizeClass(6, args.buckets[0], 0.5),
                SizeClass(args.buckets[0] + 1, args.buckets[-1], 0.5))
    traffic = make_traffic(TrafficConfig(
        rate_rps=rate, n_requests=args.requests, size_mix=size_mix,
        n_species=model_cfg.n_species, seed=42))

    # 1. scaling curve: identical replay at each replica count ------------
    print(f"\n{'repl':>5} {'thruput':>9} {'p50':>8} {'p99':>8} "
          f"{'batch':>6} {'routed/replica'}")
    scaling = []
    for n in args.replicas:
        pool = make_pool(model_cfg, base.qparams, fp32_nbytes, serve, n,
                         args)
        with pool:
            row = None
            for _ in range(args.reps):       # best-of-reps: noisy container
                r, _ = replay(pool, traffic, rate)
                if row is None or r["throughput_rps"] > row["throughput_rps"]:
                    row = r
        row = {"n_replicas": n, "offered_rps": rate, "reps": args.reps,
               **row}
        scaling.append(row)
        print(f"{n:>5} {row['throughput_rps']:>7.1f}/s "
              f"{row['p50_ms']:>7.1f}m {row['p99_ms']:>7.1f}m "
              f"{row['mean_batch']:>6.2f} {row['routed_per_replica']}")
    thr = {r["n_replicas"]: r["throughput_rps"] for r in scaling}
    n_max = max(thr)
    speedup = thr[n_max] / thr[min(thr)]
    n_cores = os.cpu_count() or 1
    print(f"scaling: {speedup:.2f}x sustained throughput at {n_max} "
          f"replicas vs {min(thr)} ({n_cores} cores)")

    # 2. step ramp at max replicas: overload burst + recovery -------------
    D = max(args.requests / (6.0 * cap_rps), 0.5)
    n_ramp = max(args.replicas)
    stages = [RateStage(0.5 * n_ramp * cap_rps, D),
              RateStage(2.5 * n_ramp * cap_rps, D),
              RateStage(0.5 * n_ramp * cap_rps, D)]
    ramp_traffic = make_step_traffic(stages, size_mix=size_mix,
                                     n_species=model_cfg.n_species, seed=7)
    pool = make_pool(model_cfg, base.qparams, fp32_nbytes, serve, n_ramp,
                     args)
    with pool:
        pool.reset_stats()
        ramp_res = run_open_loop(pool, ramp_traffic)
    ramp_rows = stage_summaries(ramp_res, stages)
    print(f"\nstep ramp at {n_ramp} replicas:")
    for st, row in zip(stages, ramp_rows):
        print(f"  {st.rate_rps:>7.1f} req/s for {st.duration_s:.2f}s: "
              f"{row['n_offered']:>4} offered, "
              f"p99 {row.get('p99_ms', float('nan')):>8.1f} ms")
    ramp = {"n_replicas": n_ramp,
            "stages": [{"rate_rps": s.rate_rps, "duration_s": s.duration_s}
                       for s in stages],
            "per_stage": ramp_rows, "overall": ramp_res.summary()}

    # 3. hot swap under traffic: zero drops required ----------------------
    # a rolling swap warms each new engine before exchanging it, which on
    # CPU takes many seconds per replica — so instead of a fixed-length
    # replay (which would end before the swap touches anything), seeded
    # Poisson traffic keeps flowing until the swap completed and a tail
    # of post-swap requests has been served
    os.makedirs(args.artifact_dir, exist_ok=True)
    p1 = os.path.join(args.artifact_dir, "w_v1.npz")
    p2 = os.path.join(args.artifact_dir, "w_v2.npz")
    save_artifact(p1, base)
    save_artifact(p2, QuantizedEngine.from_config(model_cfg, serve=serve,
                                                  seed=99))
    n_swap = max(args.replicas)
    swap_rate = 0.6 * n_swap * cap_rps          # sustainable: isolate swap
    pool = ClusterPool.from_artifact(
        p1, serve=serve,
        cluster=ClusterConfig(n_replicas=n_swap,
                              max_batch=args.sched_batch,
                              deadline_ms=args.deadline_ms))
    v1_tag = pool._replicas[0].engine.artifact_version
    swap_report = {}
    swap_done = threading.Event()
    rng = np.random.default_rng(43)

    def next_graph():
        # the same weighted size-mix recipe every other scenario's
        # traffic is drawn from (repro.server.traffic.draw_graphs)
        return draw_graphs(rng, 1, size_mix, model_cfg.n_species,
                           density=0.1)[0]

    with pool:
        pool.reset_stats()

        def do_swap():
            # a swap failure must surface as the scenario's failure, not
            # vanish into this thread's excepthook / a later KeyError
            try:
                swap_report.update(pool.swap_artifact(p2))
            except BaseException as e:
                swap_report["error"] = e
            finally:
                swap_done.set()
        swap_thread = threading.Timer(1.0, do_swap)
        swap_thread.daemon = True
        swap_thread.start()
        handles = []
        t0 = time.monotonic()
        tail_until = None
        while tail_until is None or time.monotonic() < tail_until:
            handles.append(pool.submit(next_graph()))
            time.sleep(rng.exponential(1.0 / swap_rate))
            if swap_done.is_set() and tail_until is None:
                tail_until = time.monotonic() + 1.0   # post-swap tail
        span = time.monotonic() - t0
        # result() re-raises any per-request error: reaching the stats
        # line below means zero requests dropped or errored
        results = [h.result(timeout=600) for h in handles]
    if swap_report.get("error") is not None:
        raise SystemExit(f"FAIL: hot swap raised {swap_report['error']!r} "
                         "(traffic was unaffected, but the rollout failed)")
    v2_tag = swap_report["version_tag"]
    versions = {}
    for r in results:
        versions[r.artifact_version] = versions.get(r.artifact_version,
                                                    0) + 1
    lat = np.asarray([h.latency_s for h in handles])
    pauses = [r["pause_s"] for r in swap_report.get("replicas", [])]
    hot_swap = {
        "n_replicas": n_swap, "offered_rps": swap_rate,
        "n_offered": len(handles), "n_completed": len(results),
        "n_shed": 0, "n_dropped": len(handles) - len(results),
        "n_errors": 0,
        "span_s": span,
        "version_tag": v2_tag,
        "served_per_version": {v1_tag: versions.get(v1_tag, 0),
                               v2_tag: versions.get(v2_tag, 0)},
        "pause_s_per_replica": pauses,
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
    }
    dropped = hot_swap["n_dropped"]
    print(f"\nhot swap at {n_swap} replicas over {span:.1f}s: "
          f"{len(results)}/{len(handles)} completed, {dropped} dropped, "
          f"versions {hot_swap['served_per_version']}, serve pauses "
          f"{[f'{p * 1e3:.2f}ms' for p in pauses]}")

    # 4. failover: kill one replica mid-replay, zero loss required --------
    n_kill = max(args.replicas)
    kill_rate = 0.4 * n_kill * cap_rps   # survivors must absorb the load
    kill_traffic = make_traffic(TrafficConfig(
        rate_rps=kill_rate, n_requests=args.requests, size_mix=size_mix,
        n_species=model_cfg.n_species, seed=44))
    pool = make_pool(model_cfg, base.qparams, fp32_nbytes, serve, n_kill,
                     args)
    with pool:
        pool.reset_stats()
        half = kill_traffic[len(kill_traffic) // 2][0]
        # kill the smallest bucket's *home* replica: at sub-capacity load
        # the affinity router concentrates each shape class on its home,
        # so victim 0 is guaranteed to be serving when the kill lands
        victim = 0
        timer = threading.Timer(
            half, lambda: pool.kill_replica(victim, mode="in_flight"))
        timer.daemon = True
        timer.start()
        # result_timeout: a leaked handle (the bug class this scenario
        # exists to catch) must fail loudly, not hang the bench/CI
        kill_res = run_open_loop(pool, kill_traffic, rate_rps=kill_rate,
                                 result_timeout=300)
        kill_stats = pool.stats()
    completed_k = int(kill_res.summary()["n_requests"])
    failover = {
        "n_replicas": n_kill, "offered_rps": kill_rate,
        "victim": victim,
        "n_offered": len(kill_traffic), "n_completed": completed_k,
        "n_shed": kill_res.n_shed,
        "n_lost": len(kill_traffic) - completed_k - kill_res.n_shed,
        "n_requeued": kill_stats["router"]["n_requeued"],
        "n_live_after": kill_stats["n_live"],
        "p99_ms": kill_res.summary()["p99_ms"],
    }
    print(f"failover: killed replica {victim} in flight, "
          f"{completed_k}/{len(kill_traffic)} completed, "
          f"{failover['n_requeued']} requeued, "
          f"{failover['n_live_after']}/{n_kill} replicas live")

    # the >=2x gate where the hardware can express it; on small CPU
    # containers every simulated device shares one XLA executor pool, so
    # the gate scales with the core budget (module docstring, docs/
    # cluster.md) — the JSON always records both numbers
    speedup_required = 2.0 if n_cores >= 4 else 1.25
    scaling_note = (
        f"{n_cores}-core container: all simulated devices share one XLA "
        f"CPU executor; measured machine ceiling ~1.4 useful cores for "
        f"the single-replica baseline. The 2x gate applies at >=4 cores "
        f"/ real devices; here the gate is {speedup_required}x.")

    return {
        "benchmark": "cluster_replica_scaling",
        "backend": jax.default_backend(),
        "n_devices": n_dev,
        "n_cores": n_cores,
        "mode": args.mode,
        "feat": args.feat,
        "n_layers": args.layers,
        "buckets": list(args.buckets),
        "n_requests": args.requests,
        "deadline_ms": args.deadline_ms,
        "sched_batch": args.sched_batch,
        "load_factor": args.load,
        "per_request_service_ms": t_req * 1e3,
        "sequential_capacity_rps": cap_rps,
        "scaling": scaling,
        "speedup_max_vs_1": speedup,
        "speedup_required": speedup_required,
        "scaling_note": scaling_note,
        "ramp": ramp,
        "hot_swap": hot_swap,
        "failover": failover,
        "smoke": args.smoke,
    }


def metrics_from_record(record: dict) -> list:
    """Normalize the rich record into gated metrics (benchmarks.schema).

    Hot-swap drops/errors and failover losses are **hard** zero-count
    gates and hold at any run size, so they gate smoke runs too. The
    scaling speedup gates hard at the core-count-scaled bound the bench
    itself computed, but only off smoke (``smoke_ok=False``): a smoke
    run stops its replica ladder at 2, so its "max vs 1" is a different
    measurement — and its metric name ``[r2]`` keeps it from ever being
    compared to the full-size ``[r4]`` baseline anyway."""
    ms = []
    for row in record["scaling"]:
        n = row["n_replicas"]
        ms.append(Metric(f"throughput_rps[r{n}]", row["throughput_rps"],
                         "req/s"))
        ms.append(Metric(f"p99_ms[r{n}]", row["p99_ms"], "ms",
                         direction="lower"))
    n_max = max(r["n_replicas"] for r in record["scaling"])
    ms.append(Metric(f"speedup_max_vs_1[r{n_max}]",
                     record["speedup_max_vs_1"], "x", kind="hard",
                     gate={"op": "ge",
                           "bound": record["speedup_required"]},
                     smoke_ok=False))
    ms.append(Metric("ramp_p99_ms", record["ramp"]["overall"]["p99_ms"],
                     "ms", direction="lower"))
    hs, fo = record["hot_swap"], record["failover"]
    ms.append(Metric("hot_swap_dropped", float(hs["n_dropped"]), "count",
                     kind="hard", gate={"op": "eq", "bound": 0.0}))
    ms.append(Metric("hot_swap_errors", float(hs["n_errors"]), "count",
                     kind="hard", gate={"op": "eq", "bound": 0.0}))
    ms.append(Metric("hot_swap_pause_max_s",
                     float(max(hs["pause_s_per_replica"] or [0.0])), "s",
                     direction="lower"))
    ms.append(Metric("failover_lost", float(fo["n_lost"]), "count",
                     kind="hard", gate={"op": "eq", "bound": 0.0}))
    # n_live_after < n_replicas proves the kill actually landed while
    # serving — a scenario that kills nothing gates nothing
    ms.append(Metric("failover_kill_engaged",
                     1.0 if fo["n_live_after"] < fo["n_replicas"] else 0.0,
                     "bool", kind="hard", gate={"op": "eq", "bound": 1.0}))
    ms.append(Metric("failover_requeued", float(fo["n_requeued"]), "count",
                     kind="info"))
    return ms


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead); skipped on smoke-sized runs like the legacy CLI did."""
    speedup = record["speedup_max_vs_1"]
    speedup_required = record["speedup_required"]
    n_cores = record["n_cores"]
    n_max = max(r["n_replicas"] for r in record["scaling"])
    hs, fo = record["hot_swap"], record["failover"]
    fails = []
    if speedup < speedup_required:
        fails.append(
            f"{n_max}-replica throughput only {speedup:.2f}x the "
            f"1-replica throughput (< {speedup_required}x gate for "
            f"{n_cores} cores)")
    if hs["n_dropped"] != 0 or hs["n_errors"] != 0:
        fails.append(f"hot swap dropped {hs['n_dropped']} requests / "
                     f"{hs['n_errors']} errors (must be 0)")
    if fo["n_lost"] != 0:
        fails.append(f"failover lost {fo['n_lost']} requests "
                     "(must be 0)")
    if fo["n_live_after"] == fo["n_replicas"]:
        fails.append("failover kill never engaged (victim replica served "
                     "no flush after the kill) — scenario did not test "
                     "anything")
    if fails:
        raise SystemExit("FAIL: " + "; ".join(fails))
    print(f"PASS: {speedup:.2f}x sustained throughput at {n_max} "
          f"replicas (gate {speedup_required}x on {n_cores} cores), hot "
          "swap and failover with zero lost requests")
    if n_cores < 4:
        print("NOTE: " + record["scaling_note"])


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.mode in ("fp32", "w8a8", "w4a8"):
        args.mode = config.mode
    if config.smoke:
        apply_smoke(args)
    elif config.replicas > 1:
        # full run: replica ladder up to the declared ceiling
        args.replicas = [n for n in (1, 2, 4, 8)
                         if n <= config.replicas] or [config.replicas]
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        r_max = max(args.replicas)
        result = schema.ExperimentResult(
            experiment={"domain": "cluster", "mode": args.mode,
                        "path": "auto", "replicas": r_max,
                        "devices": len(jax.devices()),
                        "smoke": args.smoke},
            fingerprint=(f"cluster:{args.mode}:auto:r{r_max}"
                         f":d{len(jax.devices())}"),
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/cluster_bench.py"))
        print(f"\nwrote {args.json}")
    if args.smoke:
        print("NOTE: smoke-sized run; acceptance claims not exercised")
        return
    check(record)


if __name__ == "__main__":
    main()
