"""Benchmarks reproducing the paper's tables/figures from pipeline artifacts.

Each function prints ``name,us_per_call,derived`` CSV rows (harness contract)
plus a human-readable table. Artifacts come from
``python -m repro.training.pipeline`` (artifacts/so3/metrics.json); if absent,
a --fast pipeline run is triggered first.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "so3")
METRICS = os.path.join(ART, "metrics.json")


def _metrics() -> dict:
    if not os.path.exists(METRICS):
        print("# no artifacts found -> running fast pipeline", file=sys.stderr)
        subprocess.run([sys.executable, "-m", "repro.training.pipeline",
                        "--fast"], check=True,
                       env=dict(os.environ, PYTHONPATH="src"))
    return json.load(open(METRICS))


def _mev(m: dict, x: float) -> float:
    return x * m["units"]["e_scale_eV"] * 1000.0


def table1_complexity():
    """Paper Table I: per-layer asymptotic cost with/without quantization.
    Analytic (the table is analytic in the paper too), plus our measured
    model-byte ratios as the constant-factor evidence."""
    rows = [
        ("PaiNN", "O(n<N>4F)", 1),
        ("SpookyNet", "O(n<N>(l+1)^2 F)", 2),
        ("NequIP", "O(n<N>(l+1)^6 F)", 3),
        ("So3krates(ours)", "O(n<N>((l+1)^2+F))", 1),
    ]
    m = _metrics()
    lat = m["latency"]
    rho8 = lat["model_bytes_w8"] / lat["model_bytes_fp32"]
    rho4 = lat["model_bytes_w4"] / lat["model_bytes_fp32"]
    print("# Table I: complexity (analytic) + measured constant factors")
    for name, cost, lmax in rows:
        print(f"#   {name:18s} C_full={cost:22s} l_max={lmax} "
              f"C_quant=C_full*rho_k")
    print(f"#   measured rho_8={rho8:.3f} (theory 0.25), "
          f"rho_4={rho4:.3f} (theory 0.125)")
    print(f"table1_rho8,{rho8:.4f},theory=0.25")
    print(f"table1_rho4,{rho4:.4f},theory=0.125")


def table2_accuracy():
    """Paper Table II: E-MAE / F-MAE per method on azobenzene(synthetic)."""
    m = _metrics()
    print("# Table II: accuracy (meV / meV/A), azobenzene-like synthetic")
    print("# method            bits   E-MAE    F-MAE    stable")
    order = [("fp32", "32/32"), ("naive_int8", "8/8"),
             ("svq_kmeans", "8/8"), ("degree_quant", "8/8"),
             ("gaq_w4a8", "4/8")]
    for name, bits in order:
        d = m[name]
        stable = "diverged" if d.get("diverged") else "stable"
        e, f = _mev(m, d["e_mae"]), _mev(m, d["f_mae"])
        print(f"#  {name:16s} {bits:6s} {e:8.2f} {f:8.2f}  {stable}")
        print(f"table2_{name}_emae_mev,{e:.3f},f_mae_mev={f:.3f}")
    gaq, fp = _mev(m, m["gaq_w4a8"]["e_mae"]), _mev(m, m["fp32"]["e_mae"])
    print(f"table2_gaq_vs_fp32,{gaq / max(fp, 1e-9):.3f},"
          f"paper_claims_gaq_matches_fp32")


def table3_lee():
    """Paper Table III: Local Equivariance Error per method."""
    m = _metrics()
    print("# Table III: LEE (meV/A equivalent, force-norm units)")
    for name in ["fp32", "naive_int8", "degree_quant", "gaq_w4a8"]:
        lee = _mev(m, m[name]["lee"])
        print(f"#  {name:16s} LEE={lee:10.4f}")
        print(f"table3_{name}_lee,{lee:.4f},")
    if "lee_dir16" in m["gaq_w4a8"]:
        lee16 = _mev(m, m["gaq_w4a8"]["lee_dir16"])
        print(f"#  gaq_w4a8(dir16) LEE={lee16:10.4f}  (same ckpt, eval-time "
              f"16-bit codebook)")
        print(f"table3_gaq_dir16_lee,{lee16:.4f},")
        ratio = m["naive_int8"]["lee"] / max(m["gaq_w4a8"]["lee_dir16"], 1e-12)
    else:
        ratio = m["naive_int8"]["lee"] / max(m["gaq_w4a8"]["lee"], 1e-12)
    print(f"#  naive/GAQ ratio = {ratio:.1f}x (paper: >30x; directional "
          f"resolution is the lever, see DESIGN.md §8)")
    print(f"table3_naive_over_gaq,{ratio:.2f},paper_claims_over_30x")


def table4_memory_wall():
    """Paper Table IV: latency/memory breakdown — CPU bandwidth-multiplier
    microbenchmark (weight-I/O row) + model footprints."""
    m = _metrics()
    lat = m["latency"]
    io32, io8, io4 = (lat["weight_io_fp32_us"], lat["weight_io_int8_us"],
                      lat["weight_io_int4_us"])
    print("# Table IV: memory-wall breakdown (CPU analogue of paper's 4090)")
    print(f"#  weight I/O  fp32 {io32:10.1f} us   int8 {io8:10.1f} us "
          f"({io32 / io8:.2f}x)   int4 {io4:10.1f} us ({io32 / io4:.2f}x)")
    print(f"#  gemv (compute, same across precisions): {lat['gemv_us']:.1f} us")
    print(f"#  quant overhead (unfused CPU dequant): "
          f"{lat['quant_overhead_us']:.1f} us -> fused in TPU Pallas kernel")
    print(f"table4_weight_io_speedup_int8,{io32 / io8:.3f},paper=4.0x")
    print(f"table4_weight_io_speedup_int4,{io32 / io4:.3f},theory=8x")
    print(f"table4_model_mem_ratio_w4a8,"
          f"{lat['model_bytes_fp32'] / lat['model_bytes_w4']:.2f},paper=4x")


def fig3_nve():
    """Paper Fig. 3: NVE stability (energy drift / explosion)."""
    m = _metrics()
    print("# Fig 3: NVE dynamics stability")
    for name in ["fp32", "gaq_w4a8", "naive_int8"]:
        d = m[name].get("nve")
        if not d:
            continue
        drift = d["drift_ev_per_atom_ps"] * 1000
        print(f"#  {name:12s} drift={drift:12.4f} meV/atom/ps "
              f"blew_up={d['blew_up']} ({d['n_steps']} steps @ {d['dt_fs']}fs)")
        print(f"fig3_{name}_drift,{drift:.4f},blew_up={d['blew_up']}")
    print("# Fig 3 supplementary: 100 K (regime where the CPU-scale fp32 "
          "model is itself stable)")
    for name in ["fp32", "gaq_w4a8", "naive_int8"]:
        for key in ("nve_100k", "nve_100k_dir14", "nve_100k_dir16"):
            d = m[name].get(key)
            if not d:
                continue
            drift = d["drift_ev_per_atom_ps"] * 1000
            print(f"#  {name:12s}[{key}] drift={drift:12.4f} meV/atom/ps "
                  f"blew_up={d['blew_up']} e_range={d.get('e_range', -1):.2f} eV")
            print(f"fig3_{name}_{key},{drift:.4f},blew_up={d['blew_up']}")


def main():
    table1_complexity()
    table2_accuracy()
    table3_lee()
    table4_memory_wall()
    fig3_nve()


if __name__ == "__main__":
    main()
