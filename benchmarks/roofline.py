"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh (256 chips):
    compute term    = FLOPs / (chips * 197 TFLOP/s)
    memory term     = HBM bytes / (chips * 819 GB/s)
    collective term = per-chip collective bytes / 50 GB/s
FLOPs and HBM bytes are the analytic implementation costs (launch/costs.py;
XLA's cost_analysis undercounts scan bodies — both raw and analytic are in
the artifacts). Collective bytes come from the compiled HLO with while-loop
trip expansion (launch/hlo_analysis.py); SPMD HLO shapes are per-chip, so
the term divides by one link's bandwidth (equivalent to the global
convention chips*link_bw with global = per-chip * chips).

Roofline fraction = T_ideal / T_bound, where T_ideal = MODEL_FLOPS /
(chips * peak) and T_bound = max(three terms): "how close would this program
be to the hardware's best possible time for the useful math".
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

CHIPS = 256
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: str = "single", tag: str = "") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}*.json"))):
        rec = json.load(open(path))
        if rec.get("tag", "") != tag or rec["mesh"] != mesh:
            continue
        out.append(rec)
    return out


def terms(rec: dict) -> dict:
    flops = rec["analytic_flops"]
    hbm = rec["analytic_hbm_bytes"]["total"]
    coll = sum(rec["collective_bytes"].values())
    t_c = flops / (CHIPS * PEAK_FLOPS)
    t_m = hbm / (CHIPS * HBM_BW)
    t_n = coll / LINK_BW
    t_bound = max(t_c, t_m, t_n)
    dom = {t_c: "compute", t_m: "memory", t_n: "collective"}[t_bound]
    t_ideal = rec["model_flops"] / (CHIPS * PEAK_FLOPS)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom, "bound_s": t_bound,
        "useful_ratio": rec["model_flops"] / max(flops, 1),
        "roofline_fraction": t_ideal / max(t_bound, 1e-30),
        "hbm_split": rec["analytic_hbm_bytes"],
    }


_FIX_HINTS = {
    ("compute",): "cut implementation overhead (causal block-skip in "
                  "attention, sparser MoE dispatch) or quantize compute",
    ("memory",): "quantize weights/KV (W4A8 + int8 cache) to shrink the "
                 "dominant HBM stream",
    ("collective",): "reshard to cut per-layer collectives (sequence-shard "
                     "norms, overlap TP all-reduces, int8 gradient "
                     "all-reduce)",
}


def render_table(mesh: str = "single", tag: str = "") -> str:
    rows = []
    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | useful | roofline frac |")
    rows.append(hdr)
    rows.append("|" + "---|" * 8)
    for rec in load_cells(mesh, tag):
        t = terms(rec)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main():
    print("# Roofline terms per (arch x shape), single-pod 16x16 (256 chips)")
    recs = load_cells("single")
    if not recs:
        print("# no dry-run artifacts; run python -m repro.launch.dryrun --all")
        return
    for rec in recs:
        t = terms(rec)
        name = f"{rec['arch']}__{rec['shape']}"
        if rec.get("quant_mode", "none") != "none" or rec.get("kv_quant"):
            name += f"__{rec['quant_mode']}" + ("_kv8" if rec["kv_quant"] else "")
        print(f"roofline_{name},{t['bound_s'] * 1e6:.2f},"
              f"dom={t['dominant']};frac={t['roofline_fraction']:.3f};"
              f"useful={t['useful_ratio']:.2f}")
    print()
    print(render_table("single"))


if __name__ == "__main__":
    main()
