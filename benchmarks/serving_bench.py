"""Throughput benchmark: batched-bucketed engine vs per-molecule dispatch.

The claim under test (ISSUE 1 / ROADMAP batching): padding variable-size
molecular graphs into MXU-aligned shape classes and pushing them through
ONE quantized forward per bucket beats dispatching molecules one at a
time — on the same hardware, with the identical kernels. Per-molecule
dispatch still pays the full 128-row alignment cost per call (a 10-atom
molecule occupies a 128-row kernel launch alone), so batching amortizes
exactly the padding the MXU contract forces on us.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--mode w8a8]
          [--graphs 16] [--buckets 16 32] [--repeats 3]

Prints a per-bucket table of molecules/s for both strategies and the
speedup. CPU runs use the kernels' interpret fallback; on TPU the same
script exercises the compiled path.
"""
from __future__ import annotations

import argparse
import statistics
import time

import numpy as np

from repro.models import so3krates as so3
from repro.serving import QuantizedEngine, ServeConfig, random_graphs


def time_strategy(engine: QuantizedEngine, graphs, batched: bool,
                  repeats: int) -> float:
    """Median wall-clock seconds for one full pass over the graphs."""
    def run():
        if batched:
            engine.infer_batch(graphs)
        else:
            for g in graphs:
                engine.infer_batch([g])

    run()  # warm: compiles every shape class this strategy will use
    times = []
    for _ in range(repeats):
        t0 = time.time()
        run()
        times.append(time.time() - t0)
    return statistics.median(times)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w8a8",
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--graphs", type=int, default=16)
    ap.add_argument("--min-atoms", type=int, default=6)
    ap.add_argument("--buckets", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    if min(args.buckets) < args.min_atoms:
        ap.error(f"--buckets must all be >= --min-atoms ({args.min_atoms}); "
                 f"got {sorted(args.buckets)}")

    model_cfg = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2,
                                    n_rbf=8, dir_bits=6)

    print(f"mode={args.mode} graphs={args.graphs} repeats={args.repeats} "
          f"(median)")
    print(f"{'bucket':>7} {'batched mol/s':>14} {'per-mol mol/s':>14} "
          f"{'speedup':>8}")
    speedups = []
    for cap in args.buckets:
        serve = ServeConfig(mode=args.mode, bucket_sizes=(cap,),
                            max_batch=args.max_batch)
        engine = QuantizedEngine.from_config(model_cfg, serve=serve)
        graphs = random_graphs(args.graphs, args.min_atoms, cap,
                               model_cfg.n_species, seed=cap)
        t_batched = time_strategy(engine, graphs, batched=True,
                                  repeats=args.repeats)
        t_permol = time_strategy(engine, graphs, batched=False,
                                 repeats=args.repeats)
        n = len(graphs)
        speedup = t_permol / t_batched
        speedups.append(speedup)
        print(f"{cap:>7} {n / t_batched:>14.2f} {n / t_permol:>14.2f} "
              f"{speedup:>7.2f}x")

    geo = float(np.exp(np.mean(np.log(speedups))))
    print(f"\nbatched-bucketed vs per-molecule dispatch: "
          f"geomean speedup {geo:.2f}x over {len(speedups)} bucket sizes")
    if geo <= 1.0:
        raise SystemExit("FAIL: batching did not beat per-molecule dispatch")
    print("PASS: batched-bucketed inference beats per-molecule dispatch")


if __name__ == "__main__":
    main()
