"""Serving throughput benchmark: dense O(n^2) vs sparse O(E) edge-list path.

The claim under test (ISSUE 2 / the paper's memory-traffic argument): once
molecules are large enough that the cutoff graph is sparse, gathering edge
features and reducing with a segment softmax beats materializing
(B, n, n, .) pairwise tensors — on the same hardware, with the identical
quantized matmul kernels. Small dense molecules still favor the dense
path; the benchmark reports the crossover capacity.

Graphs are drawn at constant density (atoms per A^3), the physical regime
for molecules: the average degree is size-independent, so dense work grows
as n^2 while sparse work grows as n.

The bench also records the Local Equivariance Error of the *served*
quantized engine on seeded traffic (``QuantizedEngine.lee_diagnostic``)
— the paper's correctness metric — as a **hard** regression gate:
throughput may wobble with the machine, the LEE of a deterministic
seeded batch may not.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--mode w8a8]
          [--buckets 16 32 64 128] [--graphs 8] [--repeats 3]
          [--density 0.1] [--cutoff 3.0] [--json BENCH_serving.json]
          [--smoke]

Prints a per-bucket table of molecules/s for both paths and writes a
``repro.bench/1`` document (benchmarks/schema.py) so the perf
trajectory is tracked across PRs and gated by ``benchmarks.run
--diff-baselines``. The runner drives the same measurement through
:func:`run`. CPU runs use the kernels' interpret fallback for the
matmuls and XLA segment ops for the edge softmax; on TPU the same
script exercises the compiled kernels.
"""
from __future__ import annotations

import argparse
import statistics
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema
from benchmarks.schema import Metric
from repro.models import so3krates as so3
from repro.serving import (QuantizedEngine, ServeConfig,
                           default_edge_capacity, random_graphs)


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w8a8",
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--graphs", type=int, default=8)
    ap.add_argument("--buckets", type=int, nargs="+",
                    default=[16, 32, 64, 128])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--density", type=float, default=0.1,
                    help="atoms per cubic Angstrom (0.1 ~ condensed phase)")
    ap.add_argument("--cutoff", type=float, default=3.0)
    ap.add_argument("--feat", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small buckets only, one repeat; "
                         "the crossover claim is not exercised")
    return ap


def apply_smoke(args) -> None:
    args.buckets = [16, 32]
    args.graphs = 4
    args.repeats = 1


def time_engine(engine: QuantizedEngine, graphs, repeats: int) -> float:
    """Median wall-clock seconds for one full pass over the graphs."""
    engine.infer_batch(graphs)   # warm: compiles this traffic's shapes
    times = []
    for _ in range(repeats):
        t0 = time.time()
        engine.infer_batch(graphs)
        times.append(time.time() - t0)
    return statistics.median(times)


def bench_bucket(model_cfg, mode, cap, n_graphs, max_batch, density,
                 repeats, seed):
    graphs = random_graphs(n_graphs, max(6, cap // 2), cap,
                           model_cfg.n_species, seed=seed, density=density)
    out = {"capacity": cap, "edge_capacity": default_edge_capacity(cap),
           "n_graphs": n_graphs,
           "mean_atoms": float(np.mean([g.n_atoms for g in graphs]))}
    for path in ("dense", "sparse"):
        serve = ServeConfig(mode=mode, bucket_sizes=(cap,),
                            max_batch=max_batch, path=path)
        engine = QuantizedEngine.from_config(model_cfg, serve=serve)
        t = time_engine(engine, graphs, repeats)
        out[f"{path}_mol_per_s"] = n_graphs / t
        out[f"{path}_seconds"] = t
        if path == "sparse":
            # a fallback batch ran DENSE inside the "sparse" engine: its
            # timing would compare dense to dense, so flag the row and
            # exclude it from the crossover computation
            out["sparse_fallbacks"] = engine.dispatch_stats[
                "sparse_fallback"]
            out["sparse_pure"] = out["sparse_fallbacks"] == 0
    out["speedup_sparse_vs_dense"] = (out["dense_seconds"]
                                      / out["sparse_seconds"])
    return out


def lee_section(model_cfg, mode, *, cap=16, n_graphs=4, n_rotations=2,
                seed=5):
    """LEE of the served quantized model on a fixed seeded batch — the
    deterministic correctness metric the hard gate pins. Same seeds
    everywhere, so the number is comparable across machines and PRs."""
    import jax
    serve = ServeConfig(mode=mode, bucket_sizes=(cap,), max_batch=8,
                        path="sparse")
    engine = QuantizedEngine.from_config(model_cfg, serve=serve, seed=0)
    graphs = random_graphs(n_graphs, 6, 12, model_cfg.n_species, seed=seed,
                           density=0.1)
    diag = engine.lee_diagnostic(graphs, jax.random.PRNGKey(0),
                                 n_rotations=n_rotations)
    return {"mode": mode, "bucket": cap, "seed": seed, **diag}


def collect(args) -> dict:
    """Run the full measurement; returns the domain's rich record."""
    import jax
    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=8,
                                    n_layers=args.layers, n_rbf=8,
                                    dir_bits=6, cutoff=args.cutoff)

    print(f"mode={args.mode} graphs={args.graphs} repeats={args.repeats} "
          f"density={args.density} cutoff={args.cutoff} (median)")
    print(f"{'bucket':>7} {'edges':>6} {'dense mol/s':>12} "
          f"{'sparse mol/s':>13} {'speedup':>8}")
    rows = []
    for cap in args.buckets:
        row = bench_bucket(model_cfg, args.mode, cap, args.graphs,
                           args.max_batch, args.density, args.repeats,
                           seed=cap)
        rows.append(row)
        note = "" if row["sparse_pure"] else \
            f"  ({row['sparse_fallbacks']} dense fallbacks!)"
        print(f"{cap:>7} {row['edge_capacity']:>6} "
              f"{row['dense_mol_per_s']:>12.2f} "
              f"{row['sparse_mol_per_s']:>13.2f} "
              f"{row['speedup_sparse_vs_dense']:>7.2f}x{note}")

    # only rows that actually ran the edge-list path count as evidence,
    # and the crossover is the capacity from which sparse wins *onward*
    # (a noise win at one small bucket is not a crossover)
    pure = [r for r in rows if r["sparse_pure"]]
    crossover = next(
        (r["capacity"] for i, r in enumerate(pure)
         if all(p["speedup_sparse_vs_dense"] > 1.0 for p in pure[i:])),
        None)
    geo = (float(np.exp(np.mean(np.log(
        [r["speedup_sparse_vs_dense"] for r in pure])))) if pure else None)
    lee = lee_section(model_cfg, args.mode)
    print(f"LEE (served {args.mode}, seeded batch): "
          f"mean {lee['lee_mean']:.3e}  max {lee['lee_max']:.3e}")
    return {
        "benchmark": "serving_dense_vs_sparse",
        "mode": args.mode,
        "density": args.density,
        "cutoff": args.cutoff,
        "feat": args.feat,
        "n_layers": args.layers,
        "repeats": args.repeats,
        "backend": jax.default_backend(),
        "buckets": rows,
        "crossover_capacity": crossover,
        "geomean_speedup": geo,
        "lee": lee,
        "smoke": bool(getattr(args, "smoke", False)),
    }


def metrics_from_record(record: dict) -> list:
    """Normalize the rich record into gated metrics (benchmarks.schema).
    Also applied unchanged to the legacy committed record during the
    one-time schema migration, so converted and fresh documents agree."""
    ms = []
    for row in record["buckets"]:
        cap = row["capacity"]
        ms.append(Metric(f"mol_per_s[b{cap}].dense", row["dense_mol_per_s"],
                         "mol/s"))
        ms.append(Metric(f"mol_per_s[b{cap}].sparse",
                         row["sparse_mol_per_s"], "mol/s"))
        ms.append(Metric(f"speedup_sparse_vs_dense[b{cap}]",
                         row["speedup_sparse_vs_dense"], "x", kind="info"))
    # a fallback-polluted row means the sparse path silently stopped
    # being exercised — that is a correctness regression of the bench
    ms.append(Metric("sparse_fallbacks_total",
                     float(sum(r.get("sparse_fallbacks", 0)
                               for r in record["buckets"])),
                     "count", kind="hard", gate={"op": "eq", "bound": 0.0}))
    if record.get("geomean_speedup") is not None:
        ms.append(Metric("geomean_speedup_sparse", record["geomean_speedup"],
                         "x"))
    if record.get("crossover_capacity") is not None:
        ms.append(Metric("crossover_capacity",
                         float(record["crossover_capacity"]), "atoms",
                         kind="info"))
    lee = record.get("lee")
    if lee is not None:
        ms.append(Metric("lee_mean", lee["lee_mean"], "force-norm",
                         kind="hard",
                         gate={"op": "le", "bound": 2.0 * lee["lee_mean"]}))
        ms.append(Metric("lee_max", lee["lee_max"], "force-norm",
                         kind="info"))
    return ms


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead). The claim under test is "sparse wins at n >= 64"; it is
    only testable when a >= 64-atom bucket was actually benchmarked, so
    smoke-size runs (small buckets only) report instead of failing."""
    rows = record["buckets"]
    pure = [r for r in rows if r["sparse_pure"]]
    crossover = record["crossover_capacity"]
    if crossover is not None:
        print(f"sparse beats dense from bucket capacity {crossover} up "
              f"(geomean speedup {record['geomean_speedup']:.2f}x over "
              f"{len(pure)} fallback-free buckets)")
    caps_64 = [r for r in rows if r["capacity"] >= 64]
    if not caps_64:
        print("NOTE: no bucket >= 64 atoms benchmarked; the "
              "sparse-vs-dense claim was not exercised (smoke run)")
    elif all(r["sparse_pure"] and r["speedup_sparse_vs_dense"] > 1.0
             for r in caps_64):
        print("PASS: sparse edge-list path wins at n >= 64 atoms")
    else:
        raise SystemExit("FAIL: sparse path did not beat dense at "
                         ">= 64 atoms")


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.mode in ("fp32", "w8a8", "w4a8"):
        args.mode = config.mode
    if config.smoke:
        apply_smoke(args)
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        result = schema.ExperimentResult(
            experiment={"domain": "serving", "mode": args.mode,
                        "path": "dense+sparse", "replicas": 1, "devices": 1,
                        "smoke": args.smoke},
            fingerprint=f"serving:{args.mode}:dense+sparse:r1:d1",
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/serving_bench.py"))
        print(f"\nwrote {args.json}")
    if not args.smoke:
        check(record)


if __name__ == "__main__":
    main()
