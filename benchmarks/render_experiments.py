"""Render EXPERIMENTS.md from artifacts (dry-run JSONs + pipeline metrics).

PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import CHIPS, HBM_BW, LINK_BW, PEAK_FLOPS, load_cells, terms

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts")


def _cell(arch, shape, mesh="single", tag=""):
    name = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    path = os.path.join(ART, "dryrun", name + ".json")
    return json.load(open(path)) if os.path.exists(path) else None


def dryrun_section(out):
    out.append("## §Dry-run\n")
    out.append(
        "Every (architecture × input-shape) cell is lowered AND compiled "
        "(`jax.jit(...).lower(...).compile()`) for the single-pod 16×16 mesh "
        "(256 chips) and the multi-pod 2×16×16 mesh (512 chips) with 512 "
        "placeholder host devices. `long_500k` runs only for the "
        "sub-quadratic archs (zamba2, xlstm) per the shape spec — 32 cells "
        "× 2 meshes = 64 compilations, all passing (see dryrun_sweep.log).\n")
    out.append("| arch | shape | mesh | devices | kind | compile s | "
               "HLO flops* | collective bytes/chip | peak mem/chip† |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for mesh in ("single", "multi"):
        for rec in load_cells(mesh):
            coll = sum(rec["collective_bytes"].values())
            peak = rec["memory"].get("temp_bytes", -1) / rec["n_devices"]
            out.append(
                f"| {rec['arch']} | {rec['shape']} | {mesh} | "
                f"{rec['n_devices']} | {rec['kind']} | {rec['compile_s']} | "
                f"{rec['flops']:.2e} | {coll:.2e} | {peak/1e9:.2f} GB |")
    out.append("")
    out.append(
        "\\* XLA's `cost_analysis()` counts while-loop bodies once, so HLO "
        "flops undercount scan-stacked layers; the roofline below uses the "
        "analytic implementation costs (`launch/costs.py`) instead, and "
        "collective bytes come from the compiled HLO with while-trip "
        "expansion (`launch/hlo_analysis.py`).  † temp-buffer bytes reported "
        "by `memory_analysis()` divided across devices; per-chip peaks are "
        "well inside the 16 GB v5e HBM for every cell.\n")


def roofline_section(out):
    out.append("## §Roofline\n")
    out.append(
        f"Hardware model: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16, "
        f"{HBM_BW/1e9:.0f} GB/s HBM per chip, {LINK_BW/1e9:.0f} GB/s/link "
        f"ICI; {CHIPS} chips (single pod).\n\n"
        "- compute term = analytic FLOPs / (chips × peak)\n"
        "- memory term = analytic HBM bytes / (chips × HBM bw)\n"
        "- collective term = per-chip collective bytes (compiled HLO, "
        "while-trips expanded, result-size convention) / link bw\n"
        "- useful = MODEL_FLOPS / implementation FLOPs, MODEL_FLOPS = 6·N·D "
        "(train) or 2·N_active·D (inference)\n"
        "- roofline fraction = [MODEL_FLOPS / (chips × peak)] / max(terms) — "
        "the score we hillclimb.\n\n"
        "CPU-backend caveat: XLA CPU promotes bf16 dots to f32, so the "
        "collective bytes of bf16 activation traffic are inflated ≤2× vs a "
        "TPU lowering; the true TPU collective term lies in [0.5×, 1×] of "
        "the reported value (gradient/optimizer collectives are genuinely "
        "f32). Dominance calls below are unchanged in every cell except "
        "llama/musicgen train, where compute and the corrected collective "
        "term are within 2× of each other.\n")
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | useful | roofline frac | what moves the dominant "
               "term |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    hints = {
        ("train", "collective"): "TP act all-reduces: context-parallel + "
                                 "FSDP weight storage (§Perf cell 1)",
        ("train", "compute"): "causal block-skip in attention (2x), MoE "
                              "sort-based dispatch",
        ("prefill", "collective"): "same CP resharding as train",
        ("decode", "memory"): "W4A8 weights + int4 KV cache (§Perf cell 3)",
        ("decode", "collective"): "decode act-AR in bf16; KV-head "
                                  "replication to TP width (cell 2/3)",
        ("prefill", "compute"): "causal block-skip in attention",
        ("prefill", "memory"): "quantized weight streaming",
        ("train", "memory"): "quantized weight streaming",
    }
    for rec in load_cells("single"):
        t = terms(rec)
        hint = hints.get((rec["kind"], t["dominant"]), "")
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_fraction']:.3f} | {hint} |")
    out.append("")


def _fmt_terms(rec):
    t = terms(rec)
    return (f"compute {t['compute_s']*1e3:.2f} ms / memory "
            f"{t['memory_s']*1e3:.2f} ms / collective "
            f"{t['collective_s']*1e3:.2f} ms → dominant {t['dominant']}, "
            f"fraction {t['roofline_fraction']:.3f}")


def perf_section(out):
    out.append("## §Perf — hillclimb log (hypothesis → change → before → "
               "after → verdict)\n")
    out.append(
        "Three cells chosen per spec: most collective-bound class "
        "(llama3.2-3b × train_4k), worst roofline fraction with a concrete "
        "pathology (xlstm-1.3b × decode_32k), and the cell most "
        "representative of the paper's technique (qwen1.5-110b × decode_32k "
        "— the memory-wall, attacked with the paper's quantization). The "
        "paper-faithful baseline and each beyond-paper step are recorded "
        "separately.\n")

    # cell 1
    base = _cell("llama3.2-3b", "train_4k")
    cp = _cell("llama3.2-3b", "train_4k", tag="cp")
    out.append("### Cell 1: llama3.2-3b × train_4k (collective-bound)\n")
    out.append(f"Baseline (Megatron TP16 × DP16): {_fmt_terms(base)}; "
               f"collective bytes/chip {sum(base['collective_bytes'].values()):.3e}.\n")
    rows = [
        ("1", "Pin batch to data axes at block boundaries "
              "(`act_sharding=dp`)", "GSPMD loses batch sharding between "
              "blocks, causing resharding",
         "no change (3.633e11 B) — GSPMD already propagated batch; "
         "**refuted**"),
        ("2", "Full FSDP: weights + batch over all 256 chips",
         "per-layer bf16 weight gathers (~210 MB) ≪ activation all-reduces "
         "(~5.6 GB/layer)",
         "3.7× WORSE (1.376e12 B): GSPMD hoists whole-stack gathers out of "
         "the scan (2.6 GB/op) and B_local=1 wrecks attention propagation; "
         "**refuted**"),
        ("3", "ZeRO-3 over the model axis (weights sharded on contracting "
              "dim, batch on data)",
         "weight gathers replace TP partial-sum all-reduces",
         "1.8× worse (6.518e11 B): GSPMD chooses partial-sums over gathers "
         "for contracting-dim shards; **refuted**"),
        ("4", "bf16 rmsnorm statistics (`--norm-bf16`)",
         "f32 upcast pairs with the partial-sum all-reduce, doubling bytes",
         "no change — the f32 collectives are the CPU backend promoting "
         "bf16 dots; on TPU these are bf16 (documented ≤2× inflation); "
         "**refuted as a code-level fix, confirmed as an accounting "
         "artifact**"),
        ("5", "Context parallelism: sequence over 'model' between blocks + "
              "FSDP weight storage over 'data' (`--policy cp "
              "--act-sharding dp_sp`)",
         "MLPs become collective-free (seq-local), attention pays one K/V "
         "gather (~268 MB) ≪ act all-reduce (~1.6 GB/layer)",
         f"**confirmed**: 3.633e11 → {sum(cp['collective_bytes'].values()):.3e} "
         f"B/chip (2.19×); bytes_accessed also 1.9× lower; "
         f"{_fmt_terms(cp)}"),
        ("6", "Constrain grads to param shardings (force reduce-scatter)",
         "grad all-reduce over data should be RS (ZeRO-2)",
         "no change — GSPMD had already inferred it; **no-op**"),
    ]
    out.append("| # | change | hypothesis | result |")
    out.append("|---|---|---|---|")
    for r in rows:
        out.append(f"| {r[0]} | {r[1]} | {r[2]} | {r[3]} |")
    out.append(
        "\nStop: last two iterations <5% on the dominant term. Best: "
        "**2.19× collective reduction** (paper-faithful baseline kept "
        "separately). Remaining gap is weight-gather + grad-reduce traffic "
        "inherent to 3.6B params × 1M tokens on 256 chips; next lever is a "
        "pipeline axis, out of scope for this mesh.\n")

    # cell 2
    base = _cell("xlstm-1.3b", "decode_32k")
    rep = _cell("xlstm-1.3b", "decode_32k", tag="staterep")
    dv = _cell("xlstm-1.3b", "decode_32k", tag="dvshard")
    out.append("### Cell 2: xlstm-1.3b × decode_32k (worst fraction, "
               "pathological collective)\n")
    out.append(
        f"Baseline: {_fmt_terms(base)}; collective "
        f"{sum(base['collective_bytes'].values()):.3e} B/chip per decoded "
        "token — SPMD emitted 'involuntary full rematerialization' "
        "collective-permutes of the mLSTM matrix state every step (state "
        "sharded on d_k, which the per-step read contracts over).\n")
    out.append("| # | change | hypothesis | result |")
    out.append("|---|---|---|---|")
    out.append(
        f"| 1 | replicate mLSTM state over 'model' | permutes vanish, "
        f"706 MB/chip state is affordable | "
        f"{sum(rep['collective_bytes'].values()):.3e} B (1.24× WORSE): "
        f"state writes (k⊗v outer products) are TP-sharded and must be "
        f"all-reduced to a replicated state; **refuted** |")
    out.append(
        f"| 2 | split the normalizer out of the augmented value dim and "
        f"shard the state on d_v (aligned with column-parallel wv / "
        f"row-parallel down) | both the per-step write (k⊗v) and read "
        f"(q·S) become chip-local | **confirmed**: "
        f"{sum(base['collective_bytes'].values()):.3e} → "
        f"{sum(dv['collective_bytes'].values()):.3e} B/chip (**49×**), "
        f"bytes_accessed 8.4× lower; {_fmt_terms(dv)} |")
    out.append(
        "\nThe dv-sharded layout is now the default (`sharding.py`); decode "
        "is memory/collective-balanced at ~0.3 ms bound — further gains "
        "need larger per-chip batch (the cell is latency-floor-bound, "
        "2ND/chip ≈ 6 μs of math).\n")

    # cell 3
    base = _cell("qwen1.5-110b", "decode_32k")
    w8 = _cell("qwen1.5-110b", "decode_32k", tag="w8a8kv8")
    w4 = _cell("qwen1.5-110b", "decode_32k", tag="w4a8kv8")
    w44 = _cell("qwen1.5-110b", "decode_32k", tag="w4a8kv4")
    out.append("### Cell 3: qwen1.5-110b × decode_32k (paper-representative "
               "memory wall)\n")
    out.append(f"Baseline (fp32 weights, bf16 cache): {_fmt_terms(base)}; "
               f"HBM split: weights 4.45e11 B, KV cache 1.37e12 B.\n")
    out.append("| # | change | hypothesis | result |")
    out.append("|---|---|---|---|")
    out.append(
        f"| 1 | **paper-faithful W8A8 + int8 KV** (`--quant serve_w8a8 "
        f"--kv-quant`) | weights ÷4, cache ÷2 → memory term ~÷2.2 | "
        f"**confirmed**: {_fmt_terms(w8)} |")
    out.append(
        f"| 2 | W4A8 (paper's aggressive setting) | weights ÷8; cache now "
        f"dominates so total gain small | **confirmed** (as predicted, "
        f"+7%): {_fmt_terms(w4)} |")
    out.append(
        f"| 3 | beyond-paper: **int4 KV cache** (packed nibbles + per-token "
        f"scales, fused-dequant decode kernel) | cache ÷2 again → memory "
        f"term ~÷1.8 | **confirmed**: {_fmt_terms(w44)} |")
    rep = _cell("qwen1.5-110b", "decode_32k", tag="w4a8kv4rep2")
    if rep:
        out.append(
            f"| 4 | KV-head replication to TP width (`kv_replicate=2`: 8→16 "
            f"heads, cache heads shard over 'model', attention chip-local) "
            f"| kills the partial-softmax collectives (the new bound) at 2× "
            f"cache bytes | collective ÷2.4 as predicted BUT the 2× cache "
            f"puts memory back on top: {_fmt_terms(rep)} — net LOSS at "
            f"S=32k; **refuted with insight** (pays only when cache ≪ "
            f"weights) |")
    out.append(
        "\nNet accepted config (iter 3): memory term 8.69 → 2.02 ms "
        "(**4.3×**), roofline fraction 0.065 → 0.246; the bound flipped to "
        "collectives (decode act all-reduces, f32-inflated ≤2× by the CPU "
        "backend — TPU-corrected the cell sits at ~fraction 0.4).\n")


def generalization_section(out):
    out.append("### Generalization of the winning changes to other cells\n")
    out.append(
        "Context parallelism (cell 1's winner) applied across train cells — "
        "the crossover between CP and TP is exactly where theory puts it:\n")
    out.append("| arch | params | baseline coll B/chip | CP coll B/chip | "
               "gain | verdict |")
    out.append("|---|---|---|---|---|---|")
    rows = [("qwen2-0.5b", "0.5B"), ("llama3.2-3b", "3.6B"),
            ("musicgen-large", "3.2B"), ("qwen3-moe-30b-a3b", "30B MoE"),
            ("qwen1.5-110b", "111B")]
    for arch, size in rows:
        b = _cell(arch, "train_4k")
        c = _cell(arch, "train_4k", tag="cp")
        if not (b and c):
            continue
        cb = sum(b["collective_bytes"].values())
        cc = sum(c["collective_bytes"].values())
        verdict = ("CP wins (act-AR dominated)" if cb / cc > 1.05 else
                   "TP wins (weight-gather / EP dominated)")
        out.append(f"| {arch} | {size} | {cb:.3e} | {cc:.3e} | "
                   f"{cb/cc:.2f}x | {verdict} |")
    out.append(
        "\nSmall dense models are activation-all-reduce bound → CP wins "
        "(5.4× for 0.5B); MoE needs the model axis for expert parallelism "
        "(CP is 5.5× WORSE — the dispatch all-to-alls turn into gathers); "
        "at 111B the per-layer weight gathers exceed the activation "
        "all-reduces → TP wins. The launcher picks per-arch policy "
        "accordingly (default TP; CP for <4B dense).\n")

    out.append("Quantized serving (cell 3's winner) applied to the other "
               "decode cells:\n")
    out.append("| arch | shape | baseline mem term | W4A8+int4KV mem term | "
               "gain |")
    out.append("|---|---|---|---|---|")
    for arch, shape in [("musicgen-large", "decode_32k"),
                        ("zamba2-1.2b", "long_500k"),
                        ("qwen1.5-110b", "decode_32k")]:
        b = _cell(arch, shape)
        q = _cell(arch, shape, tag="w4a8kv4")
        if not (b and q):
            continue
        tb, tq = terms(b), terms(q)
        out.append(f"| {arch} | {shape} | {tb['memory_s']*1e3:.3f} ms | "
                   f"{tq['memory_s']*1e3:.3f} ms | "
                   f"{tb['memory_s']/tq['memory_s']:.2f}x |")
    out.append("")


def paper_section(out):
    path = os.path.join(ART, "so3", "metrics.json")
    if not os.path.exists(path):
        out.append("## §Paper-results\n\n(pipeline still running — rerun "
                   "`python -m benchmarks.render_experiments`)\n")
        return
    m = json.load(open(path))
    mev = m["units"]["e_scale_eV"] * 1000

    out.append("## §Paper-results (synthetic-azobenzene rMD17 stand-in)\n")
    out.append("### Table II analogue — accuracy\n")
    out.append("| method | bits (W/A) | E-MAE (meV) | F-MAE (meV/Å) | stable |")
    out.append("|---|---|---|---|---|")
    for name, bits in [("fp32", "32/32"), ("naive_int8", "8/8"),
                       ("svq_kmeans", "8/8"), ("degree_quant", "8/8"),
                       ("gaq_w4a8", "4/8")]:
        d = m[name]
        out.append(f"| {name} | {bits} | {d['e_mae']*mev:.1f} | "
                   f"{d['f_mae']*mev:.1f} | "
                   f"{'diverged' if d.get('diverged') else 'stable'} |")
    out.append("")
    out.append("### Table III analogue — Local Equivariance Error\n")
    out.append("| method | LEE (meV/Å) |")
    out.append("|---|---|")
    for name in ["fp32", "naive_int8", "degree_quant", "gaq_w4a8"]:
        out.append(f"| {name} | {m[name]['lee']*mev:.3f} |")
    if "lee_dir16" in m["gaq_w4a8"]:
        out.append(f"| gaq_w4a8 (eval-time 16-bit codebook) | "
                   f"{m['gaq_w4a8']['lee_dir16']*mev:.3f} |")
        ratio = m["naive_int8"]["lee"] / max(m["gaq_w4a8"]["lee_dir16"], 1e-12)
    else:
        ratio = m["naive_int8"]["lee"] / max(m["gaq_w4a8"]["lee"], 1e-12)
    out.append(f"\nnaive/GAQ LEE ratio: **{ratio:.1f}×** (paper: >30×). "
               "The LEE floor is the codebook covering radius: training used "
               "a 12-bit codebook for CPU tractability (δ=0.04 rad); the "
               "eval-time 16-bit swap (δ=0.0097, the paper's implied "
               "resolution) recovers the separation. At equal 24 bits/vector "
               "GAQ beats Cartesian INT8 on symmetry while keeping the same "
               "4× memory reduction (analysis in DESIGN.md §8).\n")
    out.append("### Fig. 3 analogue — NVE stability\n")
    out.append("| method | T (K) | drift (meV/atom/ps) | blew up | "
               "E-range (eV) |")
    out.append("|---|---|---|---|---|")
    for name in ["fp32", "gaq_w4a8", "naive_int8"]:
        for key, T in [("nve", 300), ("nve_100k", 100),
                       ("nve_100k_dir14", 100), ("nve_100k_dir16", 100)]:
            d = m[name].get(key)
            if d:
                label = name + (" (dir14)" if "dir14" in key else
                                " (dir16)" if "dir16" in key else "")
                out.append(
                    f"| {label} | {T} | {d['drift_ev_per_atom_ps']*1000:.3f} "
                    f"| {d['blew_up']} | {d.get('e_range', float('nan')):.2f} |")
    out.append(
        "\nAt 300 K every CPU-scale model (incl. fp32) leaves its fitted "
        "region and blows up — data-coverage-limited, not quantization-"
        "limited. At 100 K the fp32 model is stable and the paper's ordering "
        "emerges: naive INT8 explodes (hundreds of eV of energy injection); "
        "GAQ's stability tracks the directional codebook resolution "
        "(coarse codebooks put kinks in the PES that pump energy — the "
        "dynamics analogue of the LEE floor).\n")
    lat = m["latency"]
    out.append("### Table IV analogue — memory wall (CPU microbenchmark)\n")
    out.append(f"- weight-I/O: fp32 {lat['weight_io_fp32_us']:.0f} µs → int8 "
               f"{lat['weight_io_int8_us']:.0f} µs "
               f"(**{lat['weight_io_fp32_us']/lat['weight_io_int8_us']:.2f}×**, "
               f"paper claims 4.0×) → int4 {lat['weight_io_int4_us']:.0f} µs "
               f"(**{lat['weight_io_fp32_us']/lat['weight_io_int4_us']:.2f}×**)")
    out.append(f"- model footprint: fp32 {lat['model_bytes_fp32']} B → W8 "
               f"{lat['model_bytes_w8']} B → W4 {lat['model_bytes_w4']} B "
               f"(4×/8×)")
    out.append("- CPU XLA cannot fuse dequant into GEMV "
               f"(overhead {lat['quant_overhead_us']:.0f} µs) — exactly the "
               "gap the Pallas W4A8 kernel closes on TPU (in-kernel nibble "
               "unpack + MXU int8 dot).\n")


def main():
    out = ["# EXPERIMENTS", ""]
    out.append("All artifacts under `artifacts/`; regenerate with "
               "`PYTHONPATH=src python -m benchmarks.render_experiments`.\n")
    paper_section(out)
    dryrun_section(out)
    roofline_section(out)
    perf_section(out)
    generalization_section(out)
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(out) + "\n")
    print("wrote EXPERIMENTS.md", len(out), "blocks")


if __name__ == "__main__":
    main()
