"""Kernel microbenchmarks: ref-path timings on CPU (the Pallas kernels
target TPU; interpret-mode timing is not meaningful) + exact byte-movement
accounting per kernel, which is the quantity the kernels optimize."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import make_codebook
from repro.kernels import ops, ref


def _bench(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def main():
    key = jax.random.PRNGKey(0)
    m, k, n = 512, 1024, 1024
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))

    w8, s8 = ops.prepare_w8(w)
    us = _bench(jax.jit(lambda a, b, c: ref.w8a8_matmul_ref(
        *ops.quantize_activations(a), b, c)), x, w8, s8)
    print(f"kernel_w8a8_ref_{m}x{k}x{n},{us:.1f},"
          f"w_bytes={k * n}_vs_fp32={4 * k * n}")

    w4, s4 = ops.prepare_w4(w)
    us = _bench(jax.jit(lambda a, b, c: ref.w4a8_matmul_ref(
        *ops.quantize_activations(a), b, c)), x, w4, s4)
    print(f"kernel_w4a8_ref_{m}x{k}x{n},{us:.1f},"
          f"w_bytes={k * n // 2}_vs_fp32={4 * k * n}")

    cb = make_codebook(8)
    cb_t = ops.pad_codebook(cb)
    v = jax.random.normal(key, (65536, 3))
    us = _bench(jax.jit(lambda vv: ref.mddq_encode_ref(vv, jnp.asarray(cb_t.T))), v)
    print(f"kernel_mddq_ref_64k_vectors,{us:.1f},"
          f"out_bytes={65536 * 2}_vs_fp32={65536 * 12}")

    bh, s, d = 8, 4096, 128
    q = jax.random.normal(key, (bh, d))
    kc = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d))
    vc = jax.random.normal(jax.random.fold_in(key, 3), (bh, s, d))
    kq, ks, vq, vs = ops.prepare_kv_int8(kc, vc)
    us = _bench(jax.jit(lambda *a: ref.decode_attention_int8kv_ref(
        *a, softmax_scale=d ** -0.5)), q, kq, ks, vq, vs)
    print(f"kernel_int8kv_decode_ref_{bh}x{s}x{d},{us:.1f},"
          f"cache_bytes={2 * bh * s * d}_vs_bf16={4 * bh * s * d}")


if __name__ == "__main__":
    main()
