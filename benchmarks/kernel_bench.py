"""Kernel microbenchmarks: ref-path timings on CPU (the Pallas kernels
target TPU; interpret-mode timing is not meaningful) + exact byte-movement
accounting per kernel, which is the quantity the kernels optimize.

Previously this printed CSV to stdout only, so kernel numbers were
invisible to regression gating. It now writes a ``repro.bench/1``
document (benchmarks/schema.py) like the other four domains: the byte
compression ratios are *exact* arithmetic over the packed layouts, so
they gate **hard** (``op: eq``) on every machine at every size — if a
layout change silently fattens a packed buffer, ``benchmarks.run
--diff-baselines`` fails; ref-path wall times ride along as soft,
core-count-aware metrics.

Run:  PYTHONPATH=src python benchmarks/kernel_bench.py
          [--reps 5] [--json BENCH_kernels.json] [--smoke]
"""
from __future__ import annotations

import argparse
import time

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema
from benchmarks.schema import Metric


def _bench(fn, *args, reps=5):
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer timing reps (shapes are "
                         "kept — the byte accounting is exact either way)")
    return ap


def apply_smoke(args) -> None:
    args.reps = 2


def collect(args) -> dict:
    """Run the four microbenchmarks; returns the domain's rich record.
    Shapes are fixed (the byte ratios are layout facts, not
    measurements), so metric names are stable across machines."""
    import jax
    import jax.numpy as jnp
    from repro.core import make_codebook
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    rows = []

    m, k, n = 512, 1024, 1024
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))

    w8, s8 = ops.prepare_w8(w)
    us = _bench(jax.jit(lambda a, b, c: ref.w8a8_matmul_ref(
        *ops.quantize_activations(a), b, c)), x, w8, s8, reps=args.reps)
    rows.append({"kernel": f"w8a8_ref_{m}x{k}x{n}", "us": us,
                 "bytes": k * n, "fp32_bytes": 4 * k * n})

    w4, s4 = ops.prepare_w4(w)
    us = _bench(jax.jit(lambda a, b, c: ref.w4a8_matmul_ref(
        *ops.quantize_activations(a), b, c)), x, w4, s4, reps=args.reps)
    rows.append({"kernel": f"w4a8_ref_{m}x{k}x{n}", "us": us,
                 "bytes": k * n // 2, "fp32_bytes": 4 * k * n})

    cb = make_codebook(8)
    cb_t = ops.pad_codebook(cb)
    v = jax.random.normal(key, (65536, 3))
    us = _bench(jax.jit(lambda vv: ref.mddq_encode_ref(
        vv, jnp.asarray(cb_t.T))), v, reps=args.reps)
    rows.append({"kernel": "mddq_ref_64k_vectors", "us": us,
                 "bytes": 65536 * 2, "fp32_bytes": 65536 * 12})

    bh, s, d = 8, 4096, 128
    q = jax.random.normal(key, (bh, d))
    kc = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d))
    vc = jax.random.normal(jax.random.fold_in(key, 3), (bh, s, d))
    kq, ks, vq, vs = ops.prepare_kv_int8(kc, vc)
    us = _bench(jax.jit(lambda *a: ref.decode_attention_int8kv_ref(
        *a, softmax_scale=d ** -0.5)), q, kq, ks, vq, vs, reps=args.reps)
    # int8 KV halves the *cache* the decode streams, vs a bf16 cache
    rows.append({"kernel": f"int8kv_decode_ref_{bh}x{s}x{d}", "us": us,
                 "bytes": 2 * bh * s * d, "fp32_bytes": 4 * bh * s * d})

    for r in rows:
        r["compression_x"] = r["fp32_bytes"] / r["bytes"]
        print(f"kernel_{r['kernel']},{r['us']:.1f},"
              f"bytes={r['bytes']}_vs_full={r['fp32_bytes']}")

    return {"benchmark": "kernel_ref_microbench",
            "backend": jax.default_backend(),
            "reps": args.reps,
            "rows": rows,
            "smoke": bool(getattr(args, "smoke", False))}


def metrics_from_record(record: dict) -> list:
    """Normalize the rich record into gated metrics (benchmarks.schema)."""
    ms = []
    for r in record["rows"]:
        ms.append(Metric(f"compression_x[{r['kernel']}]",
                         r["compression_x"], "x", kind="hard",
                         gate={"op": "eq", "bound": r["compression_x"]}))
        ms.append(Metric(f"us[{r['kernel']}]", r["us"], "us",
                         direction="lower"))
        ms.append(Metric(f"bytes[{r['kernel']}]", float(r["bytes"]),
                         "bytes", kind="info"))
    return ms


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.smoke:
        apply_smoke(args)
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        result = schema.ExperimentResult(
            experiment={"domain": "kernels", "mode": "-", "path": "-",
                        "replicas": 1, "devices": 1, "smoke": args.smoke},
            fingerprint="kernels:-:-:r1:d1",
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/kernel_bench.py"))
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
