"""Observability benchmark: trace completeness under chaos + clean-path
overhead (ISSUE 9).

The claims under test for ``repro.obs`` (docs/observability.md):

1. **Chaos trace completeness** — a seeded chaos replay (NaN poison
   escalating w4a8 -> w8a8, an in-flight replica kill, a rolling weight
   swap) through a 4-replica mixed-tier pool with tracing on: **every**
   request yields **exactly one** complete trace — an orphan-free span
   tree with every span closed, whose child-span durations sum to the
   measured end-to-end latency within 5% (the span model tiles the
   interval, so the margin is structural slack, not tolerance), and
   whose escalation / failover-requeue hops are attributed with one
   event per hop. The Prometheus exposition and the JSONL trace sink
   must round-trip the same story.
2. **Clean-path overhead** — identical request waves through the
   single-engine micro-batching scheduler with the whole obs plane ON
   (tracing + JSONL sink + metrics registry) vs OFF: median wave
   latency ratio <= 1.05x. Timing-gated, full-size runs only
   (``smoke_ok=False``).
3. **Active-plane alerting** (ISSUE 10) — two arms with the identical
   traffic shape through a 4-replica watchdog fleet plus an MD
   session, health plane (SLO burn-rate evaluator + anomaly monitor)
   armed in both. The *chaos* arm seeds five fault classes (guardrail
   escalations, an in-flight replica kill, an engine-lock stall, MD
   energy drift, session frame loss) and must fire an alert for
   **every** class with nothing unattributed; the *clean* arm must
   stay **silent** (zero false positives). The chaos arm's spans +
   flush records + warmup compiles must re-export as a Chrome-trace
   timeline that passes schema + exact-tiling + span-sum validation.

Run:  PYTHONPATH=src python benchmarks/obs_bench.py
          [--requests 160] [--poison-every 20] [--overhead-waves 30]
          [--json BENCH_obs.json] [--smoke]

Writes a ``repro.bench/1`` document (benchmarks/schema.py); the runner
drives the same measurement through :func:`run`.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

# devices must be forced before jax initializes (cluster_bench has the
# full rationale); under ``benchmarks.run`` the parent already committed
# the count into the child environment, so this is a no-op there.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import jax          # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402

if __package__ in (None, ""):   # `python benchmarks/<name>.py`
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))))

from benchmarks import schema                                  # noqa: E402
from benchmarks.schema import Metric                           # noqa: E402
from repro.cluster import ClusterConfig, ClusterPool           # noqa: E402
from repro.guardrails import (ForceEnvelope, GuardrailConfig,  # noqa: E402
                              GuardrailViolation)
from repro.md.engine import MDConfig                           # noqa: E402
from repro.models import so3krates as so3                      # noqa: E402
from repro.obs import (REGISTRY, TRACER, AlertBus,             # noqa: E402
                       AnomalyMonitor, HealthMonitor,
                       JsonlTraceSink, SLOEvaluator,
                       configure_tracing, default_detectors,
                       default_slos, load_traces,
                       prometheus_text, validate_chrome_trace,
                       write_chrome_trace, write_metrics)
from repro.server import save_artifact                         # noqa: E402
from repro.server.scheduler import (MicroBatchScheduler,       # noqa: E402
                                    RequestHandle,
                                    SchedulerConfig)
from repro.serving import (Graph, QuantizedEngine,             # noqa: E402
                           ServeConfig)
from repro.serving.qparams import quantize_so3_params          # noqa: E402
from repro.sessions import SessionConfig, SessionManager       # noqa: E402

# scenario 3's seeded fault classes and the alert each must raise
ALERT_REQUIRED = ("escalation_rate", "replica_failure", "replica_stall",
                  "md_energy_drift", "session_frame_loss")

WAIT_S = 1200.0
BUCKET = 16
SPAN_SUM_TOL = 0.05     # the committed <= 5% acceptance margin


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="w4a8",
                    choices=["fp32", "w8a8", "w4a8"],
                    help="traffic (primary) tier; poison escalates one "
                         "tier above it")
    ap.add_argument("--requests", type=int, default=160,
                    help="scenario 1: chaos replay size")
    ap.add_argument("--poison-every", type=int, default=20,
                    help="scenario 1: every Nth request is NaN-poisoned "
                         "(each one exercises an escalation hop)")
    ap.add_argument("--overhead-waves", type=int, default=30,
                    help="scenario 2: timed request waves per A/B arm")
    ap.add_argument("--wave-size", type=int, default=16,
                    help="scenario 2: requests per wave")
    ap.add_argument("--alert-requests", type=int, default=12,
                    help="scenario 3: paced background requests per arm "
                         "(detection is structural, not volume-driven, "
                         "so smoke keeps the same size)")
    ap.add_argument("--atoms", type=int, default=12)
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--json", default="BENCH_obs.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--workdir", default="/tmp/obs_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: same trace-completeness gates, "
                         "overhead gate skipped")
    return ap


def apply_smoke(args) -> None:
    args.requests = 32
    args.poison_every = 8
    args.overhead_waves = 6
    args.wave_size = 8


def _graph(n_species, n=12, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return Graph(species=rng.integers(0, n_species, n).astype(np.int32),
                 coords=rng.uniform(0, side, size=(n, 3)).astype(np.float32))


def _poison(n_species, n=12, seed=0):
    g = _graph(n_species, n, seed)
    coords = g.coords.copy()
    coords[0] = np.nan
    return Graph(species=g.species, coords=coords)


def _audit_trace(doc: dict, latency_s: float) -> dict:
    """Structural audit of one trace against its measured latency."""
    out = {"orphans": 0, "unclosed": 0, "sum_violation": 0,
           "unattributed": 0}
    spans = doc["spans"]
    root, children = spans[0], spans[1:]
    if root["t1"] is None:
        out["unclosed"] += 1
    for s in children:
        if s["parent_id"] != root["span_id"]:
            out["orphans"] += 1
        if s["t1"] is None:
            out["unclosed"] += 1
    if not out["unclosed"]:
        total = sum(s["t1"] - s["t0"] for s in children)
        if latency_s > 0 and abs(total - latency_s) > SPAN_SUM_TOL \
                * latency_s:
            out["sum_violation"] += 1
    # one attributing event per hop: each re-entry into a queue must be
    # explained by an "escalated" or "requeued" event
    hop_events = sum(1 for e in doc["events"]
                     if e["name"] in ("escalated", "requeued"))
    if doc["hops"] != hop_events:
        out["unattributed"] += 1
    return out


def scenario_chaos(model_cfg, params, serve4, serve8, args,
                   workdir) -> dict:
    """Seeded poison + in-flight kill + rolling swap through a traced
    4-replica mixed-tier pool; audit every request's trace."""
    trace_path = os.path.join(workdir, "chaos_traces.jsonl")
    if os.path.exists(trace_path):
        os.remove(trace_path)
    sink = JsonlTraceSink(trace_path)
    TRACER.reset()
    configure_tracing(enabled=True, sink=sink)
    REGISTRY.set_enabled(True)

    guard = GuardrailConfig(check_finite=True)
    qp4 = quantize_so3_params(params, serve4.mode)
    qp8 = quantize_so3_params(params, serve8.mode)
    engines = (
        [QuantizedEngine.from_quantized(model_cfg, qp4, serve4,
                                        guardrails=guard)
         for _ in range(3)]
        + [QuantizedEngine.from_quantized(model_cfg, qp8, serve8)])
    art = os.path.join(workdir, "swap_v2.npz")
    save_artifact(art, QuantizedEngine.from_config(
        model_cfg, serve=serve4, seed=99))

    kill_at = args.requests // 3
    swap_at = 2 * args.requests // 3
    n_poison = lost = typed = 0
    handles = []
    try:
        with ClusterPool(engines, ClusterConfig(
                n_replicas=4, max_batch=4, deadline_ms=2.0, warmup=False,
                max_escalations=1)) as pool:
            for i in range(args.requests):
                poisoned = i % args.poison_every == args.poison_every - 1
                n_poison += poisoned
                g = (_poison(model_cfg.n_species, n=args.atoms, seed=i)
                     if poisoned
                     else _graph(model_cfg.n_species, n=args.atoms,
                                 seed=i))
                handles.append(pool.submit(g))
                if i == kill_at:
                    pool.kill_replica(1, mode="in_flight")
                if i == swap_at:
                    pool.swap_artifact(art, warmup=False)
            for h in handles:
                try:
                    h.result(timeout=WAIT_S)
                except GuardrailViolation:
                    typed += 1
                except BaseException:
                    lost += 1
    finally:
        configure_tracing(enabled=False)
        sink.close()

    docs = TRACER.drain()
    by_id: dict = {}
    duplicates = 0
    for d in docs:
        if d["trace_id"] in by_id:
            duplicates += 1
        by_id[d["trace_id"]] = d
    missing = orphans = unclosed = sum_viol = unattributed = 0
    escalated = requeued = 0
    lat = []
    for h in handles:
        doc = by_id.get(h.trace.trace_id)
        if doc is None:
            missing += 1
            continue
        audit = _audit_trace(doc, h.latency_s)
        orphans += audit["orphans"]
        unclosed += audit["unclosed"]
        sum_viol += audit["sum_violation"]
        unattributed += audit["unattributed"]
        escalated += any(e["name"] == "escalated" for e in doc["events"])
        requeued += any(e["name"] == "requeued" for e in doc["events"])
        lat.append(h.latency_s)

    # export round-trip: the JSONL sink and the Prometheus exposition
    # must tell the same story the in-memory objects do
    sunk = {t["trace_id"] for t in load_traces(trace_path)}
    prom_path = os.path.join(workdir, "chaos_metrics.prom")
    write_metrics(prom_path)
    prom = open(prom_path).read()
    roundtrip_ok = int(
        sunk == set(by_id)
        and prom.startswith("# exported_at ")
        and "pool_events_total" in prom
        and 'event="escalated"' in prometheus_text())

    out = {
        "n_requests": args.requests,
        "n_poison": n_poison,
        "typed_errors": typed,
        "requests_lost": lost,
        "traces_missing": missing,
        "traces_duplicate": duplicates,
        "orphan_spans": orphans,
        "unclosed_spans": unclosed,
        "span_sum_violations": sum_viol,
        "unattributed_hops": unattributed,
        "escalated_traces": escalated,
        "requeued_traces": requeued,
        "export_roundtrip_ok": roundtrip_ok,
        "traced_p50_ms": float(np.percentile(lat, 50) * 1e3) if lat
        else 0.0,
    }
    print(f"chaos: {args.requests} requests ({n_poison} poisoned, 1 kill,"
          f" 1 swap) -> {len(by_id)} traces, {missing} missing, "
          f"{sum_viol} span-sum violations, {escalated} escalated, "
          f"{requeued} requeued")
    return out


def scenario_overhead(model_cfg, params, serve4, args, workdir) -> dict:
    """A/B the obs plane's clean-path cost through the single-engine
    scheduler: tracing + JSONL sink + registry ON vs everything OFF.

    The arms are **interleaved wave-by-wave** on one shared scheduler
    (tracing is minted per-submit, so toggling between waves is safe):
    sequential arms measure machine drift — on this box the bare
    baseline itself moves ~20% over a 30-wave run — so the reported
    ratio is the median of per-wave-pair on/off ratios, with the
    within-pair order alternating to cancel any order bias. Each pair
    runs the *same* wave twice, so the pair ratio is a same-input,
    same-instant comparison.
    """
    qp4 = quantize_so3_params(params, serve4.mode)
    engine = QuantizedEngine.from_quantized(model_cfg, qp4, serve4)
    cfg = SchedulerConfig(max_batch=4, deadline_ms=2.0, warmup=False)
    waves = [[_graph(model_cfg.n_species, n=args.atoms,
                     seed=1000 + w * args.wave_size + i)
              for i in range(args.wave_size)]
             for w in range(args.overhead_waves + 2)]
    sink_path = os.path.join(workdir, "overhead_traces.jsonl")
    sink = JsonlTraceSink(sink_path)

    def set_arm(on: bool) -> None:
        configure_tracing(enabled=on, sink=sink if on else None)
        REGISTRY.set_enabled(on)

    def run_wave(sched, wave) -> float:
        t0 = time.perf_counter()
        for h in [sched.submit(g) for g in wave]:
            h.result(timeout=WAIT_S)
        return time.perf_counter() - t0

    t_off, t_on = [], []
    try:
        with MicroBatchScheduler(engine, cfg) as sched:
            for wave in waves[:2]:                     # warm / compile
                run_wave(sched, wave)
            for w, wave in enumerate(waves[2:]):
                order = (True, False) if w % 2 else (False, True)
                for on in order:
                    set_arm(on)
                    (t_on if on else t_off).append(run_wave(sched, wave))
    finally:
        configure_tracing(enabled=False)
        sink.close()
        REGISTRY.set_enabled(True)
    off_s = float(np.median(t_off))
    on_s = float(np.median(t_on))
    ratio = float(np.median([a / b for a, b in zip(t_on, t_off)]))
    out = {
        "waves": args.overhead_waves,
        "wave_size": args.wave_size,
        "off_p50_ms": off_s * 1e3,
        "on_p50_ms": on_s * 1e3,
        "overhead_x": ratio,
    }
    print(f"overhead: off {off_s * 1e3:.2f} ms/wave, on "
          f"{on_s * 1e3:.2f} ms/wave -> {ratio:.3f}x "
          f"(median of {len(t_on)} paired wave ratios)")
    return out


def _alert_arm(model_cfg, qp_primary, qp_esc, serve_primary, serve_esc,
               hair, args, workdir, chaos: bool):
    """One arm of the active-plane replay. Identical traffic shape in
    both arms; only the chaos arm seeds faults. Returns the fired
    alerts plus (chaos arm) the raw material for the timeline export.
    """
    E = QuantizedEngine
    REGISTRY.reset()
    if chaos:
        TRACER.reset()
        configure_tracing(enabled=True)
    if chaos:
        # two hair-trigger primary-tier replicas (every request on them
        # violates the force envelope -> escalates a tier up) + two
        # escalation-tier replicas to absorb the hops
        engines = [E.from_quantized(model_cfg, qp_primary, serve_primary,
                                    guardrails=hair) for _ in range(2)]
        engines += [E.from_quantized(model_cfg, qp_esc, serve_esc)
                    for _ in range(2)]
    else:
        engines = [E.from_quantized(model_cfg, qp_esc, serve_esc)
                   for _ in range(4)]
    # warmup=True: the stall watchdog cannot tell a first-flush compile
    # from a stall, so a watchdog fleet must pre-compile
    pool = ClusterPool(engines, ClusterConfig(
        n_replicas=4, max_batch=4, deadline_ms=2.0, warmup=True,
        max_escalations=1, max_queue=64, stall_timeout_s=0.3,
        watchdog_interval_s=0.1, probation_s=0.1))
    bus = AlertBus(registry=REGISTRY)
    fired = []
    bus.subscribe(fired.append)
    monitor = HealthMonitor(
        [SLOEvaluator(default_slos(fast_window_s=0.6, slow_window_s=1.8,
                                   latency_p99_s=30.0,
                                   allow_partial=True),
                      registry=REGISTRY, bus=bus),
         AnomalyMonitor(default_detectors(), registry=REGISTRY, bus=bus)],
        interval_s=0.1).start()
    pool.watch_alerts(bus)
    flushes, warmups = [], []
    try:
        handles = []
        for i in range(args.alert_requests):    # paced background load
            handles.append(pool.submit(_graph(
                model_cfg.n_species, n=args.atoms, seed=100 + i)))
            time.sleep(0.04)
        if chaos:
            # fault 1: guardrail escalations, pinned to a hair-trigger
            # replica so each re-runs a tier up
            for k in range(3):
                h = RequestHandle(
                    _graph(model_cfg.n_species, n=args.atoms,
                           seed=500 + k),
                    time.monotonic(), bucket_capacity=BUCKET)
                assert pool._replicas[0].try_submit(h)
                handles.append(h)
            # fault 2: in-flight replica kill -> failover requeue
            rep3 = pool._replicas[3]
            pool.kill_replica(3, mode="in_flight")
            h = RequestHandle(
                _graph(model_cfg.n_species, n=args.atoms, seed=600),
                time.monotonic(), bucket_capacity=BUCKET)
            assert rep3.try_submit(h)
            handles.append(h)
            # fault 3: engine-lock stall -> watchdog quarantine
            rep1 = pool._replicas[1]
            rep1.inject_stall(1.5)
            h = RequestHandle(
                _graph(model_cfg.n_species, n=args.atoms, seed=700),
                time.monotonic(), bucket_capacity=BUCKET)
            assert rep1.try_submit(h)
            handles.append(h)
        for h in handles:
            h.result(timeout=WAIT_S)
        pool_alerts = pool.stats()["alerts"]
        flushes = pool.flush_records()
        warmups = pool.warmup_records()
    finally:
        pool.close()

    # fault 4 (chaos) / clean baseline: an MD session on a separate
    # watchdog-free pool — an MD chunk is ONE unit of worker busy time,
    # so its first-chunk compile would read as a stall
    md_pool = ClusterPool(
        [E.from_quantized(model_cfg, qp_esc, serve_esc)
         for _ in range(2)],
        ClusterConfig(n_replicas=2, max_batch=4, warmup=False,
                      max_queue=64))
    try:
        md = MDConfig(mode=serve_esc.mode, dt_fs=0.25, record_every=10,
                      drift_limit=1e-12 if chaos else None)
        scfg = SessionConfig(n_steps=40, chunk_steps=20, record_every=10,
                             checkpoint_every=1, md=md)
        rng = np.random.default_rng(13)
        n = args.atoms
        side = (n / 0.1) ** (1.0 / 3.0)
        mgr = SessionManager(md_pool, os.path.join(
            workdir, "alert_chaos" if chaos else "alert_clean"))
        s = mgr.start(
            rng.integers(0, model_cfg.n_species, n).astype(np.int32),
            rng.uniform(0, side, size=(n, 3)).astype(np.float32),
            np.full(n, 12.0, np.float32), seed=5, config=scfg)
        try:
            status = s.wait(WAIT_S)
        except BaseException:           # wait re-raises the session's
            status = s.status           # fatal error (drift kill)
        assert status == ("failed" if chaos else "done"), status
        mgr.close()
        time.sleep(0.5)                 # let the windows catch up
    finally:
        monitor.stop(final_step=True)
        md_pool.close()
        if chaos:
            configure_tracing(enabled=False)
    docs = TRACER.drain() if chaos else []
    return fired, pool_alerts, docs, flushes, warmups


def scenario_alerting(model_cfg, params, serve_primary, serve_esc, args,
                      workdir) -> dict:
    """Active health plane A/B: clean arm silent, chaos arm fires every
    seeded fault class, chaos spans re-export as a valid timeline."""
    qp_primary = quantize_so3_params(params, serve_primary.mode)
    qp_esc = quantize_so3_params(params, serve_esc.mode)
    hair = GuardrailConfig(
        envelope=ForceEnvelope(limits=((BUCKET, 1e-9),)))

    clean_fired, _, _, _, _ = _alert_arm(
        model_cfg, qp_primary, qp_esc, serve_primary, serve_esc, hair,
        args, workdir, chaos=False)
    chaos_fired, pool_alerts, docs, flushes, warmups = _alert_arm(
        model_cfg, qp_primary, qp_esc, serve_primary, serve_esc, hair,
        args, workdir, chaos=True)

    required = set(ALERT_REQUIRED)
    allowed = required | {d.name for d in default_detectors()}
    names = {a.name for a in chaos_fired}
    detected = required & names

    chrome_path = os.path.join(workdir, "alert_timeline.json")
    doc = write_chrome_trace(chrome_path, docs, flushes=flushes,
                             warmup=warmups)
    verdict = validate_chrome_trace(doc)

    out = {
        "requests_per_arm": args.alert_requests,
        "required_classes": sorted(required),
        "detected_classes": sorted(detected),
        "missed_classes": sorted(required - names),
        "detection_rate": len(detected) / len(required),
        "alerts_fired": sorted(names),
        "clean_false_positives": len(clean_fired),
        "clean_alert_names": sorted({a.name for a in clean_fired}),
        "unexpected_alerts": len(names - allowed),
        "pool_alerts_seen": pool_alerts["n_seen"],
        "chrome_events": verdict["n_events"],
        "chrome_trees": verdict["n_async_trees"],
        "chrome_schema_ok": int(verdict["n_schema_errors"] == 0),
        "chrome_tiling_violations": verdict["tiling_violations"],
        "chrome_sum_violations": verdict["sum_violations"],
    }
    print(f"alerting: chaos arm {len(detected)}/{len(required)} fault "
          f"classes detected ({', '.join(sorted(names)) or 'none'}), "
          f"clean arm {len(clean_fired)} false positive(s); timeline "
          f"{verdict['n_events']} events / {verdict['n_async_trees']} "
          f"tree(s), ok={verdict['ok']}")
    return out


def collect(args) -> dict:
    if args.mode == "fp32":
        raise SystemExit("--mode fp32 has no tier above it for the "
                         "poison-escalation chaos; use w4a8 or w8a8")
    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=4,
                                    n_layers=args.layers, n_rbf=4,
                                    dir_bits=6, cutoff=3.0)
    # dense path: the one NaN coordinates propagate through
    serve4 = ServeConfig(mode=args.mode, bucket_sizes=(BUCKET,),
                         max_batch=4, path="dense")
    esc_mode = "w8a8" if args.mode == "w4a8" else "fp32"
    serve8 = dataclasses.replace(serve4, mode=esc_mode)
    params = so3.init_params(jax.random.PRNGKey(0), model_cfg)
    os.makedirs(args.workdir, exist_ok=True)
    workdir = os.path.join(args.workdir, f"run_{os.getpid()}")
    os.makedirs(workdir, exist_ok=True)
    print(f"mode={args.mode} (escalates to {esc_mode}) "
          f"backend={jax.default_backend()} "
          f"devices={len(jax.devices())} requests={args.requests}")
    record = {
        "benchmark": "obs_tracing",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "n_cores": os.cpu_count() or 1,
        "mode": args.mode,
        "escalation_mode": esc_mode,
        "feat": args.feat,
        "n_layers": args.layers,
        "n_atoms": args.atoms,
        "chaos": scenario_chaos(model_cfg, params, serve4, serve8, args,
                                workdir),
        "overhead": scenario_overhead(model_cfg, params, serve4, args,
                                      workdir),
        "alerting": scenario_alerting(model_cfg, params, serve4, serve8,
                                      args, workdir),
        "smoke": args.smoke,
    }
    return record


def metrics_from_record(record: dict) -> list:
    """Normalize into gated metrics. Trace completeness and alert
    detection are structural and size-independent, so those gates are
    hard in smoke too; the overhead ratio is timing and
    full-size-only."""
    ch, ov = record["chaos"], record["overhead"]
    al = record["alerting"]
    return [
        Metric("obs_traces_missing", float(ch["traces_missing"]),
               "count", kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_traces_duplicate", float(ch["traces_duplicate"]),
               "count", kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_orphan_spans", float(ch["orphan_spans"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_unclosed_spans", float(ch["unclosed_spans"]),
               "count", kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_span_sum_violations",
               float(ch["span_sum_violations"]), "count", kind="hard",
               gate={"op": "eq", "bound": 0.0}),
        Metric("obs_unattributed_hops", float(ch["unattributed_hops"]),
               "count", kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_requests_lost", float(ch["requests_lost"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_escalated_traces", float(ch["escalated_traces"]),
               "count", kind="hard", gate={"op": "ge", "bound": 1.0}),
        Metric("obs_requeued_traces", float(ch["requeued_traces"]),
               "count", kind="hard", gate={"op": "ge", "bound": 1.0}),
        Metric("obs_export_roundtrip_ok",
               float(ch["export_roundtrip_ok"]), "bool", kind="hard",
               gate={"op": "eq", "bound": 1.0}),
        Metric("obs_overhead_x", ov["overhead_x"], "x", kind="hard",
               gate={"op": "le", "bound": 1.05}, smoke_ok=False),
        Metric("obs_alert_detection_rate",
               float(al["detection_rate"]), "frac", kind="hard",
               gate={"op": "eq", "bound": 1.0}),
        Metric("obs_alert_false_positives",
               float(al["clean_false_positives"]), "count", kind="hard",
               gate={"op": "eq", "bound": 0.0}),
        Metric("obs_alert_unexpected",
               float(al["unexpected_alerts"]), "count", kind="hard",
               gate={"op": "eq", "bound": 0.0}),
        Metric("obs_pool_alerts_seen",
               float(al["pool_alerts_seen"]), "count", kind="hard",
               gate={"op": "ge", "bound": 1.0}),
        Metric("obs_chrome_schema_ok",
               float(al["chrome_schema_ok"]), "bool", kind="hard",
               gate={"op": "eq", "bound": 1.0}),
        Metric("obs_chrome_tiling_violations",
               float(al["chrome_tiling_violations"]), "count",
               kind="hard", gate={"op": "eq", "bound": 0.0}),
        Metric("obs_chrome_sum_violations",
               float(al["chrome_sum_violations"]), "count", kind="hard",
               gate={"op": "eq", "bound": 0.0}),
        Metric("obs_traced_p50_ms", ch["traced_p50_ms"], "ms",
               direction="lower"),
        Metric("obs_typed_errors", float(ch["typed_errors"]), "count",
               kind="info"),
        Metric("obs_chrome_events", float(al["chrome_events"]), "count",
               kind="info"),
    ]


def check(record: dict) -> None:
    """Standalone acceptance assertions (the runner gates via baselines
    instead)."""
    ch, ov = record["chaos"], record["overhead"]
    al = record["alerting"]
    fails = []
    for key, label in (("traces_missing", "requests without a trace"),
                       ("traces_duplicate", "duplicate traces"),
                       ("orphan_spans", "orphan spans"),
                       ("unclosed_spans", "unclosed spans"),
                       ("span_sum_violations",
                        "traces whose span sum misses e2e latency by "
                        ">5%"),
                       ("unattributed_hops",
                        "traces with unexplained hops"),
                       ("requests_lost", "requests lost")):
        if ch[key] != 0:
            fails.append(f"{ch[key]} {label} (must be 0)")
    if ch["escalated_traces"] < 1:
        fails.append("chaos replay produced no escalation hop to trace")
    if ch["requeued_traces"] < 1:
        fails.append("chaos replay produced no failover requeue to trace")
    if not ch["export_roundtrip_ok"]:
        fails.append("JSONL sink / Prometheus exposition round-trip "
                     "disagrees with in-memory traces")
    if not record["smoke"] and ov["overhead_x"] > 1.05:
        fails.append(f"obs clean-path overhead {ov['overhead_x']:.3f}x "
                     "> 1.05x")
    if al["detection_rate"] < 1.0:
        fails.append("undetected fault classes: "
                     + ", ".join(al["missed_classes"]))
    if al["clean_false_positives"]:
        fails.append(f"{al['clean_false_positives']} clean-arm false "
                     "positive(s): "
                     + ", ".join(al["clean_alert_names"]))
    if al["unexpected_alerts"]:
        fails.append(f"{al['unexpected_alerts']} unattributed alert(s)")
    if al["pool_alerts_seen"] < 1:
        fails.append("pool.watch_alerts surfaced no alerts in stats()")
    if not al["chrome_schema_ok"]:
        fails.append("chrome-trace export has schema errors")
    if al["chrome_tiling_violations"]:
        fails.append(f"{al['chrome_tiling_violations']} chrome-trace "
                     "tiling violation(s)")
    if al["chrome_sum_violations"]:
        fails.append(f"{al['chrome_sum_violations']} chrome-trace "
                     "span-sum violation(s)")
    if fails:
        raise SystemExit("FAIL: " + "; ".join(fails))
    print(f"PASS: {ch['n_requests']} requests -> "
          f"{ch['n_requests'] - ch['traces_missing']} complete traces "
          f"({ch['escalated_traces']} escalated, "
          f"{ch['requeued_traces']} requeued), overhead "
          f"{ov['overhead_x']:.3f}x, alerting "
          f"{len(al['detected_classes'])}/{len(al['required_classes'])} "
          "fault classes, 0 false positives")


def run(config) -> tuple:
    """Runner entrypoint: ExperimentConfig -> (metrics, record)."""
    args = parser().parse_args([])
    args.json = ""
    if config.mode in ("w8a8", "w4a8"):
        args.mode = config.mode
    if config.smoke:
        apply_smoke(args)
    for k, v in config.extra.items():
        setattr(args, k.replace("-", "_"), v)
    args.smoke = config.smoke
    record = collect(args)
    return metrics_from_record(record), record


def main(argv=None):
    args = parser().parse_args(argv)
    if args.smoke:
        apply_smoke(args)
    record = collect(args)
    if args.json:
        result = schema.ExperimentResult(
            experiment={"domain": "obs", "mode": args.mode,
                        "path": "dense", "replicas": 4,
                        "devices": len(jax.devices()),
                        "smoke": args.smoke},
            fingerprint=(f"obs:{args.mode}:dense:r4"
                         f":d{len(jax.devices())}"),
            hardware=schema.hardware_context(),
            metrics=metrics_from_record(record),
            detail=record)
        schema.write_document(args.json, schema.bench_document(
            [result], generated_by="benchmarks/obs_bench.py"))
        print(f"\nwrote {args.json}")
    check(record)


if __name__ == "__main__":
    main()
