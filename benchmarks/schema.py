"""The one normalized benchmark-result schema every domain emits.

Before this module each bench script wrote its own JSON shape — four
divergent schemas, no way to diff a number between PRs without reading
the producing script. Now every benchmark result is a *document*:

    {
      "schema": "repro.bench/1",
      "generated_by": "benchmarks/serving_bench.py",
      "results": [
        {
          "experiment": {"domain": "serving", "mode": "w8a8", ...},
          "fingerprint": "serving:w8a8:dense+sparse:r1:d1",
          "hardware":   {"backend": "cpu", "n_cores": 2, "n_devices": 1},
          "duration_s": 123.4,
          "metrics": [
            {"name": "mol_per_s[b64].sparse", "value": 140.3,
             "unit": "mol/s", "kind": "soft", "direction": "higher"},
            {"name": "drift_ratio[w8a8,n64]", "value": 1.0, "unit": "x",
             "kind": "hard", "gate": {"op": "le", "bound": 2.0}},
            ...
          ],
          "detail": { ...the domain's rich record, unconstrained... }
        }
      ]
    }

Three metric kinds, which is the whole gating policy:

* ``hard`` — a correctness claim (energy-drift ratio, LEE, zero-drop /
  zero-loss counts, byte-accounting ratios). Carries an absolute gate
  ``{"op": "le"|"ge"|"eq", "bound": x}``; violating it is a regression
  on any machine, at any benchmark size, so hard gates are enforced
  even on ``--smoke`` runs (unless the metric is marked
  ``smoke_ok: false`` because its value only means something at full
  size, e.g. artifact compression of a deploy-scale model).
* ``soft`` — a performance claim (throughput, latency, speedup).
  Compared against the committed baseline value with a relative
  tolerance band, and only when the run is full-size *and* the core
  count matches the baseline's hardware context — perf numbers from a
  2-core reference container mean nothing on a 1-core box, so the gate
  skips (with a note) instead of crying wolf.
* ``info`` — recorded, never gated.

``BENCH_baselines.json`` is the committed gate table (one entry per
fingerprint x metric, plus the hardware context the values were measured
on); :func:`diff_against_baselines` compares a results document against
it and returns a report whose ``ok`` drives the runner's exit code.

This module is deliberately dependency-free (stdlib only, jax imported
lazily in :func:`hardware_context`) so schema validation in tests stays
cheap.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

SCHEMA_VERSION = "repro.bench/1"
BASELINES_VERSION = "repro.bench.baselines/1"

METRIC_KINDS = ("hard", "soft", "info")
GATE_OPS = ("le", "ge", "eq")
DIRECTIONS = ("higher", "lower")

# relative band for soft (perf) gates when the baseline entry does not
# override it: the 1-2 core reference containers show ±30% run-to-run
# noise on throughput under load (docs/cluster.md), so the default band
# must sit above that
DEFAULT_SOFT_TOLERANCE = 0.40


class SchemaError(ValueError):
    """A benchmark document/baselines file violates the schema."""


@dataclasses.dataclass(frozen=True)
class Metric:
    """One measured number in normalized form."""
    name: str
    value: float
    unit: str
    kind: str = "soft"                    # "hard" | "soft" | "info"
    direction: str = "higher"             # soft only: which way is better
    gate: Optional[Dict] = None           # hard only: {"op": .., "bound": ..}
    smoke_ok: bool = True                 # hard only: gate applies to --smoke

    def __post_init__(self):
        if self.kind not in METRIC_KINDS:
            raise SchemaError(f"metric {self.name!r}: bad kind {self.kind!r}")
        if self.kind == "hard":
            if not self.gate or self.gate.get("op") not in GATE_OPS:
                raise SchemaError(
                    f"hard metric {self.name!r} needs gate op in {GATE_OPS}")
        if self.direction not in DIRECTIONS:
            raise SchemaError(
                f"metric {self.name!r}: bad direction {self.direction!r}")

    def to_json(self) -> Dict:
        out = {"name": self.name, "value": self.value, "unit": self.unit,
               "kind": self.kind}
        if self.kind == "soft":
            out["direction"] = self.direction
        if self.kind == "hard":
            out["gate"] = dict(self.gate)
            if not self.smoke_ok:
                out["smoke_ok"] = False
        return out

    @classmethod
    def from_json(cls, d: Dict) -> "Metric":
        return cls(name=d["name"], value=d["value"], unit=d.get("unit", ""),
                   kind=d.get("kind", "soft"),
                   direction=d.get("direction", "higher"),
                   gate=d.get("gate"), smoke_ok=d.get("smoke_ok", True))


@dataclasses.dataclass
class ExperimentResult:
    """One experiment config's outcome: metrics + the rich detail record."""
    experiment: Dict                      # domain/mode/path/replicas/devices
    fingerprint: str
    hardware: Dict
    metrics: List[Metric]
    duration_s: float = 0.0
    detail: Optional[Dict] = None

    def to_json(self) -> Dict:
        out = {"experiment": self.experiment, "fingerprint": self.fingerprint,
               "hardware": self.hardware, "duration_s": self.duration_s,
               "metrics": [m.to_json() for m in self.metrics]}
        if self.detail is not None:
            out["detail"] = self.detail
        return out

    @classmethod
    def from_json(cls, d: Dict) -> "ExperimentResult":
        return cls(experiment=d["experiment"], fingerprint=d["fingerprint"],
                   hardware=d["hardware"],
                   metrics=[Metric.from_json(m) for m in d["metrics"]],
                   duration_s=d.get("duration_s", 0.0),
                   detail=d.get("detail"))


def hardware_context() -> Dict:
    """Backend + core/device counts of the running process (jax lazy)."""
    import os
    import platform

    import jax
    return {"backend": jax.default_backend(),
            "n_cores": os.cpu_count() or 1,
            "n_devices": jax.device_count(),
            "machine": platform.machine()}


def bench_document(results: Sequence[ExperimentResult],
                   generated_by: str) -> Dict:
    return {"schema": SCHEMA_VERSION, "generated_by": generated_by,
            "results": [r.to_json() for r in results]}


def write_document(path: str, doc: Dict) -> None:
    validate_document(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)


def load_document(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    validate_document(doc, path=path)
    return doc


# -- validation --------------------------------------------------------------

_EXPERIMENT_KEYS = ("domain", "mode", "path", "replicas", "devices", "smoke")


def _require(cond: bool, msg: str, path: str) -> None:
    if not cond:
        raise SchemaError(f"{path}: {msg}")


def validate_document(doc: Dict, path: str = "<doc>") -> None:
    """Raise :class:`SchemaError` unless ``doc`` is a valid results
    document. Shared by the runner, the standalone bench CLIs, and the
    tests that pin every committed BENCH_*.json to the schema."""
    _require(isinstance(doc, dict), "document must be an object", path)
    _require(doc.get("schema") == SCHEMA_VERSION,
             f"schema must be {SCHEMA_VERSION!r}, got {doc.get('schema')!r}",
             path)
    _require(isinstance(doc.get("generated_by"), str) and doc["generated_by"],
             "generated_by must be a non-empty string", path)
    results = doc.get("results")
    _require(isinstance(results, list) and results,
             "results must be a non-empty list", path)
    seen = set()
    for i, r in enumerate(results):
        where = f"{path}#results[{i}]"
        _require(isinstance(r, dict), "result must be an object", where)
        exp = r.get("experiment")
        _require(isinstance(exp, dict), "experiment must be an object", where)
        for k in _EXPERIMENT_KEYS:
            _require(k in exp, f"experiment missing key {k!r}", where)
        fp = r.get("fingerprint")
        _require(isinstance(fp, str) and fp,
                 "fingerprint must be a non-empty string", where)
        _require(fp not in seen, f"duplicate fingerprint {fp!r}", where)
        seen.add(fp)
        hw = r.get("hardware")
        _require(isinstance(hw, dict), "hardware must be an object", where)
        for k in ("backend", "n_cores", "n_devices"):
            _require(k in hw, f"hardware missing key {k!r}", where)
        _require(isinstance(r.get("duration_s"), (int, float)),
                 "duration_s must be a number", where)
        metrics = r.get("metrics")
        _require(isinstance(metrics, list) and metrics,
                 "metrics must be a non-empty list", where)
        names = set()
        for j, m in enumerate(metrics):
            mwhere = f"{where}.metrics[{j}]"
            _require(isinstance(m, dict), "metric must be an object", mwhere)
            try:
                metric = Metric.from_json(m)
            except (KeyError, SchemaError) as e:
                raise SchemaError(f"{mwhere}: {e}") from e
            _require(isinstance(metric.value, (int, float))
                     and not isinstance(metric.value, bool),
                     f"metric {metric.name!r} value must be a number", mwhere)
            _require(metric.name not in names,
                     f"duplicate metric name {metric.name!r}", mwhere)
            names.add(metric.name)


def validate_baselines(doc: Dict, path: str = "<baselines>") -> None:
    _require(isinstance(doc, dict), "baselines must be an object", path)
    _require(doc.get("schema") == BASELINES_VERSION,
             f"schema must be {BASELINES_VERSION!r}, "
             f"got {doc.get('schema')!r}", path)
    gates = doc.get("gates")
    _require(isinstance(gates, dict) and gates,
             "gates must be a non-empty object", path)
    for fp, entry in gates.items():
        where = f"{path}#gates[{fp}]"
        _require(isinstance(entry, dict), "gate entry must be an object",
                 where)
        hw = entry.get("hardware")
        _require(isinstance(hw, dict) and "n_cores" in hw,
                 "gate entry needs hardware.n_cores", where)
        metrics = entry.get("metrics")
        _require(isinstance(metrics, dict) and metrics,
                 "gate entry needs a non-empty metrics map", where)
        for name, g in metrics.items():
            gwhere = f"{where}.{name}"
            kind = g.get("kind")
            _require(kind in ("hard", "soft"),
                     f"gated metric kind must be hard|soft, got {kind!r}",
                     gwhere)
            if kind == "hard":
                _require(g.get("op") in GATE_OPS,
                         f"hard gate op must be in {GATE_OPS}", gwhere)
                _require(isinstance(g.get("bound"), (int, float)),
                         "hard gate needs a numeric bound", gwhere)
            else:
                _require(isinstance(g.get("value"), (int, float)),
                         "soft gate needs a numeric baseline value", gwhere)
                _require(g.get("direction", "higher") in DIRECTIONS,
                         "soft gate direction must be higher|lower", gwhere)


def load_baselines(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    validate_baselines(doc, path=path)
    return doc


# -- baseline construction ---------------------------------------------------

def baselines_from_documents(docs: Sequence[Dict], source: str) -> Dict:
    """Derive the committed gate table from per-domain result documents:
    hard metrics contribute their op+bound, soft metrics their measured
    value (the tolerance band is applied at diff time). Info metrics are
    not gated."""
    gates: Dict[str, Dict] = {}
    for doc in docs:
        validate_document(doc)
        for r in doc["results"]:
            entry = gates.setdefault(
                r["fingerprint"],
                {"hardware": {k: r["hardware"][k]
                              for k in ("backend", "n_cores", "n_devices")},
                 "metrics": {}})
            for m in r["metrics"]:
                metric = Metric.from_json(m)
                if metric.kind == "hard":
                    entry["metrics"][metric.name] = {
                        "kind": "hard", "op": metric.gate["op"],
                        "bound": metric.gate["bound"], "unit": metric.unit,
                        "measured": metric.value,
                        "smoke_ok": metric.smoke_ok}
                elif metric.kind == "soft":
                    entry["metrics"][metric.name] = {
                        "kind": "soft", "value": metric.value,
                        "unit": metric.unit, "direction": metric.direction,
                        "tolerance": DEFAULT_SOFT_TOLERANCE}
    out = {"schema": BASELINES_VERSION, "source": source, "gates": gates}
    validate_baselines(out)
    return out


# -- gating ------------------------------------------------------------------

@dataclasses.dataclass
class GateCheck:
    fingerprint: str
    metric: str
    status: str          # "pass" | "fail" | "skip"
    message: str


@dataclasses.dataclass
class GateReport:
    checks: List[GateCheck]

    @property
    def ok(self) -> bool:
        return not any(c.status == "fail" for c in self.checks)

    def counts(self) -> Dict[str, int]:
        out = {"pass": 0, "fail": 0, "skip": 0}
        for c in self.checks:
            out[c.status] = out.get(c.status, 0) + 1
        return out

    def render(self) -> str:
        lines = []
        for c in self.checks:
            if c.status == "pass":
                continue                     # keep the report readable
            lines.append(f"  {c.status.upper():<5} {c.fingerprint} :: "
                         f"{c.metric}: {c.message}")
        n = self.counts()
        lines.append(f"gates: {n['pass']} pass, {n['fail']} fail, "
                     f"{n['skip']} skipped")
        return "\n".join(lines)


def _check_hard(fp: str, name: str, gate: Dict, value: float,
                smoke: bool) -> GateCheck:
    if smoke and not gate.get("smoke_ok", True):
        return GateCheck(fp, name, "skip",
                         "hard gate only meaningful at full size")
    op, bound = gate["op"], gate["bound"]
    ok = {"le": value <= bound, "ge": value >= bound,
          "eq": value == bound}[op]
    msg = f"value {value:g} {op} bound {bound:g}"
    return GateCheck(fp, name, "pass" if ok else "fail",
                     msg if ok else f"HARD GATE VIOLATED: {msg} is false")


def _check_soft(fp: str, name: str, gate: Dict, value: float, smoke: bool,
                run_cores: int) -> GateCheck:
    if smoke:
        return GateCheck(fp, name, "skip", "perf gate skipped on smoke run")
    base_cores = gate.get("n_cores")
    if base_cores is not None and run_cores != base_cores:
        return GateCheck(
            fp, name, "skip",
            f"core-count mismatch: baseline measured on {base_cores} "
            f"cores, this run has {run_cores} — perf band not comparable")
    base = gate["value"]
    tol = gate.get("tolerance", DEFAULT_SOFT_TOLERANCE)
    if gate.get("direction", "higher") == "higher":
        floor = base * (1.0 - tol)
        ok = value >= floor
        msg = (f"value {value:g} vs baseline {base:g} "
               f"(floor {floor:g}, -{tol:.0%})")
    else:
        ceil = base * (1.0 + tol)
        ok = value <= ceil
        msg = (f"value {value:g} vs baseline {base:g} "
               f"(ceiling {ceil:g}, +{tol:.0%})")
    return GateCheck(fp, name, "pass" if ok else "fail",
                     msg if ok else f"perf regression: {msg}")


def diff_against_baselines(doc: Dict, baselines: Dict,
                           expected_fingerprints: Optional[Sequence[str]]
                           = None) -> GateReport:
    """Gate a results document against the committed baselines.

    ``expected_fingerprints`` limits which baseline entries *must* be
    present in the document (the runner passes the fingerprints of the
    configs it was asked to run, so ``--domains md`` does not fail the
    serving gates as missing). Baseline entries outside the expectation
    are skipped with a note; an expected fingerprint absent from the
    document is a failure — a silently-not-run experiment must not read
    as green.
    """
    validate_document(doc)
    validate_baselines(baselines)
    by_fp = {r["fingerprint"]: r for r in doc["results"]}
    if expected_fingerprints is None:
        expected = set(baselines["gates"])
    else:
        expected = set(expected_fingerprints)
    checks: List[GateCheck] = []
    for fp, entry in sorted(baselines["gates"].items()):
        if fp not in expected:
            checks.append(GateCheck(fp, "*", "skip",
                                    "experiment not selected for this run"))
            continue
        result = by_fp.get(fp)
        if result is None:
            checks.append(GateCheck(
                fp, "*", "fail",
                "expected experiment missing from results document"))
            continue
        smoke = bool(result["experiment"].get("smoke"))
        run_cores = int(result["hardware"]["n_cores"])
        values = {m["name"]: m["value"] for m in result["metrics"]}
        for name, gate in sorted(entry["metrics"].items()):
            if name not in values:
                if gate["kind"] == "hard" and not smoke:
                    checks.append(GateCheck(
                        fp, name, "fail",
                        "hard-gated metric missing from full-size run"))
                else:
                    checks.append(GateCheck(
                        fp, name, "skip",
                        "metric not emitted by this run "
                        + ("(smoke runs shrink coverage)" if smoke else "")))
                continue
            if gate["kind"] == "hard":
                checks.append(_check_hard(fp, name, gate, values[name],
                                          smoke))
            else:
                soft = dict(gate)
                soft.setdefault("n_cores", entry["hardware"]["n_cores"])
                checks.append(_check_soft(fp, name, soft, values[name],
                                          smoke, run_cores))
    return GateReport(checks)
