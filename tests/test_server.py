"""Tests for repro.server: the dynamic micro-batching scheduler, the
packed quantized-artifact format, the traffic harness, and the engine
stats API that rides along.

The invariants under test:

* **request identity** — any molecule submitted through the scheduler
  yields the same energy/forces (<= 1e-6) as a direct
  ``engine.infer_batch([g])`` call, for mixed-size traffic across
  buckets, out-of-order flushes, and graphs riding the dense-fallback
  path;
* **artifact bit-exactness** — save -> load reproduces the source
  engine's results *bit-identically* (the loaded arrays are
  byte-for-byte the saved ones), and corruption (truncation, flipped
  bytes, version skew) raises ``ArtifactError`` instead of serving
  garbage.
"""
import dataclasses
import json
import os
import time
import zipfile

import jax
import numpy as np
import pytest

from repro.models import so3krates as so3
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.server import (ARTIFACT_VERSION, ArtifactError,
                          MicroBatchScheduler, RateStage, SchedulerClosed,
                          SchedulerConfig, SchedulerOverloaded, SizeClass,
                          TrafficConfig, flush_summary, latency_summary,
                          load_artifact, load_engine, make_step_traffic,
                          make_traffic, run_closed_loop, run_open_loop,
                          save_artifact, stage_summaries)

CFG = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2, n_rbf=8,
                          dir_bits=6, cutoff=3.0)
RESULT_TIMEOUT = 300   # generous: CPU-interpret compiles inside flushes


def _graphs(ns, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    out = []
    for n in ns:
        side = (n / density) ** (1.0 / 3.0)
        out.append(Graph(
            species=rng.integers(0, CFG.n_species, n).astype(np.int32),
            coords=rng.uniform(0, side, (n, 3)).astype(np.float32)))
    return out


@pytest.fixture(scope="module")
def engine():
    serve = ServeConfig(mode="w8a8", bucket_sizes=(16, 32), max_batch=8)
    return QuantizedEngine.from_config(CFG, serve=serve, seed=0)


class TestSchedulerIdentity:
    def test_mixed_size_traffic_matches_direct_calls(self, engine):
        """Mixed-size molecules through the scheduler == per-molecule
        direct infer_batch, <= 1e-6, independent of how flushes grouped
        them."""
        graphs = _graphs([5, 30, 12, 7, 25, 16, 9, 32, 11], seed=1)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=5.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            handles = [sched.submit(g) for g in graphs]
            results = [h.result(timeout=RESULT_TIMEOUT) for h in handles]
        for g, r in zip(graphs, results):
            (direct,) = engine.infer_batch([g])
            assert abs(r.energy - direct.energy) <= 1e-6
            np.testing.assert_allclose(r.forces, direct.forces, atol=1e-6)
            assert r.n_atoms == g.n_atoms

    def test_identity_through_dense_fallback(self):
        """Graphs whose cutoff graph overflows the bucket's edge capacity
        ride the dense fallback inside a sparse-preferring engine — the
        scheduler must preserve identity there too."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16, 32), max_batch=8,
                            path="sparse", edge_capacity=128)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        rng = np.random.default_rng(3)
        # a tight 16-atom cluster: 16*15 = 240 directed edges > 128 slots
        dense_g = Graph(
            species=rng.integers(0, CFG.n_species, 16).astype(np.int32),
            coords=(rng.normal(size=(16, 3)) * 0.5).astype(np.float32))
        sparse_gs = _graphs([10, 24], seed=4)
        cfg = SchedulerConfig(max_batch=2, deadline_ms=5.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            handles = [sched.submit(g)
                       for g in [dense_g] + sparse_gs]
            results = [h.result(timeout=RESULT_TIMEOUT) for h in handles]
        assert engine.dispatch_stats["sparse_fallback"] > 0, \
            "test molecule did not exercise the dense fallback"
        for g, r in zip([dense_g] + sparse_gs, results):
            (direct,) = engine.infer_batch([g])
            assert abs(r.energy - direct.energy) <= 1e-6
            np.testing.assert_allclose(r.forces, direct.forces, atol=1e-6)

    def test_results_resolve_to_their_own_handles(self, engine):
        """Same-size molecules batched together must not get each
        other's results (row mixups inside a flush)."""
        graphs = _graphs([12, 12, 12, 12, 12], seed=5)
        cfg = SchedulerConfig(max_batch=5, deadline_ms=50.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            handles = [sched.submit(g) for g in graphs]
            results = [h.result(timeout=RESULT_TIMEOUT) for h in handles]
        energies = [r.energy for r in results]
        direct = [engine.infer_batch([g])[0].energy for g in graphs]
        np.testing.assert_allclose(energies, direct, atol=1e-6)
        # distinct random molecules: energies must actually differ
        assert len({round(e, 6) for e in direct}) > 1


class TestSchedulerBatching:
    def test_full_queue_flushes_as_one_batch(self, engine):
        """max_batch same-bucket requests submitted at once flush as a
        single "full" batch (no deadline wait)."""
        graphs = _graphs([10, 11, 12, 13], seed=6)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=10_000.0,
                              warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            t0 = time.monotonic()
            handles = [sched.submit(g) for g in graphs]
            for h in handles:
                h.result(timeout=RESULT_TIMEOUT)
            elapsed = time.monotonic() - t0
            stats = sched.stats()
        full = [1 for f in sched._flushes if f.reason == "full"]
        assert sum(full) >= 1
        assert stats["max_batch"] == 4
        # a 10-second deadline was never the trigger
        assert elapsed < 10.0

    def test_deadline_flushes_partial_batch(self, engine):
        """A lone request must not wait for a full batch: the deadline
        fires and ships a partial one."""
        (g,) = _graphs([9], seed=7)
        cfg = SchedulerConfig(max_batch=8, deadline_ms=30.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            h = sched.submit(g)
            r = h.result(timeout=RESULT_TIMEOUT)
            stats = sched.stats()
        assert r.n_atoms == 9
        assert stats["flush_reasons"].get("deadline", 0) \
            + stats["flush_reasons"].get("drain", 0) >= 1
        assert stats["mean_batch"] == 1.0

    def test_close_drains_pending_requests(self, engine):
        """close() completes everything already admitted, then rejects
        new submissions with the typed SchedulerClosed error — a request
        is admitted (and resolves) or refused loudly, never left hanging
        on a handle no worker will ever serve."""
        graphs = _graphs([8, 14, 22], seed=8)
        cfg = SchedulerConfig(max_batch=8, deadline_ms=60_000.0,
                              warmup=False)
        sched = MicroBatchScheduler(engine, cfg)
        handles = [sched.submit(g) for g in graphs]
        sched.close()                      # no deadline ever fired: drain
        for h in handles:
            assert h.done()
            assert np.isfinite(h.result().energy)
        with pytest.raises(SchedulerClosed, match="closed"):
            sched.submit(graphs[0])
        # SchedulerClosed subclasses RuntimeError (pre-existing callers)
        assert issubclass(SchedulerClosed, RuntimeError)

    def test_bounded_admission_sheds_with_retry_hint(self, engine):
        """With max_queue set, submit beyond the bound sheds with
        SchedulerOverloaded + retry_after_s instead of growing the queue
        without bound; already-admitted requests still complete."""
        graphs = _graphs([10, 11, 12], seed=30)
        cfg = SchedulerConfig(max_batch=8, deadline_ms=60_000.0,
                              warmup=False, max_queue=2)
        sched = MicroBatchScheduler(engine, cfg)
        admitted = [sched.submit(g) for g in graphs[:2]]
        with pytest.raises(SchedulerOverloaded) as ei:
            sched.submit(graphs[2])
        assert ei.value.retry_after_s > 0
        assert sched.stats()["n_shed"] == 1
        sched.close()
        for h in admitted:
            assert np.isfinite(h.result().energy)

    def test_deadline_expired_queue_not_starved_by_full_queue(self, engine):
        """Among triggered queues the oldest head request flushes first:
        a full small-bucket queue must not preempt a deadline-expired
        request that has waited longer (starvation under sustained
        small-molecule overload). Probed on BatchQueue directly — the
        policy object both the single scheduler and every cluster
        replica drive."""
        from repro.server import BatchQueue
        from repro.server.scheduler import RequestHandle
        cfg = SchedulerConfig(max_batch=2, deadline_ms=10.0, warmup=False)
        queue = BatchQueue(engine.serve.buckets(), cfg)
        (g16,) = _graphs([8], seed=20)
        (g32,) = _graphs([24], seed=21)
        now = time.monotonic()
        old = RequestHandle(g32, now - 1.0, bucket_capacity=32)
        queue.append(old)                       # deadline long expired
        for _ in range(2):                      # full 16-atom queue
            queue.append(RequestHandle(g16, now, bucket_capacity=16))
        cap, handles, reason = queue.pick_flush(now, drain=False)
        assert (cap, reason) == (32, "deadline")
        assert handles == [old]
        # the full queue goes next
        cap, handles, reason = queue.pick_flush(now, drain=False)
        assert (cap, reason) == (16, "full")
        assert len(handles) == 2
        assert queue.depth() == 0

    def test_oversize_molecule_raises_at_submit(self, engine):
        big = _graphs([100], seed=9)[0]
        cfg = SchedulerConfig(warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            with pytest.raises(ValueError, match="exceeds the largest"):
                sched.submit(big)

    def test_scheduler_max_batch_clamped_to_engine(self, engine):
        with pytest.raises(ValueError, match="exceeds ServeConfig"):
            MicroBatchScheduler(
                engine, SchedulerConfig(max_batch=99, warmup=False))


class TestEngineStats:
    def test_reset_and_snapshot(self, engine):
        engine.infer_batch(_graphs([10], seed=10))
        before = engine.stats_snapshot()
        assert sum(before.values()) > 0
        pre_reset = engine.reset_stats()
        assert pre_reset == before
        assert sum(engine.dispatch_stats.values()) == 0
        # snapshot is a copy, not a live view
        snap = engine.stats_snapshot()
        engine.infer_batch(_graphs([10], seed=10))
        assert sum(snap.values()) == 0
        assert sum(engine.dispatch_stats.values()) > 0


class TestArtifact:
    @pytest.mark.parametrize("mode", ["w8a8", "w4a8"])
    def test_round_trip_bit_exact(self, tmp_path, mode):
        """saved -> loaded engine produces bit-identical energies and
        forces to the in-memory source engine."""
        serve = ServeConfig(mode=mode, bucket_sizes=(16,), max_batch=8)
        src = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        path = str(tmp_path / f"model_{mode}.npz")
        nbytes = save_artifact(path, src)
        assert nbytes == os.path.getsize(path)

        loaded = load_engine(path)
        assert loaded.model_cfg == CFG
        assert loaded.serve == serve
        graphs = _graphs([6, 12, 16], seed=11)
        for a, b in zip(src.infer_batch(graphs), loaded.infer_batch(graphs)):
            assert a.energy == b.energy                  # bit-exact
            np.testing.assert_array_equal(a.forces, b.forces)
        # the fp32 footprint survives the round trip for memory_report
        assert loaded.memory_report() == src.memory_report()

    def test_truncated_file_raises_clean_error(self, tmp_path):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        src = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        path = str(tmp_path / "model.npz")
        save_artifact(path, src)
        data = open(path, "rb").read()
        for cut in (len(data) // 2, 10):
            trunc = str(tmp_path / f"trunc_{cut}.npz")
            with open(trunc, "wb") as f:
                f.write(data[:cut])
            with pytest.raises(ArtifactError):
                load_artifact(trunc)

    def test_bitflip_fails_checksum(self, tmp_path):
        """A flipped byte inside a weight payload must be caught by the
        per-leaf SHA-256, not served."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        src = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        path = str(tmp_path / "model.npz")
        save_artifact(path, src)
        # rewrite one member with a corrupted payload (zip CRC suppressed
        # by rebuilding the archive, so only our checksum can catch it)
        with zipfile.ZipFile(path) as z:
            members = {n: z.read(n) for n in z.namelist()}
        victim = next(n for n in members if n.startswith("q/")
                      and n.endswith("/data.npy"))
        body = bytearray(members[victim])
        body[-1] ^= 0xFF                     # flip a payload byte
        members[victim] = bytes(body)
        bad = str(tmp_path / "bad.npz")
        with zipfile.ZipFile(bad, "w") as z:
            for n, b in members.items():
                z.writestr(n, b)
        with pytest.raises(ArtifactError, match="checksum|corrupt"):
            load_artifact(bad)

    def test_version_mismatch_raises(self, tmp_path):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        src = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        path = str(tmp_path / "model.npz")
        save_artifact(path, src)
        with zipfile.ZipFile(path) as z:
            members = {n: z.read(n) for n in z.namelist()}
        raw = members["__manifest__.npy"]
        # the manifest payload is raw utf-8 json after the .npy header
        head_end = raw.index(b"\n") + 1
        manifest = json.loads(raw[head_end:].decode())
        manifest["version"] = ARTIFACT_VERSION + 1
        new_json = json.dumps(manifest).encode()
        bumped = str(tmp_path / "bumped.npz")
        with zipfile.ZipFile(bumped, "w") as z:
            for n, b in members.items():
                if n == "__manifest__.npy":
                    hdr = _npy_u8_header(len(new_json))
                    z.writestr(n, hdr + new_json)
                else:
                    z.writestr(n, b)
        with pytest.raises(ArtifactError, match="version"):
            load_artifact(bumped)

    def test_not_an_artifact_raises(self, tmp_path):
        plain = str(tmp_path / "plain.npz")
        np.savez(plain, x=np.zeros(3))
        with pytest.raises(ArtifactError, match="manifest"):
            load_artifact(plain)

    def test_mode_override_rejected(self, tmp_path):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        src = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        path = str(tmp_path / "model.npz")
        save_artifact(path, src)
        with pytest.raises(ArtifactError, match="mode"):
            load_engine(path, serve=dataclasses.replace(serve, mode="w4a8"))
        # non-mode serving knobs may change at load time
        eng = load_engine(path, serve=dataclasses.replace(
            serve, bucket_sizes=(16, 32), path="dense"))
        assert eng.serve.bucket_sizes == (16, 32)

    def test_artifact_is_smaller_than_fp32(self, tmp_path):
        """The on-disk packed artifact beats the fp32 param bytes; the
        >= 3x w4a8 target at deploy scale is pinned by
        benchmarks/server_bench.py (weight-dominated model)."""
        serve = ServeConfig(mode="w4a8", bucket_sizes=(16,), max_batch=8)
        src = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        path = str(tmp_path / "model.npz")
        nbytes = save_artifact(path, src)
        assert nbytes < src.memory_report()["fp32_bytes"]


def _npy_u8_header(n: int) -> bytes:
    """Minimal .npy v1 header for a (n,) uint8 array."""
    head = (f"{{'descr': '|u1', 'fortran_order': False, "
            f"'shape': ({n},), }}").encode()
    pad = 64 - (10 + len(head) + 1) % 64
    head += b" " * pad + b"\n"
    return b"\x93NUMPY\x01\x00" + len(head).to_bytes(2, "little") + head


class TestStepTraffic:
    STAGES = [RateStage(50.0, 1.0), RateStage(400.0, 0.5),
              RateStage(50.0, 1.0)]

    def test_step_traffic_is_seeded(self):
        t1 = make_step_traffic(self.STAGES, seed=3)
        t2 = make_step_traffic(self.STAGES, seed=3)
        assert [t for t, _ in t1] == [t for t, _ in t2]
        for (_, g1), (_, g2) in zip(t1, t2):
            np.testing.assert_array_equal(g1.coords, g2.coords)
        assert [t for t, _ in make_step_traffic(self.STAGES, seed=4)] \
            != [t for t, _ in t1]

    def test_step_traffic_rates_are_piecewise(self):
        """Arrival counts per stage track the stage rates; arrivals are
        strictly inside the schedule and increasing."""
        t = make_step_traffic(self.STAGES, seed=5)
        times = np.asarray([x for x, _ in t])
        assert (np.diff(times) > 0).all()
        assert times[0] >= 0.0 and times[-1] < 2.5
        n1 = ((times >= 0.0) & (times < 1.0)).sum()
        n2 = ((times >= 1.0) & (times < 1.5)).sum()
        n3 = (times >= 1.5).sum()
        # expectation 50 / 200 / 50: the burst stage must dominate
        assert n2 > 2 * n1 and n2 > 2 * n3
        assert abs(n1 - 50) < 40 and abs(n2 - 200) < 80

    def test_stage_summaries_attribute_by_arrival(self, engine):
        stages = [RateStage(100.0, 0.1), RateStage(100.0, 0.1)]
        traffic = make_step_traffic(stages, size_mix=(SizeClass(6, 16, 1.0),),
                                    seed=6)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=5.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            res = run_open_loop(sched, traffic)
        rows = stage_summaries(res, stages)
        assert len(rows) == 2
        assert sum(r["n_offered"] for r in rows) == len(traffic)
        assert all(r["n_shed"] == 0 for r in rows)

    def test_telemetry_carries_replica_and_batch(self, engine):
        """Per-request results expose replica_id/batch_size and the flush
        summary carries the per-replica breakdown (routing-balance
        telemetry; a single scheduler is all replica 0)."""
        graphs = _graphs([10, 11, 12, 13], seed=31)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=50.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            handles = [sched.submit(g) for g in graphs]
            results = [h.result(timeout=RESULT_TIMEOUT) for h in handles]
            stats = sched.stats()
        for h, r in zip(handles, results):
            assert r.replica_id == 0
            assert h.replica_id == 0
            assert r.batch_size >= 1
        assert list(stats["per_replica"]) == ["0"]
        assert stats["per_replica"]["0"]["n_requests"] == len(graphs)


class TestTrafficHarness:
    def test_traffic_is_seeded_and_mixed(self):
        cfg = TrafficConfig(rate_rps=50.0, n_requests=40,
                            size_mix=(SizeClass(6, 12, 1.0),
                                      SizeClass(20, 30, 1.0)),
                            seed=3)
        t1, t2 = make_traffic(cfg), make_traffic(cfg)
        assert [t for t, _ in t1] == [t for t, _ in t2]
        for (_, g1), (_, g2) in zip(t1, t2):
            np.testing.assert_array_equal(g1.coords, g2.coords)
        times = np.asarray([t for t, _ in t1])
        assert (np.diff(times) > 0).all()
        sizes = {g.n_atoms for _, g in t1}
        assert any(s <= 12 for s in sizes) and any(s >= 20 for s in sizes)

    def test_open_loop_end_to_end(self, engine):
        cfg = TrafficConfig(rate_rps=200.0, n_requests=12,
                            size_mix=(SizeClass(6, 16, 1.0),), seed=4)
        sched_cfg = SchedulerConfig(max_batch=4, deadline_ms=10.0,
                                    warmup=False)
        with MicroBatchScheduler(engine, sched_cfg) as sched:
            res = run_open_loop(sched, make_traffic(cfg), rate_rps=200.0)
        s = res.summary()
        assert s["n_requests"] == 12
        assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"] <= s["max_ms"]
        assert s["throughput_rps"] > 0
        assert res.scheduler_stats["n_completed"] == 12

    def test_closed_loop_end_to_end(self, engine):
        graphs = [g for _, g in make_traffic(TrafficConfig(
            rate_rps=1.0, n_requests=8,
            size_mix=(SizeClass(6, 16, 1.0),), seed=5))]
        sched_cfg = SchedulerConfig(max_batch=4, deadline_ms=5.0,
                                    warmup=False)
        with MicroBatchScheduler(engine, sched_cfg) as sched:
            res = run_closed_loop(sched, graphs, concurrency=3)
        assert res.summary()["n_requests"] == 8

    def test_latency_summary_percentile_math(self):
        s = latency_summary([0.010] * 99 + [1.0], span_s=2.0)
        assert s["p50_ms"] == pytest.approx(10.0)
        assert s["p99_ms"] > 10.0
        assert s["throughput_rps"] == pytest.approx(50.0)

    def test_flush_summary_empty(self):
        assert flush_summary([]) == {"n_flushes": 0}
