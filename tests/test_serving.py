"""Tests for repro.serving: bucketing, padding semantics, batched-vs-
reference agreement, and the CPU interpret fallback.

All kernel paths run with interpret=True on CPU (selected automatically by
repro.kernels.ops), so this suite exercises the exact code the engine
serves with when no TPU is present.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import so3krates as so3
from repro.serving import (BucketSpec, Graph, MXU_LANE, QuantizedEngine,
                           ServeConfig, assign_bucket, pad_graphs,
                           plan_batches, quantize_so3_params)
from repro.serving.forward import batched_energy, batched_energy_and_forces

CFG = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2, n_rbf=8,
                          dir_bits=6)


def _graphs(ns, seed=0):
    rng = np.random.default_rng(seed)
    return [Graph(species=rng.integers(0, CFG.n_species, n).astype(np.int32),
                  coords=(rng.normal(size=(n, 3)) * 2.0).astype(np.float32))
            for n in ns]


@pytest.fixture(scope="module")
def qparams_w8():
    params = so3.init_params(jax.random.PRNGKey(0), CFG)
    return quantize_so3_params(params, "w8a8")


class TestBucketing:
    def test_every_graph_gets_an_aligned_bucket(self):
        buckets = [BucketSpec(16, max_batch=8), BucketSpec(32, max_batch=8),
                   BucketSpec(64, max_batch=8)]
        graphs = _graphs([3, 5, 11, 16, 17, 30, 33, 64, 7, 40])
        plans = plan_batches(graphs, buckets)
        covered = sorted(i for p in plans for i in p.graph_indices)
        assert covered == list(range(len(graphs)))
        for p in plans:
            # alignment contract: total rows a multiple of the MXU lane
            assert (p.batch_size * p.bucket.capacity) % MXU_LANE == 0
            for gi in p.graph_indices:
                assert graphs[gi].n_atoms <= p.bucket.capacity

    def test_smallest_fitting_bucket_chosen(self):
        buckets = [BucketSpec(16), BucketSpec(32), BucketSpec(64)]
        assert assign_bucket(10, buckets).capacity == 16
        assert assign_bucket(16, buckets).capacity == 16
        assert assign_bucket(17, buckets).capacity == 32
        assert assign_bucket(64, buckets).capacity == 64

    def test_oversize_graph_raises(self):
        with pytest.raises(ValueError, match="exceeds the largest bucket"):
            assign_bucket(100, [BucketSpec(16), BucketSpec(64)])

    def test_pad_graphs_mask_and_dummy_rows(self):
        graphs = _graphs([5, 9])
        buckets = [BucketSpec(16, max_batch=4)]
        (plan,) = plan_batches(graphs, buckets)
        species, coords, mask = pad_graphs(graphs, plan)
        assert species.shape == (plan.batch_size, 16)
        assert mask[0].sum() == 5 and mask[1].sum() == 9
        # dummy alignment molecules are all-padding
        assert not mask[len(graphs):].any()
        np.testing.assert_array_equal(coords[0, 5:], 0.0)


class TestPaddingSemantics:
    def test_padded_atoms_zero_force_and_energy(self, qparams_w8):
        g = _graphs([10])[0]
        B, cap = 1, 16
        species = np.zeros((B, cap), np.int32)
        coords = np.zeros((B, cap, 3), np.float32)
        mask = np.zeros((B, cap), bool)
        species[0, :10], coords[0, :10], mask[0, :10] = g.species, g.coords, True
        e, f = batched_energy_and_forces(
            qparams_w8, CFG, jnp.asarray(species), jnp.asarray(coords),
            jnp.asarray(mask))
        f = np.asarray(f)
        # forces on padded atoms are exactly zero (energy independent of them)
        np.testing.assert_array_equal(f[0, 10:], 0.0)
        assert np.isfinite(f).all() and np.isfinite(float(e[0]))

    def test_energy_invariant_to_bucket_capacity(self, qparams_w8):
        """The same molecule padded into a larger shape class yields the
        same energy/forces — padding never leaks into results."""
        g = _graphs([12], seed=3)[0]
        out = {}
        for cap in (16, 32):
            species = np.zeros((1, cap), np.int32)
            coords = np.zeros((1, cap, 3), np.float32)
            mask = np.zeros((1, cap), bool)
            species[0, :12], coords[0, :12], mask[0, :12] = \
                g.species, g.coords, True
            e, f = batched_energy_and_forces(
                qparams_w8, CFG, jnp.asarray(species), jnp.asarray(coords),
                jnp.asarray(mask))
            out[cap] = (float(e[0]), np.asarray(f)[0, :12])
        assert abs(out[16][0] - out[32][0]) < 1e-5
        np.testing.assert_allclose(out[16][1], out[32][1], atol=1e-5)

    def test_padded_coords_never_leak(self, qparams_w8):
        """Garbage in the padded coordinate slots must not change results."""
        g = _graphs([8], seed=4)[0]
        cap = 16
        species = np.zeros((1, cap), np.int32)
        mask = np.zeros((1, cap), bool)
        species[0, :8], mask[0, :8] = g.species, True
        outs = []
        for junk in (0.0, 1e3):
            coords = np.full((1, cap, 3), junk, np.float32)
            coords[0, :8] = g.coords
            e, f = batched_energy_and_forces(
                qparams_w8, CFG, jnp.asarray(species), jnp.asarray(coords),
                jnp.asarray(mask))
            outs.append((float(e[0]), np.asarray(f)[0, :8]))
        assert outs[0][0] == pytest.approx(outs[1][0], abs=1e-6)
        np.testing.assert_allclose(outs[0][1], outs[1][1], atol=1e-6)


class TestBatchedMatchesReference:
    @pytest.mark.parametrize("mode", ["w8a8", "w4a8"])
    def test_batched_kernel_vs_per_molecule_reference(self, mode):
        """Batched Pallas path == per-molecule pure-jnp oracle, <= 1e-5
        (fp32 accumulation), for energies AND forces."""
        params = so3.init_params(jax.random.PRNGKey(0), CFG)
        qp = quantize_so3_params(params, mode)
        ns = [5, 9, 14, 16]
        B, cap = 4, 16
        species = np.zeros((B, cap), np.int32)
        coords = np.zeros((B, cap, 3), np.float32)
        mask = np.zeros((B, cap), bool)
        gs = _graphs(ns, seed=1)
        for r, g in enumerate(gs):
            n = g.n_atoms
            species[r, :n], coords[r, :n], mask[r, :n] = \
                g.species, g.coords, True
        e_b, f_b = batched_energy_and_forces(
            qp, CFG, jnp.asarray(species), jnp.asarray(coords),
            jnp.asarray(mask), use_kernels=True)
        for r, g in enumerate(gs):
            e_r, f_r = batched_energy_and_forces(
                qp, CFG, jnp.asarray(species[r:r + 1]),
                jnp.asarray(coords[r:r + 1]), jnp.asarray(mask[r:r + 1]),
                use_kernels=False)
            assert abs(float(e_b[r] - e_r[0])) <= 1e-5
            np.testing.assert_allclose(np.asarray(f_b[r]),
                                       np.asarray(f_r[0]), atol=1e-5)

    def test_fp32_mode_matches_original_model(self):
        """ServeConfig(mode=fp32, no vector quant) reproduces the original
        single-molecule so3krates forward."""
        cfg = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2, n_rbf=8,
                                  quant="none")
        params = so3.init_params(jax.random.PRNGKey(2), cfg)
        qp = quantize_so3_params(params, "fp32")
        g = _graphs([14], seed=5)[0]
        e_ref = float(so3.energy(params, cfg, jnp.asarray(g.species),
                                 jnp.asarray(g.coords)))
        e_srv = batched_energy(qp, cfg, jnp.asarray(g.species[None]),
                               jnp.asarray(g.coords[None]),
                               jnp.ones((1, 14), bool),
                               quant_vectors=False)
        assert abs(float(e_srv[0]) - e_ref) < 1e-4 * max(1.0, abs(e_ref))


class TestEngine:
    def test_cpu_fallback_and_end_to_end(self):
        """The engine auto-selects interpret mode on CPU and produces
        finite, correctly-shaped, input-ordered results."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16, 32), max_batch=8)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        assert engine.backend == "cpu"
        assert engine.interpret  # CPU fallback path is what this suite runs
        graphs = _graphs([5, 20, 9], seed=7)
        results = engine.infer_batch(graphs)
        assert [r.n_atoms for r in results] == [5, 20, 9]
        assert results[1].bucket_capacity == 32
        for r in results:
            assert r.forces.shape == (r.n_atoms, 3)
            assert np.isfinite(r.forces).all() and np.isfinite(r.energy)
        mem = engine.memory_report()
        assert mem["served_bytes"] < mem["fp32_bytes"]

    def test_engine_matches_direct_forward(self):
        """infer_batch (bucketed, dummy-padded) == calling the forward
        directly on a hand-padded batch."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        graphs = _graphs([6, 11], seed=9)
        results = engine.infer_batch(graphs)
        for g, r in zip(graphs, results):
            cap = 16
            species = np.zeros((1, cap), np.int32)
            coords = np.zeros((1, cap, 3), np.float32)
            mask = np.zeros((1, cap), bool)
            n = g.n_atoms
            species[0, :n], coords[0, :n], mask[0, :n] = \
                g.species, g.coords, True
            e, f = batched_energy_and_forces(
                engine.qparams, CFG, jnp.asarray(species),
                jnp.asarray(coords), jnp.asarray(mask),
                engine._codebook)
            assert abs(float(e[0]) - r.energy) <= 1e-5
            np.testing.assert_allclose(np.asarray(f)[0, :n], r.forces,
                                       atol=1e-5)

    def test_warmup_compiles_shape_classes(self):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        engine.warmup()
        # max_batch=8, capacity 16: every admissible batch class is the
        # single aligned class (8, 16) -> exactly one compiled shape
        # (default path="auto" keeps 16-atom buckets dense — the edge
        # list is not profitable there — so no sparse shape is warmed)
        assert engine.compiled_shapes == {(8, 16)}
        # a warmed engine never compiles a new shape under traffic
        engine.infer_batch(_graphs([5, 9, 11], seed=13))
        assert engine.compiled_shapes == {(8, 16)}

    def test_isolated_atoms_finite_forces(self):
        """Atoms with no neighbours inside the cutoff keep v == 0 through
        every layer; the NaN-safe norm in core.mddq must keep their force
        gradient finite (regression: 0/0 in d||v||/dv at v = 0)."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        single = Graph(species=np.array([1], np.int32),
                       coords=np.zeros((1, 3), np.float32))
        far_pair = Graph(species=np.array([1, 1], np.int32),
                         coords=np.array([[0, 0, 0], [50, 0, 0]],
                                         np.float32))
        for r in engine.infer_batch([single, far_pair]):
            assert np.isfinite(r.energy)
            assert np.isfinite(r.forces).all()

    def test_lee_diagnostic_masks_padding(self):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        diag = engine.lee_diagnostic(_graphs([7, 12], seed=11),
                                     jax.random.PRNGKey(0), n_rotations=2)
        assert np.isfinite(diag["lee_mean"])
        assert diag["lee_mean"] >= 0.0
