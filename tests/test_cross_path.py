"""Cross-path consistency golden test (ISSUE 6).

The repo now has four surfaces that evaluate the same quantized model:
direct ``QuantizedEngine.infer_batch``, the micro-batching scheduler
(``repro.server``), the multi-replica ``ClusterPool`` (``repro.cluster``),
and the MD engine's force evaluation (``repro.md``). Each surface has
its own identity tests against its immediate neighbour; this module
pins all four to each other on ONE molecule batch, per quantization
mode — so a numeric divergence introduced in any one layer (batch
assembly, edge building, replica construction, artifact round-trip)
fails a single obvious test instead of surfacing as a subtle
cross-subsystem drift.

All surfaces are forced onto the sparse edge-list path: the MD engine
only has that path, and sparse-vs-dense already has its own 1e-5
agreement budget in test_sparse_serving — cross-path identity is the
tighter <= 1e-6 claim about the SAME forward reached four ways. The MD
leg runs ``skin=0`` so its (refined) skin list is exactly the fresh
cutoff edge list the serving builder produces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterPool
from repro.md import MDConfig, MDEngine, pad_replicas
from repro.models import so3krates as so3
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.server import MicroBatchScheduler, SchedulerConfig

CFG = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1, n_rbf=4,
                          dir_bits=6, cutoff=3.0)
NS = [7, 16, 11, 5]
RESULT_TIMEOUT = 300   # generous: CPU-interpret compiles inside flushes
ATOL = 1e-6


def _graphs(ns, seed=21, density=0.1):
    rng = np.random.default_rng(seed)
    out = []
    for n in ns:
        side = (n / density) ** (1.0 / 3.0)
        out.append(Graph(
            species=rng.integers(0, CFG.n_species, n).astype(np.int32),
            coords=rng.uniform(0, side, (n, 3)).astype(np.float32)))
    return out


@pytest.mark.parametrize("mode", ["w8a8", "w4a8"])
def test_all_paths_agree(mode):
    serve = ServeConfig(mode=mode, bucket_sizes=(16,), max_batch=4,
                        path="sparse")
    graphs = _graphs(NS)

    # surface 1: direct engine (the reference the other three match)
    engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
    direct = engine.infer_batch(graphs)
    assert all(r.path == "sparse" for r in direct)

    # surface 2: micro-batching scheduler over the same engine — flush
    # grouping must be unobservable in the numbers
    cfg = SchedulerConfig(max_batch=4, deadline_ms=5.0, warmup=False)
    with MicroBatchScheduler(engine, cfg) as sched:
        handles = [sched.submit(g) for g in graphs]
        scheduled = [h.result(timeout=RESULT_TIMEOUT) for h in handles]

    # surface 3: 2-replica cluster pool built from the same seed — which
    # replica served a molecule must be unobservable too
    pool = ClusterPool.from_config(
        CFG, serve=serve,
        cluster=ClusterConfig(n_replicas=2, deadline_ms=5.0, max_batch=4),
        seed=0)
    try:
        pooled = pool.infer(graphs, timeout=RESULT_TIMEOUT)
    finally:
        pool.close()

    # surface 4: one MD-engine force evaluation per molecule (init_state
    # evaluates e_pot/forces at the given coords through the MD forward)
    params = so3.init_params(jax.random.PRNGKey(0), CFG)
    md = MDEngine(CFG, params, md=MDConfig(mode=mode, skin=0.0))
    masses = np.full(16, 12.0, np.float32)
    md_results = []
    for g in graphs:
        spec, co, mask = pad_replicas(g.species, g.coords, 1, capacity=16)
        st = md.init_state(jax.random.PRNGKey(0), spec, co, mask, masses,
                           200.0)
        md_results.append((float(st.e_pot[0]),
                           np.asarray(st.forces)[0, :g.n_atoms]))

    for g, rd, rs, rp, (e_md, f_md) in zip(graphs, direct, scheduled,
                                           pooled, md_results):
        for label, e, f in (("scheduler", rs.energy, rs.forces),
                            ("cluster", rp.energy, rp.forces),
                            ("md", e_md, f_md)):
            assert abs(e - rd.energy) <= ATOL, (
                f"{label} energy diverged from direct infer_batch for "
                f"n={g.n_atoms}: {e!r} vs {rd.energy!r} ({mode})")
            np.testing.assert_allclose(
                f, rd.forces, atol=ATOL,
                err_msg=f"{label} forces diverged from direct "
                        f"infer_batch for n={g.n_atoms} ({mode})")
