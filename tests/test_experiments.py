"""Tests for the unified experiment runner + regression gates (ISSUE 6).

Three layers, cheapest first:

* **committed artifacts** — every ``BENCH_*.json`` in the repo parses
  under the ``repro.bench/1`` schema, ``BENCH_baselines.json`` under the
  baselines schema, and each committed document gates *clean* against
  the committed baselines (the reference numbers must agree with the
  gate table derived from them — a drifted hand-edit fails here);
* **gate semantics** — unit tests of ``diff_against_baselines`` on
  synthetic documents: hard le/ge/eq violations, ``smoke_ok`` policy,
  soft tolerance bands, core-count skip, missing-metric and
  missing-experiment handling;
* **the runner itself** — ``python -m benchmarks.run --smoke`` per
  domain (shrunk further via ``--extra``) emits a schema-valid combined
  document, and the ``--diff-only`` CLI exits 0 on the committed
  numbers / exits 2 when gating against a corrupted baselines copy —
  the acceptance demonstration that a regression actually fails CI.
"""
import copy
import json
import os
import subprocess
import sys

import pytest

from benchmarks import experiments, schema
from benchmarks.schema import ExperimentResult, Metric

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOMAIN_DOCS = {d: experiments.DOMAINS[d]["document"]
               for d in experiments.DOMAIN_ORDER}
BASELINES = os.path.join(REPO, experiments.BASELINES_PATH)


def _run_cli(argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *argv],
                          cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=timeout)


class TestCommittedArtifacts:
    @pytest.mark.parametrize("domain", sorted(DOMAIN_DOCS))
    def test_domain_document_valid(self, domain):
        doc = schema.load_document(os.path.join(REPO, DOMAIN_DOCS[domain]))
        assert all(r["experiment"]["domain"] == domain
                   for r in doc["results"])
        assert all(not r["experiment"]["smoke"] for r in doc["results"]), \
            "committed reference documents must be full-size runs"

    def test_baselines_valid(self):
        baselines = schema.load_baselines(BASELINES)
        assert baselines["gates"], "baselines must gate something"

    @pytest.mark.parametrize("domain", sorted(DOMAIN_DOCS))
    def test_domain_document_gates_clean(self, domain):
        """The gate table was derived from these documents — they must
        pass it. Fails when someone edits a BENCH_*.json or the gate
        policy without refreshing BENCH_baselines.json."""
        doc = schema.load_document(os.path.join(REPO, DOMAIN_DOCS[domain]))
        baselines = schema.load_baselines(BASELINES)
        report = schema.diff_against_baselines(
            doc, baselines,
            expected_fingerprints=[r["fingerprint"]
                                   for r in doc["results"]])
        assert report.ok, report.render()
        assert report.counts()["pass"] > 0

    def test_baselines_cover_every_enumerated_config(self):
        """Every config the default full suite would run has a baseline
        entry — a new experiment axis must come with reference numbers."""
        baselines = schema.load_baselines(BASELINES)
        for c in experiments.enumerate_experiments():
            assert c.fingerprint in baselines["gates"], c.fingerprint


# -- gate semantics on synthetic documents -----------------------------------

_FP = "unit:w8a8:dense:r1:d1"


def _result(metrics, smoke=False, n_cores=2, fp=_FP):
    return ExperimentResult(
        experiment={"domain": "unit", "mode": "w8a8", "path": "dense",
                    "replicas": 1, "devices": 1, "smoke": smoke},
        fingerprint=fp,
        hardware={"backend": "cpu", "n_cores": n_cores, "n_devices": 1,
                  "machine": "x86_64"},
        metrics=metrics)


def _doc(*results):
    return schema.bench_document(results, generated_by="test")


def _metrics(drift=1.0, dropped=0.0, thr=100.0, lat=5.0):
    return [
        Metric("drift", drift, "x", kind="hard",
               gate={"op": "le", "bound": 2.0}),
        Metric("dropped", dropped, "count", kind="hard",
               gate={"op": "eq", "bound": 0.0}, smoke_ok=False),
        Metric("thr", thr, "mol/s", kind="soft"),
        Metric("lat", lat, "ms", kind="soft", direction="lower"),
        Metric("note", 1.0, "", kind="info"),
    ]


@pytest.fixture(scope="module")
def baselines():
    return schema.baselines_from_documents([_doc(_result(_metrics()))],
                                           source="test")


class TestGateSemantics:
    def test_identical_rerun_is_clean(self, baselines):
        report = schema.diff_against_baselines(_doc(_result(_metrics())),
                                               baselines)
        assert report.ok
        # hard drift + hard dropped + 2 soft gates all compared
        assert report.counts() == {"pass": 4, "fail": 0, "skip": 0}

    @pytest.mark.parametrize("kwargs,bad", [
        ({"drift": 2.5}, "drift"),       # le bound exceeded
        ({"dropped": 1.0}, "dropped"),   # eq count no longer zero
        ({"thr": 50.0}, "thr"),          # > 40% below soft baseline
        ({"lat": 8.0}, "lat"),           # > 40% above lower-is-better
    ])
    def test_regressions_fail(self, baselines, kwargs, bad):
        report = schema.diff_against_baselines(
            _doc(_result(_metrics(**kwargs))), baselines)
        assert not report.ok
        assert [c.metric for c in report.checks
                if c.status == "fail"] == [bad]

    def test_soft_band_tolerates_noise(self, baselines):
        report = schema.diff_against_baselines(
            _doc(_result(_metrics(thr=70.0, lat=6.5))), baselines)
        assert report.ok

    def test_smoke_skips_soft_and_smoke_unsafe_hard_gates(self, baselines):
        # dropped=1 would hard-fail at full size, but the metric is
        # marked smoke_ok=False; thr/lat are wild but soft gates never
        # apply on smoke. Only the drift hard gate still guards.
        report = schema.diff_against_baselines(
            _doc(_result(_metrics(dropped=1.0, thr=1.0, lat=500.0),
                         smoke=True)), baselines)
        assert report.ok
        assert report.counts() == {"pass": 1, "fail": 0, "skip": 3}

    def test_smoke_still_enforces_hard_gates(self, baselines):
        report = schema.diff_against_baselines(
            _doc(_result(_metrics(drift=2.5), smoke=True)), baselines)
        assert not report.ok

    def test_core_count_mismatch_skips_soft_gates(self, baselines):
        report = schema.diff_against_baselines(
            _doc(_result(_metrics(thr=1.0, lat=500.0), n_cores=1)),
            baselines)
        assert report.ok
        skipped = [c.metric for c in report.checks if c.status == "skip"]
        assert sorted(skipped) == ["lat", "thr"]

    def test_missing_experiment_fails_when_expected(self, baselines):
        other = _result(_metrics(), fp="other:w8a8:dense:r1:d1")
        report = schema.diff_against_baselines(_doc(other), baselines,
                                               expected_fingerprints=[_FP])
        assert not report.ok

    def test_unselected_experiment_skips(self, baselines):
        other = _result(_metrics(), fp="other:w8a8:dense:r1:d1")
        report = schema.diff_against_baselines(
            _doc(other), baselines,
            expected_fingerprints=["other:w8a8:dense:r1:d1"])
        assert report.ok

    def test_missing_hard_metric_fails_full_but_skips_smoke(self, baselines):
        for smoke, ok in ((False, False), (True, True)):
            partial = _result([Metric("note", 1.0, "", kind="info")],
                              smoke=smoke)
            report = schema.diff_against_baselines(_doc(partial), baselines)
            assert report.ok is ok, (smoke, report.render())


# -- the runner CLI ----------------------------------------------------------

# per-domain overrides shrinking *below* smoke size: these runs only
# prove end-to-end plumbing + schema validity, not performance
_EXTRAS = {
    "serving": {"graphs": 2, "buckets": [16]},
    "md": {"steps": 20},
    "server": {"requests": 10, "loads": [1.5]},
    "cluster": {"requests": 30},
    "kernels": {"reps": 1},
    # 10 x 20-step chunks: the smallest trajectory the fixed fault
    # schedule (boundaries 2-6, kill point 7) can run against
    "sessions": {"steps": 200, "chunk_steps": 20, "record_every": 20,
                 "oneshots": 2},
    # 2 stalls stay: the stalls-detected gate (>= 2) is hard at smoke
    "guardrails": {"escalation_mols": 3, "requests": 8, "poison_every": 4,
                   "overhead_batches": 5, "stalls": 2, "stall_traffic": 2,
                   "md_steps": 40},
    # 16 requests keep the kill (at ~5) and swap (at ~10) inside the
    # replay and 2 poisoned requests still escalate
    "obs": {"requests": 16, "poison_every": 8, "overhead_waves": 2,
            "wave_size": 4},
}


class TestRunnerCLI:
    @pytest.mark.parametrize("domain", experiments.DOMAIN_ORDER)
    def test_smoke_emits_schema_valid_document(self, domain, tmp_path):
        out = tmp_path / "out.json"
        proc = _run_cli(["--smoke", "--domains", domain,
                         "--modes", "w8a8", "--out", str(out),
                         "--work-dir", str(tmp_path / "work"),
                         "--extra", json.dumps(_EXTRAS[domain])])
        assert proc.returncode == 0, proc.stderr[-2000:]
        doc = schema.load_document(str(out))       # validates the schema
        (r,) = doc["results"]
        assert r["experiment"]["domain"] == domain
        assert r["experiment"]["smoke"] is True
        assert r["metrics"]

    def test_list_enumerates_all_domains(self):
        proc = _run_cli(["--list"])
        assert proc.returncode == 0, proc.stderr[-2000:]
        for domain in experiments.DOMAIN_ORDER:
            assert f"{domain}:" in proc.stdout

    def test_diff_only_committed_numbers_exit_zero(self):
        proc = _run_cli(["--diff-only",
                         "--results", DOMAIN_DOCS["md"],
                         "--baselines", experiments.BASELINES_PATH])
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "all gates clean" in proc.stdout

    def test_corrupted_baseline_exits_nonzero(self, tmp_path):
        """The acceptance demonstration: tighten one committed hard
        bound past its measured value and the runner must exit 2."""
        with open(BASELINES) as f:
            corrupted = json.load(f)
        md_fp = [fp for fp in corrupted["gates"] if fp.startswith("md:")][0]
        gates = corrupted["gates"][md_fp]["metrics"]
        name, gate = next((n, g) for n, g in sorted(gates.items())
                          if g["kind"] == "hard")
        gate["bound"] = {"le": gate["measured"] - 1.0,
                         "ge": gate["measured"] + 1.0,
                         "eq": gate["measured"] + 1.0}[gate["op"]]
        bad = tmp_path / "baselines.json"
        bad.write_text(json.dumps(corrupted))
        proc = _run_cli(["--diff-only",
                         "--results", DOMAIN_DOCS["md"],
                         "--baselines", str(bad)])
        assert proc.returncode == 2, proc.stdout + proc.stderr
        assert "REGRESSION" in proc.stderr
        assert name in proc.stdout
