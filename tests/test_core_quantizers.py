"""Unit + property tests for the invariant-branch linear quantizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantizers as Q

jax.config.update("jax_enable_x64", False)


class TestScaleAndRoundTrip:
    def test_qmax(self):
        assert Q.qmax(8) == 127
        assert Q.qmax(4) == 7

    def test_abs_max_scale_per_tensor(self):
        x = jnp.array([[-4.0, 2.0], [1.0, 3.0]])
        s = Q.abs_max_scale(x, bits=8)
        assert np.isclose(float(s), 4.0 / 127)

    def test_abs_max_scale_per_channel(self):
        x = jnp.array([[-4.0, 2.0], [1.0, 3.0]])
        s = Q.abs_max_scale(x, bits=8, channel_axis=1)
        assert s.shape == (1, 2)
        np.testing.assert_allclose(np.asarray(s)[0], [4.0 / 127, 3.0 / 127])

    def test_quant_dequant_error_bound(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (256, 64))
        s = Q.abs_max_scale(x, 8)
        err = jnp.abs(Q.dequantize(Q.quantize(x, s, 8), s) - x)
        assert float(jnp.max(err)) <= float(s) / 2 + 1e-7

    def test_fake_quant_idempotent(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (64,))
        s = Q.abs_max_scale(x, 8)
        y = Q.fake_quant(x, s, 8)
        z = Q.fake_quant(y, s, 8)
        np.testing.assert_allclose(np.asarray(y), np.asarray(z), atol=1e-6)

    @given(st.integers(2, 8))
    @settings(max_examples=7, deadline=None)
    def test_grid_size_property(self, bits):
        x = jnp.linspace(-1, 1, 1001)
        s = Q.abs_max_scale(x, bits)
        y = np.unique(np.asarray(Q.fake_quant(x, s, bits)))
        assert len(y) <= 2 ** bits  # symmetric grid has <= 2^b - 1 levels


class TestSTE:
    def test_fake_quant_ste_gradient_is_identity_inside_range(self):
        x = jnp.array([0.1, -0.2, 0.3])
        g = jax.grad(lambda v: jnp.sum(Q.fake_quant_ste(v, 8, scale=jnp.array(0.01))))(x)
        np.testing.assert_allclose(np.asarray(g), np.ones(3), atol=1e-6)

    def test_fake_quant_ste_gradient_zero_outside_range(self):
        # value far beyond the representable range -> clipped -> zero grad
        x = jnp.array([100.0])
        g = jax.grad(lambda v: jnp.sum(Q.fake_quant_ste(v, 8, scale=jnp.array(0.01))))(x)
        np.testing.assert_allclose(np.asarray(g), np.zeros(1), atol=1e-6)


class TestInt4Packing:
    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_pack_unpack_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        q = rng.integers(-8, 8, size=(4, 16)).astype(np.int8)
        out = np.asarray(Q.unpack_int4(Q.pack_int4(jnp.asarray(q))))
        np.testing.assert_array_equal(out, q)

    def test_pack_halves_bytes(self):
        q = jnp.zeros((8, 32), jnp.int8)
        assert Q.pack_int4(q).shape == (8, 16)

    def test_odd_dim_raises(self):
        with pytest.raises(ValueError):
            Q.pack_int4(jnp.zeros((3, 5), jnp.int8))


class TestLogMagnitude:
    def test_roundtrip_relative_error(self):
        m = jnp.array([1e-4, 1e-2, 1.0, 10.0, 500.0])
        code = Q.quantize_log_magnitude(m, 8)
        m2 = Q.dequantize_log_magnitude(code, 8)
        rel = np.abs(np.asarray(m2 / m) - 1.0)
        # log grid with 256 levels over [1e-6, 1e3]: step = ln(1e9)/255 ~ 0.081
        assert rel.max() < 0.05

    def test_monotone(self):
        m = jnp.linspace(1e-3, 100.0, 512)
        code = np.asarray(Q.quantize_log_magnitude(m, 8))
        assert (np.diff(code) >= 0).all()
