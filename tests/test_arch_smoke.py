"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.lm import transformer as tfm
from repro.models.lm.config import LMConfig

ARCHS = list(configs.ARCH_IDS)

B, S = 2, 64


def _batch(cfg: LMConfig, key):
    k1, k2 = jax.random.split(key)
    if cfg.frontend == "token":
        tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab)
        return {"tokens": tokens,
                "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab)}
    # modality stub: precomputed frame/patch embeddings
    return {"embeds": jax.random.normal(k1, (B, S, cfg.d_model)),
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.get_smoke_config(arch)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, attn_chunk_q=32,
                              ssm_chunk=min(cfg.ssm_chunk, 32))
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = tfm.forward(params, cfg, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"

    loss, grads = jax.value_and_grad(tfm.lm_loss)(params, cfg, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.get_smoke_config(arch)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    cache = tfm.init_cache(cfg, batch=B, seq=32)
    if cfg.frontend == "token":
        tok = jnp.zeros((B, 1), jnp.int32)
    else:
        tok = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
    logits, cache2 = tfm.decode_step(params, cfg, cache, tok,
                                     jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN decode"
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_quantized_modes(arch):
    """QAT and serve W8A8 modes run and stay finite."""
    import dataclasses
    cfg = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, attn_chunk_q=32,
                              ssm_chunk=min(cfg.ssm_chunk, 32))
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    qat_cfg = dataclasses.replace(cfg, quant_mode="qat_w4a8")
    loss = tfm.lm_loss(params, qat_cfg, batch)
    assert np.isfinite(float(loss))

    # kv-quantized decode
    kv_cfg = dataclasses.replace(cfg, kv_quant=True)
    cache = tfm.init_cache(kv_cfg, batch=B, seq=16)
    tok = (jnp.zeros((B, 1), jnp.int32) if cfg.frontend == "token"
           else jnp.zeros((B, 1, cfg.d_model)))
    logits, _ = tfm.decode_step(params, kv_cfg, cache, tok,
                                jnp.asarray(0, jnp.int32))
    assert np.isfinite(np.asarray(logits)).all()


def test_full_configs_param_counts():
    """Sanity: analytic param counts are in the advertised ballpark."""
    expect = {
        "zamba2-1.2b": (0.8e9, 1.8e9),
        "qwen1.5-110b": (90e9, 130e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "nemotron-4-15b": (12e9, 18e9),
        "musicgen-large": (2.5e9, 3.8e9),
        "qwen3-moe-30b-a3b": (25e9, 35e9),
        "moonshot-v1-16b-a3b": (24e9, 30e9),  # 48L assigned (published has 27L)
        "chameleon-34b": (30e9, 40e9),
        "xlstm-1.3b": (0.8e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
