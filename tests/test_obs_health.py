"""Tests for the active health plane (ISSUE 10): SLO burn-rate
alerting, anomaly detectors, the Chrome-trace timeline export, and the
collection-plane hardening satellites (label-cardinality bounding,
trace-sink rotation, guaranteed exporter shutdown).

Two layers:

* property-style unit tests drive :class:`SLOEvaluator` /
  :class:`AnomalyMonitor` over synthetic metric streams with *known*
  breach points — the alert must fire at (and only at) the engineered
  step, re-arm on recovery, and stay silent on clean streams;
* a seeded 4-replica chaos replay injects one fault per class (poison
  escalations, in-flight kill, watchdog stall, drifting MD session)
  and asserts the exact attributed alert set fires — and that an
  identical clean arm fires nothing. ``benchmarks/obs_bench.py`` gates
  the same invariant at scale.
"""
import dataclasses
import json
import math
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.obs import (REGISTRY, Alert, AlertBus, AnomalyMonitor,
                       CompileStorm, EscalationTrend, EwmaZScore,
                       HealthMonitor, JsonlTraceSink, MetricsRegistry,
                       PeriodicExporter, QueueDepthRunaway, ReplicaLatencySkew,
                       RequestTrace, SLO, SLOEvaluator, chrome_trace,
                       default_detectors, default_slos, robust_zscore,
                       validate_chrome_trace)
from repro.obs.metrics import OVERFLOW_LABELS

WAIT_S = 600
REPO = Path(__file__).resolve().parent.parent


def _bus():
    """Fresh bus on a throwaway registry, with a capture list."""
    reg = MetricsRegistry()
    bus = AlertBus(registry=reg)
    fired = []
    bus.subscribe(fired.append)
    return bus, fired


# -- burn-rate SLO evaluation (synthetic streams, synthetic clock) ------------

class TestBurnRate:
    RATIO = SLO(name="err_rate", kind="ratio",
                bad="reqs", bad_where={"event": "bad"},
                total="reqs", total_where={"event": "all"},
                objective=0.01, burn_threshold=10.0,
                fast_window_s=10.0, slow_window_s=30.0)

    def test_breach_fires_once_at_the_engineered_step(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        ev = SLOEvaluator([self.RATIO], registry=reg, bus=bus)
        all_c = reg.counter("reqs", event="all")
        bad_c = reg.counter("reqs", event="bad")
        breach_t = 41
        for t in range(80):
            all_c.inc(10)
            if t >= breach_t:
                bad_c.inc(5)          # 50% bad from t=41 on
            ev.step(now=float(t))
            if t < breach_t:
                assert not fired, f"false positive at t={t}"
        # both windows must burn >= 10x: the slow (30s) window needs
        # several bad seconds accumulated, so the fire lands after the
        # injection but within one slow window of it
        assert len(fired) == 1
        alert = fired[0]
        assert alert.name == "err_rate" and alert.source == "slo"
        assert breach_t < alert.t <= breach_t + 30
        assert alert.evidence["fast_burn"] >= 10.0
        assert alert.evidence["slow_burn"] >= 10.0
        assert alert.evidence["slo_kind"] == "ratio"

    def test_strict_mode_waits_for_slow_window_coverage(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        ev = SLOEvaluator([self.RATIO], registry=reg, bus=bus)
        all_c = reg.counter("reqs", event="all")
        bad_c = reg.counter("reqs", event="bad")
        for t in range(20):               # 100% bad, but only 20s of
            all_c.inc(10)                 # history vs a 30s slow window
            bad_c.inc(10)
            ev.step(now=float(t))
        assert fired == []
        assert ev.status()["err_rate"]["evaluable"] is False

    def test_allow_partial_evaluates_early(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        slo = dataclasses.replace(self.RATIO, allow_partial=True)
        ev = SLOEvaluator([slo], registry=reg, bus=bus)
        all_c = reg.counter("reqs", event="all")
        bad_c = reg.counter("reqs", event="bad")
        for t in range(5):
            all_c.inc(10)
            bad_c.inc(10)
            ev.step(now=float(t))
        assert len(fired) == 1            # rates over available history

    def test_rearm_after_recovery_fires_again(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        ev = SLOEvaluator([self.RATIO], registry=reg, bus=bus)
        all_c = reg.counter("reqs", event="all")
        bad_c = reg.counter("reqs", event="bad")
        phases = [(40, 0.0), (20, 5.0), (60, 0.0), (20, 5.0), (60, 0.0)]
        t = 0
        for steps, bad_rate in phases:
            for _ in range(steps):
                all_c.inc(10)
                if bad_rate:
                    bad_c.inc(bad_rate)
                ev.step(now=float(t))
                t += 1
        assert [a.name for a in fired] == ["err_rate", "err_rate"]
        # recovered in between: the status gauge dropped back to 0
        assert reg.gauge("slo_breached", slo="err_rate").value == 0.0

    def test_event_slo_arms_baseline_then_fires_per_burst(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        slo = SLO(name="deaths", kind="event", metric="pool_events_total",
                  where={"event": "replica_failure"})
        ev = SLOEvaluator([slo], registry=reg, bus=bus)
        c = reg.counter("pool_events_total", event="replica_failure")
        c.inc(7)                          # pre-existing: must never fire
        ev.step(now=0.0)
        assert fired == []
        c.inc()                           # a fresh death
        ev.step(now=1.0)
        assert [a.name for a in fired] == ["deaths"]
        ev.step(now=2.0)                  # quiet: clears (edge re-arms)
        c.inc()
        ev.step(now=3.0)
        assert [a.name for a in fired] == ["deaths", "deaths"]

    def test_level_slo_fires_and_clears_with_the_gauge(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        slo = SLO(name="drift", kind="level",
                  metric="md_energy_drift_ratio", objective=1.0)
        ev = SLOEvaluator([slo], registry=reg, bus=bus)
        ev.step(now=0.0)                  # gauge unwritten: not evaluable
        assert ev.status()["drift"]["evaluable"] is False
        reg.gauge("md_energy_drift_ratio", mode="w8a8").set(3.5)
        ev.step(now=1.0)
        assert [a.name for a in fired] == ["drift"]
        assert fired[0].value == 3.5
        reg.gauge("md_energy_drift_ratio", mode="w8a8").set(0.2)
        ev.step(now=2.0)
        assert ev.status()["drift"]["breached"] is False

    def test_quantile_slo_window_ages_out_old_storm(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        slo = SLO(name="p99", kind="quantile",
                  metric="serve_request_latency_seconds",
                  where={"kind": "request"}, q=0.99, objective=0.5,
                  min_events=20, fast_window_s=10.0, slow_window_s=30.0,
                  allow_partial=True)
        ev = SLOEvaluator([slo], registry=reg, bus=bus)
        h = reg.histogram("serve_request_latency_seconds", kind="request",
                          bucket="16")
        ev.step(now=0.0)
        for _ in range(30):               # the storm: p99 ~ 2s
            h.observe(2.0)
        ev.step(now=1.0)
        assert [a.name for a in fired] == ["p99"]
        assert fired[0].value > 0.5
        # fast traffic only from t=50 on: the storm ages out of both
        # windows and the windowed p99 recovers (a cumulative histogram
        # would hold p99 ~ 2s forever)
        for t in range(50, 90):
            for _ in range(5):
                h.observe(0.001)
            ev.step(now=float(t))
        st = ev.status()["p99"]
        assert st["breached"] is False
        assert st["value"] < 0.5
        assert len(fired) == 1            # no re-fire after recovery

    def test_duplicate_slo_names_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            SLOEvaluator([self.RATIO, self.RATIO])

    def test_default_catalogue_shape(self):
        slos = default_slos()
        names = {s.name for s in slos}
        assert names == {"latency_p99", "shed_rate", "escalation_rate",
                         "session_frame_loss", "md_energy_drift",
                         "lee_probe_level", "replica_failure",
                         "replica_stall"}
        for s in slos:
            assert s.runbook, f"SLO {s.name} has no runbook"


# -- evaluator hardening (REVIEW regressions) ---------------------------------

class TestEvalHardening:
    def test_quantile_from_buckets_handles_underflow_key(self):
        from repro.obs.slo import quantile_from_buckets
        # "u" (underflow) alongside numeric indices must not TypeError
        # and must sort below every index
        assert quantile_from_buckets({"u": 1, "3": 5}, 0.99) > 0.0
        assert quantile_from_buckets({"u": 10, "3": 1}, 0.5) == 0.0
        assert quantile_from_buckets({"u": 4}, 0.99) == 0.0

    def test_underflow_observation_does_not_kill_the_catalogue(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        p99 = SLO(name="p99", kind="quantile",
                  metric="serve_request_latency_seconds", q=0.99,
                  objective=0.5, min_events=1, fast_window_s=10.0,
                  slow_window_s=30.0, allow_partial=True)
        drift = SLO(name="drift", kind="level",
                    metric="md_energy_drift_ratio", objective=1.0)
        ev = SLOEvaluator([p99, drift], registry=reg, bus=bus)
        h = reg.histogram("serve_request_latency_seconds")
        reg.gauge("md_energy_drift_ratio").set(3.0)
        ev.step(now=0.0)
        h.observe(0.0)                    # zero-duration sample: "u" bucket
        h.observe(2.0)
        ev.step(now=1.0)
        # both SLOs evaluated: p99 sees the 2s sample, drift still fires
        assert {a.name for a in fired} == {"p99", "drift"}

    def test_one_broken_slo_isolated_and_counted(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        good = SLO(name="drift", kind="level",
                   metric="md_energy_drift_ratio", objective=1.0)
        bad = SLO(name="boom", kind="level", metric="whatever")
        ev = SLOEvaluator([bad, good], registry=reg, bus=bus)
        ev._EVAL = dict(ev._EVAL)
        orig = ev._EVAL["level"]
        ev._EVAL["level"] = (lambda self, slo: (_ for _ in ()).throw(
            RuntimeError("bad slo")) if slo.name == "boom"
            else orig(self, slo))
        reg.gauge("md_energy_drift_ratio").set(3.0)
        ev.step(now=0.0)
        # the healthy SLO after the broken one still evaluated + fired
        assert [a.name for a in fired] == ["drift"]
        st = ev.status()["boom"]
        assert st["errored"] is True and "bad slo" in st["error"]
        assert reg.counter("repro_obs_health_eval_errors_total",
                           stepper="slo", slo="boom").value == 1.0

    def test_monitor_counts_dead_stepper_instead_of_silence(self):
        reg = MetricsRegistry()

        class Broken:
            registry = reg
            def step(self, now=None):
                raise RuntimeError("stepper died")

        fired_steps = []

        class Healthy:
            def step(self, now=None):
                fired_steps.append(now)
                return []

        mon = HealthMonitor([Broken(), Healthy()], interval_s=1.0)
        mon.step_all(now=0.0)
        assert fired_steps == [0.0]       # later steppers still ran
        assert reg.counter("repro_obs_health_eval_errors_total",
                           stepper="Broken").value == 1.0

    def test_ratio_min_events_zero_empty_window_is_safe(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        slo = SLO(name="r0", kind="ratio", bad="bad_total",
                  total="req_total", objective=0.01, min_events=0,
                  fast_window_s=10.0, slow_window_s=30.0,
                  allow_partial=True)
        ev = SLOEvaluator([slo], registry=reg, bus=bus)
        reg.counter("req_total")          # instruments exist, never bumped
        reg.counter("bad_total")
        for t in range(5):
            ev.step(now=float(t))         # windowed total == 0
        assert fired == []
        assert ev.status()["r0"].get("errored") is not True


# -- anomaly statistics --------------------------------------------------------

class TestStats:
    def test_ewma_scores_spike_against_pre_spike_baseline(self):
        z = EwmaZScore(alpha=0.3, min_points=3)
        for x in (10.0, 10.5, 9.5, 10.2, 9.8):
            assert abs(z.score(x)) < 5.0
            z.update(x)
        assert z.score(100.0) > 10.0      # judged before folding in
        assert abs(z.mean - 10.0) < 1.0

    def test_ewma_needs_min_points(self):
        z = EwmaZScore(min_points=3)
        z.update(1.0)
        z.update(1.0)
        assert z.score(1000.0) == 0.0     # not warmed up yet

    def test_robust_zscore_constant_baseline_semantics(self):
        assert robust_zscore([2.0, 2.0, 2.0, 2.0], 2.0) == 0.0
        assert robust_zscore([2.0, 2.0, 2.0, 2.0], 9.0) == math.inf
        assert robust_zscore([2.0, 2.0, 2.0, 2.0], -9.0) == -math.inf
        assert robust_zscore([], 5.0) == 0.0

    def test_robust_zscore_scales_by_mad(self):
        xs = [1.0, 2.0, 3.0, 4.0, 5.0]    # median 3, MAD 1
        assert robust_zscore(xs, 3.0) == pytest.approx(0.0)
        assert robust_zscore(xs, 3.0 + 1.4826) == pytest.approx(1.0)


# -- anomaly detectors over synthetic registry streams ------------------------

class TestDetectors:
    def test_queue_depth_runaway_fires_on_growth_not_level(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([QueueDepthRunaway()], registry=reg, bus=bus)
        g = reg.gauge("cluster_queue_depth", replica="0")
        for t in range(10):               # flat low depth: silent
            g.set(2.0)
            mon.step(now=float(t))
        assert fired == []
        for t, depth in enumerate((10.0, 14.0, 19.0, 25.0, 33.0), 10):
            g.set(depth)
            mon.step(now=float(t))
        names = [a.name for a in fired]
        assert names == ["queue_depth_runaway"]   # edge-triggered: once
        assert fired[0].severity == "page"
        assert fired[0].evidence["depth"] >= 8.0

    def test_queue_depth_high_but_flat_is_silent(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([QueueDepthRunaway()], registry=reg, bus=bus)
        g = reg.gauge("cluster_queue_depth", replica="0")
        for t in range(20):               # saturated but stable
            g.set(50.0)
            mon.step(now=float(t))
        assert fired == []

    def test_compile_storm_skips_startup_then_fires(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([CompileStorm()], registry=reg, bus=bus)
        h = reg.histogram("engine_warmup_compile_seconds", path="dense")
        h.observe(1.2)                    # startup warmup compile
        mon.step(now=0.0)
        mon.step(now=1.0)
        for t in range(2, 6):             # steady serving, no compiles
            mon.step(now=float(t))
        assert fired == []
        h.observe(0.8)                    # a mid-serving recompile
        mon.step(now=6.0)
        assert [a.name for a in fired] == ["compile_storm"]
        assert fired[0].evidence["new_compiles"] == 1

    def test_replica_latency_skew_fires_on_one_slow_replica(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([ReplicaLatencySkew(ratio=4.0, min_events=8)],
                             registry=reg, bus=bus)
        mon.step(now=0.0)
        for r in range(4):
            h = reg.histogram("replica_flush_seconds", replica=str(r))
            for _ in range(10):
                h.observe(0.10 if r == 2 else 0.01)
        mon.step(now=1.0)
        assert [a.name for a in fired] == ["replica_latency_skew"]
        assert fired[0].evidence["worst_replica"] == "2"

    def test_replica_latency_skew_silent_on_even_fleet(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([ReplicaLatencySkew()], registry=reg, bus=bus)
        mon.step(now=0.0)
        for r in range(4):
            h = reg.histogram("replica_flush_seconds", replica=str(r))
            for _ in range(10):
                h.observe(0.01 * (1.0 + 0.1 * r))   # mild spread only
        mon.step(now=1.0)
        assert fired == []

    def test_escalation_trend_fires_on_break_not_steady_rate(self):
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([EscalationTrend()], registry=reg, bus=bus)
        c = reg.counter("pool_events_total", event="escalated")
        for t in range(8):                # steady 2 escalations/interval
            c.inc(2)
            mon.step(now=float(t))
        assert fired == []
        c.inc(12)                         # the burst
        mon.step(now=8.0)
        assert [a.name for a in fired] == ["escalation_trend"]
        assert fired[0].evidence["delta"] == 12.0

    def test_broken_detector_does_not_stop_the_rest(self):
        class Boom(QueueDepthRunaway):
            name = "boom"

            def check(self, window):
                raise RuntimeError("detector bug")
        reg = MetricsRegistry()
        bus, fired = _bus()
        mon = AnomalyMonitor([Boom(), EscalationTrend()],
                             registry=reg, bus=bus)
        c = reg.counter("pool_events_total", event="escalated")
        for t in range(8):
            c.inc(2)
            mon.step(now=float(t))
        c.inc(12)
        mon.step(now=8.0)
        assert [a.name for a in fired] == ["escalation_trend"]


# -- alert bus ----------------------------------------------------------------

class TestAlertBus:
    def _alert(self, name="a1"):
        return Alert(name=name, severity="page", source="slo", message="m")

    def test_publish_counts_and_metric(self):
        reg = MetricsRegistry()
        bus = AlertBus(registry=reg)
        bus.publish(self._alert())
        bus.publish(self._alert())
        assert bus.n_published == 2 and bus.counts() == {"a1": 2}
        c = reg.counter("repro_obs_alerts_total", alert="a1",
                        severity="page")
        assert c.value == 2.0

    def test_subscriber_error_swallowed_and_counted(self):
        bus, fired = _bus()

        def bad(alert):
            raise OSError("pager down")
        bus.subscribe(bad)
        bus.publish(self._alert())
        assert len(fired) == 1            # other subscribers still served
        assert bus.n_subscriber_errors == 1

    def test_unsubscribe(self):
        bus, fired = _bus()
        got = []
        unsub = bus.subscribe(got.append)
        bus.publish(self._alert())
        unsub()
        bus.publish(self._alert())
        assert len(got) == 1 and len(fired) == 2

    def test_alert_json_roundtrip(self):
        doc = self._alert().to_json()
        assert json.loads(json.dumps(doc)) == doc
        assert doc["name"] == "a1" and doc["source"] == "slo"


# -- satellite: label-cardinality bounding ------------------------------------

class TestCardinality:
    def test_overflow_folds_into_catchall(self):
        reg = MetricsRegistry(max_label_sets=4)
        for i in range(10):
            reg.counter("hot", user=str(i)).inc()
        snap = {tuple(sorted(e["labels"].items())): e["value"]
                for e in reg.snapshot()["counters"] if e["name"] == "hot"}
        # 4 distinct label sets survive; the rest folded into overflow
        assert snap[tuple(sorted(OVERFLOW_LABELS.items()))] == 6.0
        assert len(snap) == 5             # 4 kept + the catch-all
        ovf = reg.counter("repro_obs_label_overflow_total")
        assert ovf.value == 6.0

    def test_existing_label_sets_unaffected_by_cap(self):
        reg = MetricsRegistry(max_label_sets=2)
        a = reg.counter("hot", user="a")
        b = reg.counter("hot", user="b")
        reg.counter("hot", user="c").inc()          # folded
        assert reg.counter("hot", user="a") is a    # cached lookups keep
        assert reg.counter("hot", user="b") is b    # their identity
        a.inc(3)
        assert a.value == 3.0

    def test_cap_is_per_metric_name(self):
        reg = MetricsRegistry(max_label_sets=2)
        for i in range(4):
            reg.counter("x", k=str(i)).inc()
            reg.counter("y", k=str(i)).inc()
        ovf = reg.counter("repro_obs_label_overflow_total")
        assert ovf.value == 4.0           # 2 folded per name


# -- satellite: sink rotation + exporter shutdown -----------------------------

class TestRotationAndShutdown:
    def test_sink_rotates_and_keeps_every_line(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        sink = JsonlTraceSink(path, max_bytes=400, keep=10)
        for i in range(50):
            sink.write({"trace_id": f"r-{i}", "pad": "x" * 40})
        sink.close()
        assert sink.n_rotations > 0
        files = [Path(path)] + sorted(tmp_path.glob("t.jsonl.*"))
        ids = []
        for f in files:
            ids += [json.loads(ln)["trace_id"]
                    for ln in f.read_text().splitlines()]
        assert sorted(ids) == sorted(f"r-{i}" for i in range(50))
        assert all(f.stat().st_size <= 400 + 100 for f in files)

    def test_sink_keep_bound_drops_oldest(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        sink = JsonlTraceSink(path, max_bytes=120, keep=2)
        for i in range(60):
            sink.write({"trace_id": f"r-{i}", "pad": "x" * 40})
        sink.close()
        rotated = sorted(p.name for p in tmp_path.glob("t.jsonl.*"))
        assert rotated == ["t.jsonl.1", "t.jsonl.2"]   # .3+ dropped

    def test_exporter_stop_flushes_tracer_then_closes_sink(self, tmp_path):
        calls = []

        class FakeTracer:
            def flush(self, timeout=30.0):
                calls.append("flush")
                return True

        class FakeSink:
            def close(self):
                calls.append("close")
        reg = MetricsRegistry()
        reg.counter("beat").inc()
        exp = PeriodicExporter(str(tmp_path / "m.prom"), interval_s=30.0,
                               registry=reg, tracer=FakeTracer(),
                               trace_sink=FakeSink()).start()
        exp.stop()
        exp.stop()                        # idempotent
        assert calls == ["flush", "close"]
        assert "beat 1" in (tmp_path / "m.prom").read_text()


# -- Chrome-trace timeline export ---------------------------------------------

def _request_trace(trace_id="r-1", t0=10.0, replica=2):
    rt = RequestTrace(trace_id, "request", t0=t0)
    rt.begin("serve", t0 + 1.0, replica=replica)
    rt.begin("queue", t0 + 1.5)
    rt.begin("serve", t0 + 2.0, replica=replica + 1)
    rt.finish(t0 + 3.0, status="ok")
    return rt.to_json()


class TestChromeTrace:
    FLUSHES = [{"t_start": 10.2, "reason": "deadline", "batch_size": 3,
                "bucket_capacity": 16, "replica_id": 2,
                "prep_s": 0.001, "dispatch_s": 0.004, "sync_s": 0.002,
                "service_s": 0.007},
               {"t_start": 0.0, "reason": "size", "batch_size": 4,
                "bucket_capacity": 16, "replica_id": 2,
                "prep_s": 0.001, "dispatch_s": 0.004, "sync_s": 0.002,
                "service_s": 0.007}]      # pre-timeline record: skipped
    WARMUP = [{"replica": 0, "path": "dense", "bucket": 16, "batch": 4,
               "seconds": 1.5, "t0": 9.0}]

    def test_export_validates_with_exact_span_sums(self):
        doc = chrome_trace([_request_trace(f"r-{i}") for i in range(3)],
                           flushes=self.FLUSHES, warmup=self.WARMUP)
        verdict = validate_chrome_trace(doc)
        assert verdict["ok"], verdict
        assert verdict["n_async_trees"] == 3
        assert verdict["tiling_violations"] == 0
        assert verdict["sum_violations"] == 0
        assert doc["otherData"]["n_flushes_skipped"] == 1

    def test_replica_lanes_and_router_pids(self):
        doc = chrome_trace([_request_trace()], flushes=self.FLUSHES,
                           warmup=self.WARMUP)
        ev = [e for e in doc["traceEvents"] if e["ph"] != "M"]
        assert {e["pid"] for e in ev if e["ph"] in ("b", "e")} == {1}
        flush = [e for e in ev if e["ph"] == "X"
                 and e["name"].startswith("flush")]
        assert flush and all(e["pid"] == 102 for e in flush)
        segs = [e["name"] for e in ev if e["ph"] == "X"
                and e["name"] in ("prep", "dispatch", "sync")]
        assert sorted(segs) == ["dispatch", "prep", "sync"]
        compiles = [e for e in ev if e["ph"] == "X"
                    and e["name"].startswith("compile")]
        assert compiles and compiles[0]["pid"] == 100
        names = {e["args"]["name"] for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert any("router" in n for n in names)
        assert any("replica" in n for n in names)

    def test_validator_catches_corrupted_tiling(self):
        doc = chrome_trace([_request_trace()])
        spans = [e for e in doc["traceEvents"] if e["ph"] in ("b", "e")]
        # shift one child boundary: the tiling (and the span sum) break
        child_end = [e for e in spans if e["ph"] == "e"][1]
        child_end["ts"] += 40.0
        verdict = validate_chrome_trace(doc)
        assert not verdict["ok"]
        assert verdict["tiling_violations"] >= 1

    def test_validator_catches_schema_violations(self):
        doc = chrome_trace([_request_trace()])
        del doc["traceEvents"][-1]["ts"]
        verdict = validate_chrome_trace(doc)
        assert not verdict["ok"] and verdict["n_schema_errors"] >= 1

    def test_write_and_cli_roundtrip(self, tmp_path):
        jsonl = tmp_path / "traces.jsonl"
        with jsonl.open("w") as f:
            for i in range(3):
                f.write(json.dumps(_request_trace(f"r-{i}")) + "\n")
        out = tmp_path / "chrome.json"
        proc = subprocess.run(
            [sys.executable, str(REPO / "scripts" / "trace_report.py"),
             str(jsonl), "--chrome-trace", str(out)],
            capture_output=True, text=True, timeout=120,
            env={**os.environ, "PYTHONPATH": str(REPO / "src")})
        assert proc.returncode == 0, proc.stderr
        doc = json.loads(out.read_text())
        assert validate_chrome_trace(doc)["ok"]
        assert doc["otherData"]["n_traces"] == 3


# -- obs_top exposition parser ------------------------------------------------

class TestObsTop:
    def test_parses_exposition_and_renders_once(self, tmp_path):
        from repro.obs import write_metrics
        reg = MetricsRegistry()
        reg.gauge("cluster_queue_depth", replica="0").set(3)
        reg.counter("serve_requests_total", surface="pool",
                    event="submitted").inc(7)
        reg.gauge("slo_breached", slo="shed_rate").set(1)
        path = tmp_path / "m.prom"
        write_metrics(str(path), registry=reg)
        proc = subprocess.run(
            [sys.executable, str(REPO / "scripts" / "obs_top.py"),
             str(path), "--once"],
            capture_output=True, text=True, timeout=60)
        assert proc.returncode == 0, proc.stderr
        assert "queue depth" in proc.stdout
        assert "submitted=7" in proc.stdout
        assert "BREACH" in proc.stdout


# -- seeded chaos replay: exact alert set, clean arm silent -------------------

CHAOS_REQUIRED = {"escalation_rate", "replica_failure", "replica_stall",
                  "md_energy_drift", "session_frame_loss"}
# anomaly detectors reacting to the same injected faults are legitimate
CHAOS_ALLOWED = CHAOS_REQUIRED | {d.name for d in default_detectors()}


class TestChaosReplay:
    @pytest.fixture(scope="class")
    def so3_bits(self):
        import jax

        from repro.guardrails import ForceEnvelope, GuardrailConfig
        from repro.models import so3krates as so3
        from repro.serving import Graph, QuantizedEngine, ServeConfig
        from repro.serving.qparams import quantize_so3_params
        cfg = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1, n_rbf=4,
                                  dir_bits=6, cutoff=3.0)
        params = so3.init_params(jax.random.PRNGKey(0), cfg)
        qp = {t: quantize_so3_params(params, t) for t in ("w4a8", "w8a8")}
        serve4 = ServeConfig(mode="w4a8", bucket_sizes=(16,), max_batch=4,
                             path="dense")
        serve8 = dataclasses.replace(serve4, mode="w8a8")
        hair = GuardrailConfig(envelope=ForceEnvelope(limits=((16, 1e-9),)))
        return {"cfg": cfg, "qp": qp, "serve4": serve4, "serve8": serve8,
                "hair": hair, "Graph": Graph, "Engine": QuantizedEngine}

    def _graph(self, bits, n=10, seed=0):
        rng = np.random.default_rng(seed)
        side = (n / 0.1) ** (1.0 / 3.0)
        return bits["Graph"](
            species=rng.integers(0, bits["cfg"].n_species, n)
            .astype(np.int32),
            coords=rng.uniform(0, side, size=(n, 3)).astype(np.float32))

    def _run_arm(self, bits, tmp_path, chaos: bool):
        from repro.cluster import ClusterConfig, ClusterPool
        from repro.md.engine import MDConfig
        from repro.server.scheduler import RequestHandle
        from repro.sessions import SessionConfig, SessionManager
        REGISTRY.reset()
        E, cfg, qp = bits["Engine"], bits["cfg"], bits["qp"]
        if chaos:
            engines = [E.from_quantized(cfg, qp["w4a8"], bits["serve4"],
                                        guardrails=bits["hair"])
                       for _ in range(2)]
            engines += [E.from_quantized(cfg, qp["w8a8"], bits["serve8"])
                        for _ in range(2)]
        else:
            engines = [E.from_quantized(cfg, qp["w8a8"], bits["serve8"])
                       for _ in range(4)]
        # warmup=True: a watchdog fleet pre-compiles so first-flush
        # compiles can't read as stalls (see test_guardrails)
        cluster = ClusterConfig(n_replicas=4, max_batch=4, deadline_ms=2.0,
                                warmup=True, max_escalations=1,
                                max_queue=64, stall_timeout_s=0.3,
                                watchdog_interval_s=0.1, probation_s=0.1)
        pool = ClusterPool(engines, cluster)
        bus = AlertBus(registry=REGISTRY)
        fired = []
        bus.subscribe(fired.append)
        slos = default_slos(fast_window_s=0.6, slow_window_s=1.8,
                            latency_p99_s=30.0, allow_partial=True)
        monitor = HealthMonitor(
            [SLOEvaluator(slos, registry=REGISTRY, bus=bus),
             AnomalyMonitor(default_detectors(), registry=REGISTRY,
                            bus=bus)],
            interval_s=0.1).start()
        pool.watch_alerts(bus)
        try:
            handles = []
            for i in range(12):           # paced background traffic
                handles.append(pool.submit(self._graph(bits, seed=100 + i)))
                time.sleep(0.04)
            if chaos:
                # fault 1: poison escalations — requests pinned to the
                # hair-trigger w4a8 replicas re-run a tier up
                for k in range(3):
                    h = RequestHandle(self._graph(bits, seed=500 + k),
                                      time.monotonic(), bucket_capacity=16)
                    assert pool._replicas[0].try_submit(h)
                    handles.append(h)
                # fault 2: in-flight replica kill -> failover requeue
                rep3 = pool._replicas[3]
                pool.kill_replica(3, mode="in_flight")
                h = RequestHandle(self._graph(bits, seed=600),
                                  time.monotonic(), bucket_capacity=16)
                assert rep3.try_submit(h)
                handles.append(h)
                # fault 3: engine-lock stall -> watchdog quarantine
                rep1 = pool._replicas[1]
                rep1.inject_stall(1.5)
                h = RequestHandle(self._graph(bits, seed=700),
                                  time.monotonic(), bucket_capacity=16)
                assert rep1.try_submit(h)
                handles.append(h)
            for h in handles:
                h.result(timeout=WAIT_S)
            pool_alerts = pool.stats()["alerts"]
        finally:
            pool.close()

        # fault 4: MD session — drifting (chaos) vs clean. A separate
        # watchdog-free pool: an MD chunk is ONE unit of worker time and
        # its first-chunk step compile would read as a stall
        md_pool = ClusterPool(
            [E.from_quantized(cfg, qp["w8a8"], bits["serve8"])
             for _ in range(2)],
            ClusterConfig(n_replicas=2, max_batch=4, warmup=False,
                          max_queue=64))
        try:
            md = MDConfig(mode="w8a8", dt_fs=0.25, record_every=10,
                          drift_limit=1e-12 if chaos else None)
            scfg = SessionConfig(n_steps=40, chunk_steps=20,
                                 record_every=10, checkpoint_every=1,
                                 md=md)
            rng = np.random.default_rng(13)
            n = 10
            side = (n / 0.1) ** (1.0 / 3.0)
            mgr = SessionManager(md_pool, str(tmp_path / ("c" if chaos
                                                          else "clean")))
            s = mgr.start(
                rng.integers(0, cfg.n_species, n).astype(np.int32),
                rng.uniform(0, side, size=(n, 3)).astype(np.float32),
                np.full(n, 12.0, np.float32), seed=5, config=scfg)
            if chaos:
                with pytest.raises(Exception):   # wait re-raises the
                    s.wait(WAIT_S)               # session's fatal error
                assert s.status == "failed"
            else:
                assert s.wait(WAIT_S) == "done"
            mgr.close()
            time.sleep(0.5)               # let the windows catch up
        finally:
            monitor.stop(final_step=True)
            md_pool.close()
        return fired, pool_alerts

    def test_chaos_arm_fires_every_fault_class(self, so3_bits, tmp_path):
        fired, pool_alerts = self._run_arm(so3_bits, tmp_path, chaos=True)
        names = {a.name for a in fired}
        missing = CHAOS_REQUIRED - names
        assert not missing, f"undetected fault classes: {missing}"
        unexpected = names - CHAOS_ALLOWED
        assert not unexpected, f"unattributed alerts: {unexpected}"
        by_name = {a.name: a for a in fired}
        assert by_name["md_energy_drift"].value > 1.0
        assert by_name["replica_stall"].evidence["delta"] >= 1.0
        assert by_name["escalation_rate"].evidence["fast_burn"] >= 1.0
        # the pool saw the one-shot-phase verdicts through watch_alerts
        assert pool_alerts["n_seen"] >= 1
        assert {a["name"] for a in pool_alerts["recent"]} & names

    def test_clean_arm_fires_nothing(self, so3_bits, tmp_path):
        fired, _ = self._run_arm(so3_bits, tmp_path, chaos=False)
        assert fired == [], ("clean-arm false positives: "
                             f"{[a.name for a in fired]}")
