"""Tests for MDDQ, spherical codebooks, geometric STE, LEE, attention norm."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from helpers.equivariance import assert_rotation_equivariant_bounded
from repro.core import (
    MDDQConfig,
    covering_radius,
    fibonacci_sphere,
    geometric_ste_direction,
    lee,
    lee_regularizer,
    make_codebook,
    mddq_decode,
    mddq_encode,
    mddq_fake_quant,
    nearest_code,
    octahedral_sphere,
    quantize_direction,
    random_rotation,
    random_rotations,
    robust_attention_weights,
    cosine_attention_logits,
)


def _rand_vectors(key, shape):
    return jax.random.normal(key, shape + (3,))


class TestCodebook:
    def test_fibonacci_unit_norm(self):
        c = fibonacci_sphere(256)
        np.testing.assert_allclose(np.linalg.norm(c, axis=-1), 1.0, atol=1e-6)

    def test_octahedral_closed_under_group(self):
        c = octahedral_sphere(256)
        assert len(c) > 0
        # rotating the codebook by a group element permutes it
        R = np.array([[0, -1, 0], [1, 0, 0], [0, 0, 1]], dtype=np.float32)  # z 90deg
        rc = c @ R.T
        d = np.linalg.norm(rc[:, None, :] - c[None, :, :], axis=-1).min(axis=1)
        assert d.max() < 1e-4

    def test_covering_radius_decreases_with_bits(self):
        r4 = covering_radius(make_codebook(4), n_samples=20000)
        r8 = covering_radius(make_codebook(8), n_samples=20000)
        assert r8 < r4
        # 256 points: expected covering radius ~ sqrt(4/N) ~ 0.125 rad; be loose
        assert r8 < 0.25

    def test_nearest_code_exact_on_codewords(self):
        c = make_codebook(6)
        idx = nearest_code(c, c)
        np.testing.assert_array_equal(np.asarray(idx), np.arange(len(c)))


class TestMDDQ:
    def test_fake_quant_preserves_shape_and_bounded_angle(self):
        cfg = MDDQConfig(direction_bits=8)
        v = _rand_vectors(jax.random.PRNGKey(0), (128,))
        q = mddq_fake_quant(v, cfg)
        assert q.shape == v.shape
        cos = np.sum(np.asarray(q) * np.asarray(v), axis=-1) / (
            np.linalg.norm(q, axis=-1) * np.linalg.norm(v, axis=-1))
        delta = covering_radius(cfg.codebook(), n_samples=50000)
        assert np.arccos(np.clip(cos, -1, 1)).max() <= delta + 0.02

    def test_magnitude_relative_error_small(self):
        cfg = MDDQConfig()
        v = _rand_vectors(jax.random.PRNGKey(1), (256,)) * 10.0
        q = mddq_fake_quant(v, cfg)
        m_in = np.linalg.norm(np.asarray(v), axis=-1)
        m_out = np.linalg.norm(np.asarray(q), axis=-1)
        assert np.abs(m_out / m_in - 1).max() < 0.05

    def test_zero_vector_maps_to_zero(self):
        cfg = MDDQConfig()
        v = jnp.zeros((4, 3))
        np.testing.assert_allclose(np.asarray(mddq_fake_quant(v, cfg)), 0.0)

    def test_encode_decode_roundtrip(self):
        cfg = MDDQConfig()
        v = _rand_vectors(jax.random.PRNGKey(2), (64,))
        idx, mag = mddq_encode(v, cfg)
        assert idx.dtype == jnp.int32
        v2 = mddq_decode(idx, mag, cfg)
        # bounded error: angle <= covering radius, magnitude rel err < 5%
        cos = np.sum(np.asarray(v2) * np.asarray(v), axis=-1) / (
            np.linalg.norm(v2, axis=-1) * np.linalg.norm(v, axis=-1))
        assert cos.min() > np.cos(0.25)

    @given(st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_approximate_equivariance_property(self, seed):
        """Q(Rv) ~ R Q(v) up to 2*covering-radius chordal error (paper Eq. 4)."""
        cfg = MDDQConfig(direction_bits=8)
        cb = cfg.codebook()
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        v = _rand_vectors(k1, (32,))
        u = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
        # both sides land within delta of Ru -> within 2 delta (chordal)
        delta = 0.17  # measured covering radius of 256-pt fibonacci ~ 0.135
        assert_rotation_equivariant_bounded(
            lambda x: quantize_direction(jnp.asarray(x), cb), u,
            bound=2 * 2 * np.sin(delta / 2) + 1e-5,
            R=np.asarray(random_rotation(k2), np.float32))


class TestGeometricSTE:
    def test_gradient_is_tangent(self):
        key = jax.random.PRNGKey(0)
        v = _rand_vectors(key, (16,))
        u = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
        q = quantize_direction(u, make_codebook(8))

        def loss(u_):
            out = geometric_ste_direction(u_, q)
            return jnp.sum(out * jnp.arange(48.0).reshape(16, 3))

        g = jax.grad(loss)(u)
        radial = np.abs(np.sum(np.asarray(g) * np.asarray(u), axis=-1))
        assert radial.max() < 1e-5  # Prop III.1: <u, dL/du> = 0

    def test_forward_returns_quantized(self):
        u = jnp.array([[1.0, 0.0, 0.0]])
        q = jnp.array([[0.0, 1.0, 0.0]])
        np.testing.assert_allclose(np.asarray(geometric_ste_direction(u, q)), np.asarray(q))


class TestLEE:
    def test_rotation_is_orthogonal(self):
        Rs = random_rotations(jax.random.PRNGKey(0), 8)
        eye = jnp.einsum("rij,rkj->rik", Rs, Rs)
        np.testing.assert_allclose(np.asarray(eye), np.tile(np.eye(3), (8, 1, 1)), atol=1e-5)
        det = np.linalg.det(np.asarray(Rs))
        np.testing.assert_allclose(det, 1.0, atol=1e-5)

    def test_lee_zero_for_equivariant_fn(self):
        # f(X) = X @ A with A = a I is equivariant: (XR^T) aI = (X aI) R^T
        f = lambda x: 2.5 * x
        coords = jax.random.normal(jax.random.PRNGKey(1), (10, 3))
        R = random_rotation(jax.random.PRNGKey(2))
        assert float(lee(f, coords, R)) < 1e-5

    def test_lee_positive_for_non_equivariant_fn(self):
        f = lambda x: x ** 2  # breaks equivariance
        coords = jax.random.normal(jax.random.PRNGKey(1), (10, 3))
        R = random_rotation(jax.random.PRNGKey(2))
        assert float(lee(f, coords, R)) > 0.1

    def test_regularizer_differentiable(self):
        coords = jax.random.normal(jax.random.PRNGKey(1), (6, 3))

        def model(w, x):
            return x * w  # equivariant iff scalar; grad flows through w

        g = jax.grad(lambda w: lee_regularizer(
            lambda x: model(w, x) + w * x ** 2, coords, jax.random.PRNGKey(0)))(1.0)
        assert np.isfinite(g)


class TestRobustAttention:
    def test_logits_bounded_by_tau(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 8)) * 100.0
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 8)) * 100.0
        logits = cosine_attention_logits(q, k, tau=10.0)
        assert float(jnp.max(jnp.abs(logits))) <= 10.0 + 1e-4

    def test_weights_sum_to_one_and_masked(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 8))
        k = jax.random.normal(jax.random.PRNGKey(1), (3, 6, 8))
        mask = jnp.ones((3, 4, 6), bool).at[:, :, -1].set(False)
        w = robust_attention_weights(q, k, mask=mask)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
        assert float(w[..., -1].max()) < 1e-6

    def test_scale_invariance(self):
        """Attention depends only on directions (paper: scale carried by values)."""
        q = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8))
        w1 = robust_attention_weights(q, k)
        w2 = robust_attention_weights(q * 37.0, k * 0.01)
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)
