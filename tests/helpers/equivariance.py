"""Shared symmetry-property assertions for every surface in the repo.

Each serving/MD/quantization surface ultimately claims the same
contract — energies are scalars invariant under SO(3) rotations,
translations and atom permutations; forces (and any per-atom vector
output) rotate with the frame; the MDDQ vector quantizer commutes with
rotation up to its codebook's covering radius. Before this module those
claims were asserted four times in four slightly different hand-rolled
shapes (test_sparse_serving, test_so3_system, test_core_mddq,
test_md_engine). Now each property is stated once, parameterized over
the surface's ``run`` callable, so every path asserts the *same*
property with the same rotation machinery (``repro.core``'s sampled
rotations).

The central helper is :func:`assert_rotation_equivariant`. Its ``run``
callable receives ``(coords, R)`` — the rotation is passed in because
some surfaces must co-rotate auxiliary state (the MD engine rotates its
sampled initial velocities); surfaces without such state just ignore
``R``. Returning ``None`` for the scalar skips the invariance half
(e.g. trajectory-endpoint checks that only compare vectors).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

Array = np.ndarray


def rotation(seed: int = 0) -> Array:
    """A uniformly random SO(3) matrix, deterministic per seed."""
    import jax

    from repro.core import random_rotation
    return np.asarray(random_rotation(jax.random.PRNGKey(seed)), np.float32)


def assert_rotation_equivariant(
        run: Callable[[Array, Array], Tuple[Optional[object], Array]],
        coords: Array, *, seed: int = 0, R: Optional[Array] = None,
        atol: float = 1e-5, scalar_atol: Optional[float] = None,
        mask: Optional[Array] = None) -> Array:
    """Energies invariant, vectors covariant: ``run(R.c) == (s, R.v)``.

    ``run(coords, R) -> (scalars | None, vectors)`` evaluates the
    surface under test; it is called once with the identity and once
    with a random rotation applied to ``coords`` (rows are positions:
    ``coords @ R.T``). Scalars must match to ``scalar_atol`` (defaults
    to ``atol``); vectors must match the rotated originals to ``atol``.
    ``mask`` additionally pins padded vector rows to exactly zero in
    the rotated frame — rotation must not leak signal into padding.
    Returns the rotation used so callers can chain further checks.
    """
    if R is None:
        R = rotation(seed)
    eye = np.eye(3, dtype=np.float32)
    coords = np.asarray(coords)
    s0, v0 = run(coords, eye)
    s1, v1 = run(coords @ R.T, R)
    if s0 is not None:
        np.testing.assert_allclose(
            np.asarray(s1), np.asarray(s0),
            atol=scalar_atol if scalar_atol is not None else atol,
            err_msg="scalar output is not rotation-invariant")
    np.testing.assert_allclose(
        np.asarray(v1), np.asarray(v0) @ R.T, atol=atol,
        err_msg="vector output is not rotation-equivariant")
    if mask is not None:
        np.testing.assert_array_equal(
            np.asarray(v1)[~np.asarray(mask)], 0.0,
            err_msg="rotation leaked signal into padded rows")
    return R


def assert_rotation_equivariant_bounded(
        encode: Callable[[Array], Array], vectors: Array, *, bound: float,
        seed: int = 0, R: Optional[Array] = None) -> float:
    """``Q(Rv)`` within ``bound`` of ``R Q(v)`` (worst row, L2).

    The MDDQ contract (paper Eq. 4): a codebook quantizer cannot commute
    with rotation exactly, but both sides land within the covering
    radius of the true rotated direction, so they sit within twice the
    chordal covering distance of each other. Returns the measured error
    so callers can additionally assert tightness trends.
    """
    if R is None:
        R = rotation(seed)
    vectors = np.asarray(vectors)
    lhs = np.asarray(encode(vectors @ R.T))
    rhs = np.asarray(encode(vectors)) @ R.T
    err = float(np.linalg.norm(lhs - rhs, axis=-1).max())
    assert err <= bound, (
        f"quantizer equivariance error {err:.4g} exceeds bound "
        f"{bound:.4g}: Q(Rv) strayed further from R Q(v) than the "
        f"codebook covering radius allows")
    return err


def assert_energy_rotation_invariant(
        energy: Callable[[Array], object], coords: Array, *,
        seed: int = 0, atol: float = 1e-4) -> None:
    """Scalar ``energy(coords)`` unchanged by a random rotation."""
    R = rotation(seed)
    coords = np.asarray(coords)
    e0 = float(np.asarray(energy(coords)))
    e1 = float(np.asarray(energy(coords @ R.T)))
    assert abs(e1 - e0) < atol, (
        f"energy changed by {abs(e1 - e0):.4g} under rotation "
        f"(atol {atol:g})")


def assert_energy_translation_invariant(
        energy: Callable[[Array], object], coords: Array, *,
        shift: float = 5.0, atol: float = 1e-4) -> None:
    """Scalar ``energy(coords)`` unchanged by a rigid translation."""
    coords = np.asarray(coords)
    e0 = float(np.asarray(energy(coords)))
    e1 = float(np.asarray(energy(coords + shift)))
    assert abs(e1 - e0) < atol, (
        f"energy changed by {abs(e1 - e0):.4g} under translation by "
        f"{shift} (atol {atol:g})")


def assert_permutation_equivariant(
        run: Callable[[Array, Array], Array], species: Array,
        coords: Array, *, seed: int = 0, atol: float = 1e-4) -> None:
    """Permuting atoms permutes per-atom outputs (and nothing else):
    ``run(species[p], coords[p]) == run(species, coords)[p]``. This is
    the GNN invariance that also makes total energies permutation-
    invariant (a sum over atoms)."""
    species = np.asarray(species)
    coords = np.asarray(coords)
    perm = np.random.default_rng(seed).permutation(len(species))
    f0 = np.asarray(run(species, coords))
    f1 = np.asarray(run(species[perm], coords[perm]))
    np.testing.assert_allclose(
        f0[perm], f1, atol=atol,
        err_msg="per-atom output does not commute with atom permutation")
