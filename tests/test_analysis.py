"""Tests for the analysis substrate: HLO collective walker, analytic cost
model consistency, roofline term computation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import costs
from repro.launch.hlo_analysis import (analyze_collectives, loop_summary,
                                       split_computations, _shape_bytes)
from repro.models.lm.config import SHAPES, ShapeCell
from repro import configs


class TestHLOWalker:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[16,4096,3072]") == 16 * 4096 * 3072 * 4
        assert _shape_bytes("bf16[8,8]") == 128
        assert _shape_bytes("(f32[4,4], s8[16])") == 64 + 16
        assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1

    def test_trip_multiplication_on_real_scan(self):
        """A psum inside a 7-trip scan counts 7x (on a 1-device mesh the
        collective lowers away, so test the parser on synthetic HLO)."""
        hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %c = s32[] constant(7)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %x = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[128]) tuple()
  %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
  %ag = f32[256]{0} all-gather(%w), dimensions={0}
  ROOT %r = f32[] constant(0)
}
"""
        by, ct = analyze_collectives(hlo)
        assert ct["all-reduce"] == 7
        assert by["all-reduce"] == 7 * 128 * 4
        assert ct["all-gather"] == 1
        assert by["all-gather"] == 256 * 4

    def test_nested_loops_multiply(self):
        hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%icond (p: s32[]) -> pred[] {
  %p = s32[] parameter(0)
  %c = s32[] constant(3)
  ROOT %lt = pred[] compare(%p, %c), direction=LT
}

%ibody (p: s32[]) -> s32[] {
  %p = s32[] parameter(0)
  %x = f32[64]{0} constant(0)
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}
  ROOT %r = s32[] copy(%p)
}

%ocond (p: s32[]) -> pred[] {
  %p = s32[] parameter(0)
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%p, %c), direction=LT
}

%obody (p: s32[]) -> s32[] {
  %p = s32[] parameter(0)
  ROOT %w = s32[] while(%p), condition=%icond, body=%ibody
}

ENTRY %main () -> f32[] {
  %z = s32[] constant(0)
  %w = s32[] while(%z), condition=%ocond, body=%obody
  ROOT %r = f32[] constant(0)
}
"""
        by, ct = analyze_collectives(hlo)
        assert ct["all-reduce"] == 15  # 5 outer x 3 inner
        assert by["all-reduce"] == 15 * 64 * 4


class TestCostModel:
    @pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
    def test_flops_positive_and_ordered(self, arch):
        cfg = configs.get_config(arch)
        cells = {s.shape_name: s for s in SHAPES}
        f_train = costs.cell_flops(cfg, cells["train_4k"])
        f_prefill = costs.cell_flops(cfg, cells["prefill_32k"])
        f_decode = costs.cell_flops(cfg, cells["decode_32k"])
        assert f_train > 0 and f_prefill > 0 and f_decode > 0
        # training does 3x forward work per token; decode is one token
        assert f_train > f_decode * 1000

    @pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen2-0.5b"])
    def test_useful_ratio_sane(self, arch):
        """Implementation FLOPs must be >= MODEL_FLOPS (can't beat the
        yardstick) and within ~4x of it for dense archs."""
        cfg = configs.get_config(arch)
        for cell in SHAPES[:3]:
            impl = costs.cell_flops(cfg, cell)
            model = costs.model_flops(cfg, cell)
            assert impl >= model * 0.5, f"{arch}/{cell.shape_name}"
            assert impl <= model * 6, f"{arch}/{cell.shape_name}"

    def test_quant_reduces_weight_bytes(self):
        cfg = configs.get_config("qwen1.5-110b")
        import dataclasses
        cell = SHAPES[2]  # decode
        base = costs.cell_hbm_bytes(cfg, cell)
        w8 = costs.cell_hbm_bytes(
            dataclasses.replace(cfg, quant_mode="serve_w8a8"), cell)
        w4 = costs.cell_hbm_bytes(
            dataclasses.replace(cfg, quant_mode="serve_w4a8"), cell)
        assert abs(base["weights"] / w8["weights"] - 4.0) < 0.01
        assert abs(base["weights"] / w4["weights"] - 8.0) < 0.01

    def test_kv_quant_reduces_cache_bytes(self):
        import dataclasses
        cfg = configs.get_config("qwen1.5-110b")
        cell = SHAPES[2]
        base = costs.cell_hbm_bytes(cfg, cell)["cache"]
        kv8 = costs.cell_hbm_bytes(
            dataclasses.replace(cfg, kv_quant=True), cell)["cache"]
        kv4 = costs.cell_hbm_bytes(
            dataclasses.replace(cfg, kv_quant=True, kv_bits=4), cell)["cache"]
        assert 1.8 < base / kv8 < 2.1   # bf16 -> int8+scales
        assert 1.7 < kv8 / kv4 < 2.1

    def test_moe_active_flops_much_less_than_dense_equiv(self):
        cfg = configs.get_config("qwen3-moe-30b-a3b")
        cell = SHAPES[0]
        impl = costs.cell_flops(cfg, cell)
        # if all 128 experts ran densely, cost would be ~16x the top-8 cost
        dense_all = impl + costs.cell_flops(cfg, cell) * 0  # guard
        assert costs.model_flops(cfg, cell) / impl > 0.3


class TestRooflineTerms:
    def test_terms_from_synthetic_record(self):
        from benchmarks.roofline import terms
        rec = {
            "analytic_flops": 256 * 197e12,          # exactly 1 s compute
            "analytic_hbm_bytes": {"total": 256 * 819e9},  # 1 s memory
            "collective_bytes": {"all-reduce": 50e9},      # 1 s collective
            "model_flops": 0.5 * 256 * 197e12,
        }
        t = terms(rec)
        assert abs(t["compute_s"] - 1) < 1e-9
        assert abs(t["memory_s"] - 1) < 1e-9
        assert abs(t["collective_s"] - 1) < 1e-9
        assert abs(t["roofline_fraction"] - 0.5) < 1e-9
        assert t["useful_ratio"] == 0.5
