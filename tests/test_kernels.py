"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
with shape/dtype sweeps as required.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_codebook
from repro.core.quantizers import abs_max_scale, pack_int4, quantize
from repro.kernels import ops, ref
from repro.kernels.quant_matmul import w4a8_matmul, w8a8_matmul
from repro.kernels.mddq_kernel import mddq_encode_kernel
from repro.kernels.attention_int8kv import decode_attention_int8kv


def _mk_w8(key, m, k, n):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n))
    a_scale = abs_max_scale(a, 8, channel_axis=0)
    a_q = quantize(a, a_scale, 8)
    w_scale = abs_max_scale(w, 8, channel_axis=1)
    w_q = quantize(w, w_scale, 8)
    return a_q, a_scale, w_q, w_scale


class TestQuantMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                       (128, 256, 512)])
    def test_w8a8_matches_ref(self, m, k, n):
        a_q, a_s, w_q, w_s = _mk_w8(jax.random.PRNGKey(0), m, k, n)
        out = w8a8_matmul(a_q, a_s, w_q, w_s, interpret=True)
        want = ref.w8a8_matmul_ref(a_q, a_s, w_q, w_s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (128, 256, 256)])
    def test_w4a8_matches_ref(self, m, k, n):
        key = jax.random.PRNGKey(1)
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (m, k))
        w = jax.random.normal(k2, (k, n))
        a_s = abs_max_scale(a, 8, channel_axis=0)
        a_q = quantize(a, a_s, 8)
        w_s = abs_max_scale(w, 4, channel_axis=1)
        w_p = pack_int4(quantize(w, w_s, 4))
        out = w4a8_matmul(a_q, a_s, w_p, w_s, interpret=True)
        want = ref.w4a8_matmul_ref(a_q, a_s, w_p, w_s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (128, 256, 128)])
    def test_block_shape_sweep(self, bm, bn, bk):
        a_q, a_s, w_q, w_s = _mk_w8(jax.random.PRNGKey(2), 256, 256, 256)
        out = w8a8_matmul(a_q, a_s, w_q, w_s, bm=bm, bn=bn, bk=bk,
                          interpret=True)
        want = ref.w8a8_matmul_ref(a_q, a_s, w_q, w_s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_wrapper_end_to_end_close_to_fp32(self):
        """W8A8 wrapper approximates the fp32 matmul within quant noise."""
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (64, 200))
        w = jax.random.normal(jax.random.fold_in(key, 1), (200, 130))
        w_q, w_s = ops.prepare_w8(w)
        out = ops.matmul_w8a8(x, w_q, w_s)
        want = x @ w
        err = np.abs(np.asarray(out - want))
        assert err.mean() < 0.25  # ~1% of |x@w| rms (~14)
        assert out.shape == (64, 130)

    def test_ops_w4_wrapper_shapes(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (32, 100))
        w = jax.random.normal(jax.random.PRNGKey(5), (100, 64))
        w_p, w_s = ops.prepare_w4(w)
        out = ops.matmul_w4a8(x, w_p, w_s)
        assert out.shape == (32, 64)
        rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
        # 4-bit abs-max per-column on N(0,1) weights: step ~ 3sigma/7 ->
        # ~11-12% relative error is the information-theoretic neighbourhood
        assert rel < 0.15


def _edge_problem(seed, B, cap, ec, F, W, cutoff=3.0):
    """Random padded batch + edge list + features for edge_softmax tests."""
    from repro.serving.bucketing import build_edge_list
    rng = np.random.default_rng(seed)
    side = (cap / 0.05) ** (1.0 / 3.0)   # constant density ~ degree 6
    coords = rng.uniform(0, side, size=(B, cap, 3)).astype(np.float32)
    mask = np.ones((B, cap), bool)
    mask[0, cap // 2:] = False
    el = build_edge_list(coords, mask, cutoff, ec)
    assert el is not None, "edge capacity too small for test problem"
    N, E = B * cap, B * ec
    q = jnp.asarray(rng.normal(size=(N, F)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(N, F)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(E,)).astype(np.float32))
    vals = jnp.asarray(rng.normal(size=(E, W)).astype(np.float32))
    return (q, k, bias, vals, jnp.asarray(el.senders),
            jnp.asarray(el.receivers), jnp.asarray(el.edge_mask))


class TestEdgeSoftmaxKernel:
    @pytest.mark.parametrize("B,cap,ec,F,W", [(2, 16, 256, 32, 56),
                                              (4, 32, 128, 64, 128),
                                              (1, 128, 512, 16, 80)])
    def test_matches_ref(self, B, cap, ec, F, W):
        q, k, bias, vals, s, r, m = _edge_problem(B, B, cap, ec, F, W)
        out = ops.edge_softmax(q, k, bias, vals, s, r, m, cap=cap,
                               use_kernel=True)
        want = ref.edge_softmax_ref(q, k, bias, s, r, m, vals, B * cap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_gradients_match_ref(self):
        """The fused kernel's custom VJP reproduces the oracle's
        gradients (forces differentiate through this path)."""
        q, k, bias, vals, s, r, m = _edge_problem(7, 2, 16, 256, 32, 40)

        def loss(fn):
            def f(q_, k_, b_, v_):
                return jnp.sum(fn(q_, k_, b_, v_) ** 2)
            return jax.grad(f, argnums=(0, 1, 2, 3))(q, k, bias, vals)

        g_ker = loss(lambda q_, k_, b_, v_: ops.edge_softmax(
            q_, k_, b_, v_, s, r, m, cap=16, use_kernel=True))
        g_ref = loss(lambda q_, k_, b_, v_: ref.edge_softmax_ref(
            q_, k_, b_, s, r, m, v_, 32))
        for a, b in zip(g_ker, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_no_edge_receivers_are_exact_zero(self):
        """Nodes no real edge points at (incl. all-masked molecules)
        produce exactly zero output, not softmax-of-mask noise."""
        q, k, bias, vals, s, r, m = _edge_problem(3, 2, 16, 128, 32, 24)
        out = np.asarray(ops.edge_softmax(q, k, bias, vals, s, r, m,
                                          cap=16, use_kernel=True))
        has_edge = np.zeros(32, bool)
        has_edge[np.asarray(r)[np.asarray(m)]] = True
        np.testing.assert_array_equal(out[~has_edge], 0.0)


class TestMDDQKernel:
    @pytest.mark.parametrize("n,bits", [(1024, 8), (2048, 6), (4096, 4)])
    def test_matches_ref(self, n, bits):
        cb = make_codebook(bits)
        cb_t = ops.pad_codebook(cb)
        v = jax.random.normal(jax.random.PRNGKey(0), (n, 3)) * 3.0
        idx, mag = mddq_encode_kernel(v[:, 0].copy(), v[:, 1].copy(),
                                      v[:, 2].copy(), cb_t, bn=1024,
                                      interpret=True)
        # reference works on the padded codebook too (pad = copies of cw 0,
        # ties resolve to the first occurrence = identical index)
        idx_ref, mag_ref = ref.mddq_encode_ref(v, jnp.asarray(cb_t.T))
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
        np.testing.assert_array_equal(np.asarray(mag), np.asarray(mag_ref))

    def test_ops_wrapper_arbitrary_shape(self):
        cb_t = ops.pad_codebook(make_codebook(8))
        v = jax.random.normal(jax.random.PRNGKey(1), (7, 13, 3))
        idx, mag = ops.mddq_encode(v, cb_t)
        assert idx.shape == (7, 13) and mag.shape == (7, 13)
        idx_ref, _ = ref.mddq_encode_ref(v.reshape(-1, 3), jnp.asarray(cb_t.T))
        np.testing.assert_array_equal(np.asarray(idx).ravel(),
                                      np.asarray(idx_ref))

    def test_padded_codebook_never_wins_argmax(self):
        """``pad_codebook`` 128-aligns with COPIES OF CODEWORD 0, so a
        padded column can at most tie codeword 0's score; argmax takes
        the first maximizing index — the real index 0 — and no encoded
        index ever points at a padding slot. Includes the exact-tie case
        (inputs colinear with codeword 0)."""
        cb = make_codebook(6)                    # 64 entries -> padded to 128
        cb_t = ops.pad_codebook(cb)
        assert cb_t.shape == (3, 128)
        v = jax.random.normal(jax.random.PRNGKey(9), (1024, 3)) * 2.0
        v = v.at[:64].set(jnp.tile(cb[:1] * 3.0, (64, 1)))  # ties with cw 0
        idx, _ = mddq_encode_kernel(v[:, 0].copy(), v[:, 1].copy(),
                                    v[:, 2].copy(), cb_t, bn=1024,
                                    interpret=True)
        idx = np.asarray(idx)
        assert idx.max() < 64, "argmax selected a padding slot"
        np.testing.assert_array_equal(idx[:64], 0)

    def test_qdq_kernel_matches_fake_quant(self):
        """Serve-time quantize-dequantize through the Pallas encode kernel
        (ops.mddq_qdq_kernel): identical values to the fake-quant
        reference, exact zero for zero vectors, identical Geometric-STE
        gradients."""
        from repro.core.mddq import MDDQConfig, mddq_fake_quant
        cfg = MDDQConfig(direction_bits=6, magnitude_bits=8)
        cb = make_codebook(6)
        v = jax.random.normal(jax.random.PRNGKey(11), (64, 8, 3)) * 2.0
        v = v.at[0, 0].set(0.0)
        out = ops.mddq_qdq_kernel(v, cfg, cb)
        want = mddq_fake_quant(v, cfg, cb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out)[0, 0], 0.0)
        g_ker = jax.grad(lambda v_: jnp.sum(
            ops.mddq_qdq_kernel(v_, cfg, cb) ** 2))(v)
        g_ref = jax.grad(lambda v_: jnp.sum(
            mddq_fake_quant(v_, cfg, cb) ** 2))(v)
        np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-5)

    def test_qdq_kernel_respects_magnitude_config(self):
        """Regression: the encode kernel must use the config's magnitude
        grid (bits, m_min, m_max), not its 8-bit defaults — a 4-bit
        config decoded on the wrong grid overflows exp()."""
        from repro.core.mddq import MDDQConfig, mddq_fake_quant
        cfg = MDDQConfig(direction_bits=6, magnitude_bits=4,
                         m_min=1e-3, m_max=10.0)
        cb = make_codebook(6)
        v = jax.random.normal(jax.random.PRNGKey(12), (32, 4, 3))
        out = ops.mddq_qdq_kernel(v, cfg, cb)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(mddq_fake_quant(v, cfg, cb)),
                                   atol=1e-6)
        # linear-domain magnitudes are not kernel-supported: explicit error
        with pytest.raises(NotImplementedError):
            ops.mddq_qdq_kernel(
                v, MDDQConfig(direction_bits=6,
                              magnitude_domain="linear"), cb)


class TestInt8KVDecode:
    @pytest.mark.parametrize("bh,s,d,bs", [(4, 1024, 128, 512),
                                           (2, 512, 64, 256),
                                           (8, 2048, 128, 512)])
    def test_matches_ref(self, bh, s, d, bs):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (bh, d))
        k = jax.random.normal(ks[1], (bh, s, d))
        v = jax.random.normal(ks[2], (bh, s, d))
        k_q, k_s, v_q, v_s = ops.prepare_kv_int8(k, v)
        out = decode_attention_int8kv(q, k_q, k_s, v_q, v_s, bs=bs,
                                      interpret=True)
        want = ref.decode_attention_int8kv_ref(
            q, k_q, k_s, v_q, v_s, softmax_scale=1.0 / d ** 0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_close_to_fp32_attention(self):
        """int8 KV attention approximates fp32 attention."""
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 3)
        bh, s, d = 4, 512, 64
        q = jax.random.normal(ks[0], (bh, d))
        k = jax.random.normal(ks[1], (bh, s, d))
        v = jax.random.normal(ks[2], (bh, s, d))
        k_q, k_s, v_q, v_s = ops.prepare_kv_int8(k, v)
        out = ops.decode_attention_int8kv(q, k_q, k_s, v_q, v_s, bs=256)
        logits = jnp.einsum("bd,bsd->bs", q, k) / d ** 0.5
        want = jnp.einsum("bs,bsd->bd", jax.nn.softmax(logits, -1), v)
        rel = float(jnp.linalg.norm(out - want) / jnp.linalg.norm(want))
        assert rel < 0.02


class TestActQuantKernel:
    @pytest.mark.parametrize("m,k,bm", [(256, 512, 256), (512, 384, 128),
                                        (128, 1000, 64)])
    def test_matches_ref(self, m, k, bm):
        from repro.kernels.act_quant import act_quant
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 3.0
        q, s = act_quant(x, bm=bm, interpret=True)
        s_ref = abs_max_scale(x, 8, channel_axis=0)
        q_ref = quantize(x, s_ref, 8)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))

    def test_roundtrip_error_bounded(self):
        from repro.kernels.act_quant import act_quant
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 256))
        q, s = act_quant(x, interpret=True)
        err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
        assert err.max() <= float(np.asarray(s).max()) / 2 + 1e-7
