"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
with shape/dtype sweeps as required.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_codebook
from repro.core.quantizers import abs_max_scale, pack_int4, quantize
from repro.kernels import ops, ref
from repro.kernels.quant_matmul import w4a8_matmul, w8a8_matmul
from repro.kernels.mddq_kernel import mddq_encode_kernel
from repro.kernels.attention_int8kv import decode_attention_int8kv


def _mk_w8(key, m, k, n):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n))
    a_scale = abs_max_scale(a, 8, channel_axis=0)
    a_q = quantize(a, a_scale, 8)
    w_scale = abs_max_scale(w, 8, channel_axis=1)
    w_q = quantize(w, w_scale, 8)
    return a_q, a_scale, w_q, w_scale


class TestQuantMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                       (128, 256, 512)])
    def test_w8a8_matches_ref(self, m, k, n):
        a_q, a_s, w_q, w_s = _mk_w8(jax.random.PRNGKey(0), m, k, n)
        out = w8a8_matmul(a_q, a_s, w_q, w_s, interpret=True)
        want = ref.w8a8_matmul_ref(a_q, a_s, w_q, w_s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (128, 256, 256)])
    def test_w4a8_matches_ref(self, m, k, n):
        key = jax.random.PRNGKey(1)
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (m, k))
        w = jax.random.normal(k2, (k, n))
        a_s = abs_max_scale(a, 8, channel_axis=0)
        a_q = quantize(a, a_s, 8)
        w_s = abs_max_scale(w, 4, channel_axis=1)
        w_p = pack_int4(quantize(w, w_s, 4))
        out = w4a8_matmul(a_q, a_s, w_p, w_s, interpret=True)
        want = ref.w4a8_matmul_ref(a_q, a_s, w_p, w_s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (128, 256, 128)])
    def test_block_shape_sweep(self, bm, bn, bk):
        a_q, a_s, w_q, w_s = _mk_w8(jax.random.PRNGKey(2), 256, 256, 256)
        out = w8a8_matmul(a_q, a_s, w_q, w_s, bm=bm, bn=bn, bk=bk,
                          interpret=True)
        want = ref.w8a8_matmul_ref(a_q, a_s, w_q, w_s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_wrapper_end_to_end_close_to_fp32(self):
        """W8A8 wrapper approximates the fp32 matmul within quant noise."""
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (64, 200))
        w = jax.random.normal(jax.random.fold_in(key, 1), (200, 130))
        w_q, w_s = ops.prepare_w8(w)
        out = ops.matmul_w8a8(x, w_q, w_s)
        want = x @ w
        err = np.abs(np.asarray(out - want))
        assert err.mean() < 0.25  # ~1% of |x@w| rms (~14)
        assert out.shape == (64, 130)

    def test_ops_w4_wrapper_shapes(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (32, 100))
        w = jax.random.normal(jax.random.PRNGKey(5), (100, 64))
        w_p, w_s = ops.prepare_w4(w)
        out = ops.matmul_w4a8(x, w_p, w_s)
        assert out.shape == (32, 64)
        rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
        # 4-bit abs-max per-column on N(0,1) weights: step ~ 3sigma/7 ->
        # ~11-12% relative error is the information-theoretic neighbourhood
        assert rel < 0.15


class TestMDDQKernel:
    @pytest.mark.parametrize("n,bits", [(1024, 8), (2048, 6), (4096, 4)])
    def test_matches_ref(self, n, bits):
        cb = make_codebook(bits)
        cb_t = ops.pad_codebook(cb)
        v = jax.random.normal(jax.random.PRNGKey(0), (n, 3)) * 3.0
        idx, mag = mddq_encode_kernel(v[:, 0].copy(), v[:, 1].copy(),
                                      v[:, 2].copy(), cb_t, bn=1024,
                                      interpret=True)
        # reference works on the padded codebook too (pad = copies of cw 0,
        # ties resolve to the first occurrence = identical index)
        idx_ref, mag_ref = ref.mddq_encode_ref(v, jnp.asarray(cb_t.T))
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
        np.testing.assert_array_equal(np.asarray(mag), np.asarray(mag_ref))

    def test_ops_wrapper_arbitrary_shape(self):
        cb_t = ops.pad_codebook(make_codebook(8))
        v = jax.random.normal(jax.random.PRNGKey(1), (7, 13, 3))
        idx, mag = ops.mddq_encode(v, cb_t)
        assert idx.shape == (7, 13) and mag.shape == (7, 13)
        idx_ref, _ = ref.mddq_encode_ref(v.reshape(-1, 3), jnp.asarray(cb_t.T))
        np.testing.assert_array_equal(np.asarray(idx).ravel(),
                                      np.asarray(idx_ref))


class TestInt8KVDecode:
    @pytest.mark.parametrize("bh,s,d,bs", [(4, 1024, 128, 512),
                                           (2, 512, 64, 256),
                                           (8, 2048, 128, 512)])
    def test_matches_ref(self, bh, s, d, bs):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (bh, d))
        k = jax.random.normal(ks[1], (bh, s, d))
        v = jax.random.normal(ks[2], (bh, s, d))
        k_q, k_s, v_q, v_s = ops.prepare_kv_int8(k, v)
        out = decode_attention_int8kv(q, k_q, k_s, v_q, v_s, bs=bs,
                                      interpret=True)
        want = ref.decode_attention_int8kv_ref(
            q, k_q, k_s, v_q, v_s, softmax_scale=1.0 / d ** 0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_close_to_fp32_attention(self):
        """int8 KV attention approximates fp32 attention."""
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 3)
        bh, s, d = 4, 512, 64
        q = jax.random.normal(ks[0], (bh, d))
        k = jax.random.normal(ks[1], (bh, s, d))
        v = jax.random.normal(ks[2], (bh, s, d))
        k_q, k_s, v_q, v_s = ops.prepare_kv_int8(k, v)
        out = ops.decode_attention_int8kv(q, k_q, k_s, v_q, v_s, bs=256)
        logits = jnp.einsum("bd,bsd->bs", q, k) / d ** 0.5
        want = jnp.einsum("bs,bsd->bd", jax.nn.softmax(logits, -1), v)
        rel = float(jnp.linalg.norm(out - want) / jnp.linalg.norm(want))
        assert rel < 0.02


class TestActQuantKernel:
    @pytest.mark.parametrize("m,k,bm", [(256, 512, 256), (512, 384, 128),
                                        (128, 1000, 64)])
    def test_matches_ref(self, m, k, bm):
        from repro.kernels.act_quant import act_quant
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 3.0
        q, s = act_quant(x, bm=bm, interpret=True)
        s_ref = abs_max_scale(x, 8, channel_axis=0)
        q_ref = quantize(x, s_ref, 8)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))

    def test_roundtrip_error_bounded(self):
        from repro.kernels.act_quant import act_quant
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 256))
        q, s = act_quant(x, interpret=True)
        err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
        assert err.max() <= float(np.asarray(s).max()) / 2 + 1e-7
