"""Numerical-correctness tests for the LM substrate: chunked implementations
against naive references, decode-vs-forward consistency, MoE invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models.lm import attention as attn_lib
from repro.models.lm import transformer as tfm
from repro.models.lm.ssm import chunked_linear_rnn, linear_rnn_step


def naive_linear_rnn(log_a, B_in, C_out, x):
    """Step-by-step reference for the chunked scan."""
    Bt, S, H, P = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    state = jnp.zeros((Bt, H, N, P))
    ys = []
    for t in range(S):
        y, state = linear_rnn_step(state, log_a[:, t], B_in[:, t],
                                   C_out[:, t], x[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state


class TestChunkedLinearRNN:
    @pytest.mark.parametrize("S,chunk,H,G", [(32, 8, 4, 1), (64, 16, 4, 4),
                                             (48, 48, 2, 2), (32, 4, 8, 2)])
    def test_matches_naive(self, S, chunk, H, G):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        Bt, N, P = 2, 8, 16
        log_a = -jax.nn.softplus(jax.random.normal(ks[0], (Bt, S, H)))
        B_in = jax.random.normal(ks[1], (Bt, S, G, N)) * 0.3
        C_out = jax.random.normal(ks[2], (Bt, S, G, N)) * 0.3
        x = jax.random.normal(ks[3], (Bt, S, H, P))
        y, st = chunked_linear_rnn(log_a, B_in, C_out, x, chunk)
        y_ref, st_ref = naive_linear_rnn(log_a, B_in, C_out, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                                   rtol=2e-3, atol=2e-3)

    @given(st.integers(0, 10000))
    @settings(max_examples=5, deadline=None)
    def test_chunk_size_invariance(self, seed):
        """Property: output independent of chunk size (the key invariant the
        chunked algorithm must satisfy)."""
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        Bt, S, H, G, N, P = 1, 24, 2, 1, 4, 8
        log_a = -jax.nn.softplus(jax.random.normal(ks[0], (Bt, S, H)))
        B_in = jax.random.normal(ks[1], (Bt, S, G, N)) * 0.5
        C_out = jax.random.normal(ks[2], (Bt, S, G, N)) * 0.5
        x = jax.random.normal(ks[3], (Bt, S, H, P))
        y1, _ = chunked_linear_rnn(log_a, B_in, C_out, x, 4)
        y2, _ = chunked_linear_rnn(log_a, B_in, C_out, x, 24)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)


class TestChunkedAttention:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_matches_full_softmax(self, chunk):
        cfg = configs.get_smoke_config("llama3.2-3b")
        cfg = dataclasses.replace(cfg, dtype=jnp.float32, attn_chunk_q=chunk)
        params = attn_lib.init_attention(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
        out = attn_lib.causal_attention(params, x, cfg)

        # naive reference
        pos = jnp.arange(64)[None, :]
        q, k, v, scale = attn_lib._project_qkv(params, x, cfg, pos)
        g = cfg.n_heads // cfg.n_kv_heads
        qr = q.reshape(2, 64, cfg.n_kv_heads, g, cfg.hd)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k) * scale
        mask = jnp.tril(jnp.ones((64, 64), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, -1)
        ref = jnp.einsum("bkgqs,bskd->bqkgd", w, v).reshape(2, 64, -1)
        from repro.models.lm.layers import qlinear
        ref = qlinear(ref, params["wo"], cfg.quant_mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", ["llama3.2-3b", "zamba2-1.2b",
                                      "xlstm-1.3b", "qwen2-0.5b"])
    def test_decode_matches_forward(self, arch):
        """Feeding tokens one-by-one through decode_step must reproduce the
        full forward pass logits — the strongest end-to-end correctness
        check for cache handling across all block types."""
        cfg = configs.get_smoke_config(arch)
        cfg = dataclasses.replace(cfg, dtype=jnp.float32, attn_chunk_q=8,
                                  ssm_chunk=8)
        S, B = 16, 2
        params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

        full_logits, _ = tfm.forward(params, cfg, tokens=tokens)

        cache = tfm.init_cache(cfg, B, S)
        step = jax.jit(lambda c, t, i: tfm.decode_step(params, cfg, c, t, i))
        outs = []
        for i in range(S):
            logits, cache = step(cache, tokens[:, i:i + 1],
                                 jnp.asarray(i, jnp.int32))
            outs.append(logits)
        dec_logits = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec_logits),
                                   np.asarray(full_logits),
                                   rtol=5e-3, atol=5e-3)


class TestMoE:
    def test_expert_outputs_combine_weighted(self):
        from repro.models.lm import moe as moe_lib
        cfg = configs.get_smoke_config("qwen3-moe-30b-a3b")
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
        params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        y, aux = moe_lib.moe_forward(params, x, cfg)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        assert float(aux) > 0.5  # balance loss ~1 for near-uniform router

    def test_capacity_drops_are_bounded(self):
        """With capacity_factor >= 1 and a near-uniform router, most tokens
        are routed (output norm not collapsed)."""
        from repro.models.lm import moe as moe_lib
        cfg = configs.get_smoke_config("qwen3-moe-30b-a3b")
        cfg = dataclasses.replace(cfg, dtype=jnp.float32, capacity_factor=2.0)
        params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
        y, _ = moe_lib.moe_forward(params, x, cfg)
        routed = np.mean(np.linalg.norm(np.asarray(y[0]), axis=-1) > 1e-6)
        assert routed > 0.9

    def test_router_fp32_under_quant(self):
        """Branch separation: router math stays fp32 in serve mode."""
        from repro.models.lm import moe as moe_lib
        cfg = configs.get_smoke_config("qwen3-moe-30b-a3b")
        params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
        assert params["router"].dtype == jnp.float32


class TestKVReplication:
    def test_decode_matches_forward_with_replication(self):
        """kv_replicate (TP-width KV head replication) is numerically
        invisible: decode must still reproduce the forward pass."""
        import dataclasses
        cfg = dataclasses.replace(configs.get_smoke_config("llama3.2-3b"),
                                  dtype=jnp.float32, attn_chunk_q=8,
                                  kv_replicate=3)
        S, B = 16, 2
        params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab)
        full, _ = tfm.forward(
            params, dataclasses.replace(cfg, kv_replicate=1), tokens=tokens)
        cache = tfm.init_cache(cfg, B, S)
        step = jax.jit(lambda c, t, i: tfm.decode_step(params, cfg, c, t, i))
        outs = []
        for i in range(S):
            lg, cache = step(cache, tokens[:, i:i + 1], jnp.asarray(i))
            outs.append(lg)
        np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                                   np.asarray(full), rtol=5e-3, atol=5e-3)
