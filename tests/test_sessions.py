"""Tests for streaming MD sessions (ISSUE 7).

Covers: session config validation, frame streaming (ordering, global
indices, chunk-aligned steps) while one-shot inference interleaves on
the same pool, typed retry-with-backoff on shed submissions, chunk
failover after an in-flight replica kill, checkpoint/resume across a
simulated process restart, and the seeded chaos acceptance run — a
w8a8 session through kill + rolling swap + corrupted checkpoint +
restart finishing with zero lost frames and a final state equal
(<= 1e-6; in practice bit-identical) to an uninterrupted run of the
same seed.
"""
import os
import time

import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterPool
from repro.md.engine import MDConfig
from repro.models import so3krates as so3
from repro.server.artifact import save_artifact
from repro.server.scheduler import SchedulerOverloaded
from repro.serving import Graph, ServeConfig
from repro.sessions import (FaultInjector, FaultSpec, SessionConfig,
                            SessionManager)

CFG = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1, n_rbf=4,
                          dir_bits=6, cutoff=3.0)
SERVE = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=4)
CLUSTER = ClusterConfig(n_replicas=2, max_batch=4, warmup=False,
                        max_queue=64)
WAIT_S = 600


def _molecule(n=12, seed=17, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return (rng.integers(0, CFG.n_species, n).astype(np.int32),
            rng.uniform(0, side, size=(n, 3)).astype(np.float32),
            np.full(n, 12.0, np.float32))


def _session_cfg(**kw):
    base = dict(n_steps=100, chunk_steps=20, record_every=10,
                checkpoint_every=2,
                md=MDConfig(mode="w8a8", dt_fs=0.25, record_every=10))
    base.update(kw)
    return SessionConfig(**base)


@pytest.fixture(scope="module")
def pool():
    with ClusterPool.from_config(CFG, serve=SERVE, cluster=CLUSTER) as p:
        yield p


def _fresh_pool():
    return ClusterPool.from_config(CFG, serve=SERVE, cluster=CLUSTER)


class TestSessionConfig:
    def test_chunk_record_alignment_enforced(self):
        with pytest.raises(ValueError, match="multiple of"):
            SessionConfig(n_steps=100, chunk_steps=25, record_every=10)

    def test_chunk_arithmetic(self):
        cfg = _session_cfg(n_steps=110)
        assert cfg.n_chunks == 6
        assert cfg.frames_per_chunk == 2
        assert [cfg.chunk_len(i) for i in range(6)] == [20] * 5 + [10]


class TestStreaming:
    def test_frames_stream_in_order_with_inference(self, pool, tmp_path):
        sp, co, masses = _molecule()
        mgr = SessionManager(pool, str(tmp_path))
        session = mgr.start(sp, co, masses, config=_session_cfg(), seed=3)
        # one-shot traffic interleaves on the same replicas mid-session
        graphs = [Graph(species=sp, coords=co + 0.01 * i) for i in range(6)]
        handles = [pool.submit(g) for g in graphs]
        results = [h.result(timeout=WAIT_S) for h in handles]
        assert all(np.isfinite(r.energy) for r in results)
        frames = list(session.frames())       # ends at session end
        assert session.wait(WAIT_S) == "done"
        assert [f.index for f in frames] == list(range(10))
        assert [f.step for f in frames] == list(range(10, 101, 10))
        assert all(np.isfinite(f.e_tot).all() for f in frames)
        # checkpoints at chunks 2, 4, and the final (5th) chunk
        assert session.n_checkpoints == 3
        assert session.steps_done == 100
        st = pool.stats()
        assert st["sessions"]["done"] >= 1
        assert st["chunks"]["n_completed"] >= 5
        assert st["router"]["n_chunks_routed"] >= 5
        mgr.close()

    def test_on_frame_callback(self, pool, tmp_path):
        sp, co, masses = _molecule(seed=5)
        seen = []
        mgr = SessionManager(pool, str(tmp_path))
        s = mgr.start(sp, co, masses, seed=1, on_frame=seen.append,
                      config=_session_cfg(n_steps=40, checkpoint_every=1))
        s.wait(WAIT_S)
        assert [f.index for f in seen] == [0, 1, 2, 3]
        mgr.close()


class TestRetry:
    def test_shed_submissions_retry_with_backoff(self, pool, tmp_path):
        """Typed retry on SchedulerOverloaded: the manager backs off by
        the scheduler's hint and the session still completes."""
        sp, co, masses = _molecule(seed=7)
        mgr = SessionManager(pool, str(tmp_path))
        real = pool.submit_chunk
        sheds = {"left": 3}

        def flaky(*a, **kw):
            if sheds["left"] > 0:
                sheds["left"] -= 1
                raise SchedulerOverloaded("synthetic shed", 0.01)
            return real(*a, **kw)

        pool.submit_chunk = flaky
        try:
            s = mgr.start(sp, co, masses, seed=2,
                          config=_session_cfg(n_steps=40))
            assert s.wait(WAIT_S) == "done"
        finally:
            pool.submit_chunk = real
        assert sheds["left"] == 0
        assert mgr.stats()["shed_retries"] == 3
        mgr.close()

    def test_retry_budget_exhaustion_fails_loudly(self, pool, tmp_path):
        sp, co, masses = _molecule(seed=9)
        mgr = SessionManager(pool, str(tmp_path))
        real = pool.submit_chunk
        pool.submit_chunk = lambda *a, **kw: (_ for _ in ()).throw(
            SchedulerOverloaded("always shed", 0.001))
        try:
            s = mgr.start(sp, co, masses, seed=2,
                          config=_session_cfg(n_steps=40, max_retries=2,
                                              backoff_s=0.001,
                                              backoff_max_s=0.002))
            with pytest.raises(SchedulerOverloaded):
                s.wait(WAIT_S)
            assert s.status == "failed"
        finally:
            pool.submit_chunk = real
        mgr.close()


class TestFailover:
    def test_in_flight_kill_fails_over_chunk(self, tmp_path):
        """A replica killed with the session's chunk in flight: the pool
        requeues the chunk onto the survivor (or the session retries),
        and the trajectory completes without loss."""
        with _fresh_pool() as pool:
            sp, co, masses = _molecule(seed=11)
            faults = FaultInjector(
                [FaultSpec(kind="kill_replica", at_chunk=2,
                           mode="in_flight")], pool)
            mgr = SessionManager(pool, str(tmp_path), faults=faults)
            s = mgr.start(sp, co, masses, seed=4, config=_session_cfg())
            assert s.wait(WAIT_S) == "done"
            assert [f.index for f in s.collected] == list(range(10))
            assert faults.counts()["kill_replica"] == 1
            st = pool.stats()
            assert st["n_live"] == 1
            # the fault engaged the recovery path one way or the other
            assert (st["chunks"]["n_requeued"] + s.n_retries) >= 1
            mgr.close()


class TestResume:
    def test_restart_resumes_from_checkpoint(self, pool, tmp_path):
        """Cancel mid-run (simulated process death), resume with a fresh
        manager: the tail replays deterministically and the full frame
        set is covered across the two incarnations."""
        sp, co, masses = _molecule(seed=13)
        mgr = SessionManager(pool, str(tmp_path))
        s = mgr.start(sp, co, masses, seed=5, config=_session_cfg())
        while s.chunks_done < 2 and not s.done():
            time.sleep(0.02)
        s.cancel()
        mgr.close()
        assert s.status in ("cancelled", "done")
        pre = {f.index for f in s.collected}

        mgr2 = SessionManager(pool, str(tmp_path))
        resumed = mgr2.resume_all()
        assert [r.session_id for r in resumed] == [s.session_id]
        r = resumed[0]
        assert r.wait(WAIT_S) == "done"
        assert r.n_restores == 1
        post = {f.index for f in r.collected}
        assert pre | post == set(range(10))
        assert mgr2.stats()["checkpoints_restored"] == 1
        mgr2.close()

    def test_completed_session_resumes_as_done(self, pool, tmp_path):
        sp, co, masses = _molecule(seed=15)
        mgr = SessionManager(pool, str(tmp_path))
        s = mgr.start(sp, co, masses, seed=6,
                      config=_session_cfg(n_steps=40))
        s.wait(WAIT_S)
        mgr.close()
        mgr2 = SessionManager(pool, str(tmp_path))
        resumed = mgr2.resume_all()
        assert len(resumed) == 1 and resumed[0].status == "done"
        assert resumed[0].done()

    def test_empty_root_resumes_nothing(self, pool, tmp_path):
        mgr = SessionManager(pool, str(tmp_path))
        assert mgr.resume_all() == []


class TestSeededChaos:
    def test_zero_frame_loss_and_deterministic_final_state(self, tmp_path):
        """The acceptance scenario at test scale (the full-size >= 2000
        step version is the sessions bench's chaos gate): a w8a8 session
        survives an in-flight replica kill, a mid-trajectory rolling
        artifact swap, a corrupted (bitflipped) newest checkpoint, and a
        simulated process restart — completing with zero lost frames
        and a final state equal to an uninterrupted run of the same
        seed to <= 1e-6 (deterministic replay of the un-checkpointed
        tail makes it bit-identical on CPU)."""
        cfg = _session_cfg(n_steps=400, chunk_steps=50, record_every=25,
                           checkpoint_every=2)
        sp, co, masses = _molecule(seed=21)
        n_frames = 16

        with _fresh_pool() as ref_pool:
            ref_mgr = SessionManager(ref_pool,
                                     str(tmp_path / "ref"))
            ref = ref_mgr.start(sp, co, masses, seed=8, config=cfg,
                                session_id="traj")
            assert ref.wait(WAIT_S) == "done"
            ref_mgr.close()

        with _fresh_pool() as pool:
            art = str(tmp_path / "weights.rpa")
            save_artifact(art, pool._replicas[0].engine)
            faults = FaultInjector(
                [FaultSpec(kind="kill_replica", at_chunk=2,
                           mode="in_flight"),
                 FaultSpec(kind="swap_artifact", at_chunk=4,
                           artifact_path=art, swap_warmup=False),
                 FaultSpec(kind="stall", at_chunk=5, stall_s=0.05),
                 FaultSpec(kind="corrupt_checkpoint", at_chunk=6,
                           corruption="bitflip")], pool, seed=8)
            mgr = SessionManager(pool, str(tmp_path / "chaos"),
                                 faults=faults)
            s = mgr.start(sp, co, masses, seed=8, config=cfg,
                          session_id="traj")
            # simulated process death after the corruption fault fired
            while s.chunks_done < 7 and not s.done():
                time.sleep(0.02)
            s.cancel()
            mgr.close()
            pre = {f.index: f for f in s.collected}
            counts = faults.counts()
            assert counts["kill_replica"] == 1
            assert counts["swap_artifact"] == 1
            assert counts["corrupt_checkpoint"] == 1

            mgr2 = SessionManager(pool, str(tmp_path / "chaos"))
            resumed = mgr2.resume_all()
            assert len(resumed) == 1
            r = resumed[0]
            assert r.wait(WAIT_S) == "done"
            post = {f.index: f for f in r.collected}
            mgr2.close()

        # zero frame loss across kill + swap + corruption + restart
        assert set(pre) | set(post) == set(range(n_frames))
        # replayed frames are identical to their first delivery
        for i in set(pre) & set(post):
            np.testing.assert_array_equal(pre[i].e_tot, post[i].e_tot)
        # the corrupted newest checkpoint forced a fallback: the resumed
        # tail replays more than zero chunks
        assert r.chunks_done == cfg.n_chunks
        # final state equality vs the uninterrupted reference
        for leaf in ("coords", "veloc"):
            np.testing.assert_allclose(
                np.asarray(getattr(r.state, leaf)),
                np.asarray(getattr(ref.state, leaf)), atol=1e-6)
        # the swap is visible in the stream: frames carry both versions
        versions = {f.artifact_version for f in list(pre.values())
                    + list(post.values())}
        assert len(versions) == 2
