"""Tests: checkpoint manager (atomicity, corruption, resume, resharding),
gradient compression (error feedback, int8 psum), sharding rules."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.optim.compression import (ef_compress, ef_init, int8_psum,
                                     int8_psum_tree)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6.0), "step": jnp.asarray(3)}}


class TestCheckpointManager:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        t = _tree()
        mgr.save(10, t, extra={"loss": 1.5})
        out = mgr.restore(10, jax.tree.map(jnp.zeros_like, t))
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b)), t, out)
        assert mgr.extra(10)["loss"] == 1.5

    def test_latest_skips_corrupt(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        t = _tree()
        mgr.save(1, t)
        mgr.save(2, t)
        # corrupt step 2: flip bytes in one array file
        d = tmp_path / "step_2"
        manifest = json.load(open(d / "manifest.json"))
        fname = next(iter(manifest["arrays"].values()))["file"]
        with open(d / fname, "r+b") as f:
            f.seek(100)
            f.write(b"\xff\xff\xff\xff")
        assert not mgr.is_valid(2)
        assert mgr.latest_step() == 1

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        t = _tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, t)
        assert mgr.all_steps() == [3, 4]

    def test_elastic_reshard_restore(self, tmp_path):
        """Restore onto explicit shardings (the rescale path)."""
        mgr = CheckpointManager(str(tmp_path), keep=1)
        t = _tree()
        mgr.save(5, t)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        out = mgr.restore(5, t, sh)
        assert out["a"].sharding == NamedSharding(mesh, P())
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]))

    def test_tmp_dir_cleanup_on_failure(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1)

        class Boom:
            shape = (2,)

            def __array__(self):
                raise RuntimeError("boom")

        with pytest.raises(Exception):
            mgr.save(1, {"x": Boom()})
        assert not [p for p in os.listdir(tmp_path) if p.startswith("step_1")]


class TestGradientCompression:
    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated update converges to the true sum."""
        g = {"w": jnp.full((64,), 0.003)}   # small grads: worst case for int8
        state = ef_init(g)
        total = jnp.zeros((64,))
        for _ in range(50):
            dq, state = ef_compress(g, state)
            total = total + dq["w"]
        np.testing.assert_allclose(np.asarray(total),
                                   np.full(64, 0.15), rtol=0.05)

    def test_compression_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,))}
        dq, state = ef_compress(g, ef_init(g))
        err = np.abs(np.asarray(dq["w"] - g["w"]))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127
        assert err.max() <= scale / 2 + 1e-6
        np.testing.assert_allclose(np.asarray(state.residual["w"]),
                                   np.asarray(g["w"] - dq["w"]), atol=1e-7)

    def test_int8_psum_shard_map(self):
        """int8 collective matches fp psum on a real (1-sized) mesh axis."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(1), (16,))

        f = shard_map(lambda v: int8_psum(v, "data"), mesh=mesh,
                      in_specs=P(), out_specs=P())
        out = f(x)
        err = np.abs(np.asarray(out - x))
        assert err.max() <= float(jnp.abs(x).max()) / 127 / 2 + 1e-6


class TestShardingRules:
    def test_param_specs_divisible(self):
        """Every sharded dim divides the mesh axis on a 4x4 mesh."""
        from repro import configs
        from repro.launch import sharding as shd
        from repro.launch.steps import abstract_params
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        for arch in ["qwen2-0.5b", "zamba2-1.2b", "xlstm-1.3b",
                     "qwen3-moe-30b-a3b"]:
            cfg = configs.get_config(arch)
            params = abstract_params(cfg)
            specs = shd.param_specs(params, cfg, mesh)
            # structure matches
            assert jax.tree.structure(params, is_leaf=lambda x: hasattr(x, "shape")) \
                .num_leaves == len(jax.tree.leaves(
                    specs, is_leaf=lambda s: hasattr(s, "index") or s is None
                    or type(s).__name__ == "PartitionSpec"))

    def test_big_weights_are_sharded_on_production_mesh(self):
        """On the 16x16 production mesh the large matrices must NOT be
        replicated (memory would not fit otherwise). Runs in a subprocess
        with 512 fake devices."""
        import subprocess
        import sys
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro import configs
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import abstract_params
mesh = make_production_mesh(multi_pod=False)
cfg = configs.get_config("qwen1.5-110b")
params = abstract_params(cfg)
specs = shd.param_specs(params, cfg, mesh)
flat = {}
def visit(path, spec):
    key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    flat[key] = spec
jax.tree_util.tree_map_with_path(visit, specs,
    is_leaf=lambda s: type(s).__name__ == "PartitionSpec")
for key in ["embed", "lm_head", "blocks/attn/wq", "blocks/mlp/wg"]:
    assert any(s is not None for s in flat[key]), f"{key} replicated!"
print("OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env=env, cwd=os.path.dirname(
                               os.path.dirname(os.path.abspath(__file__))))
        assert "OK" in r.stdout, r.stderr[-2000:]
