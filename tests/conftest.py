"""Shared test fixtures and optional-dependency shims.

``hypothesis`` is an optional test dependency (declared in pyproject.toml's
``test`` extra). When it is absent — e.g. a minimal CI container — we install
a small deterministic stand-in into ``sys.modules`` *before* test collection
so the property-based tests still run instead of erroring at import time.

The shim covers exactly the surface this suite uses:

    @given(st.integers(lo, hi))
    @settings(max_examples=N, deadline=None)
    def test_x(self, value): ...

Under the shim each ``@given`` test runs over a deterministic sample of the
strategy's domain (endpoints + evenly spaced interior points, capped at
``max_examples``). No shrinking, no randomization — strictly weaker than real
hypothesis, but the properties are still exercised on representative inputs.
"""
from __future__ import annotations

import sys
import types


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass

    class _IntegersStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, n: int):
            lo, hi = self.lo, self.hi
            span = hi - lo
            if span < n:
                return list(range(lo, hi + 1))
            # endpoints first, then evenly spaced interior points
            vals = [lo + (span * i) // max(n - 1, 1) for i in range(n)]
            seen, out = set(), []
            for v in vals:
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(strategy):
        def deco(fn):
            n = getattr(fn, "_fallback_max_examples", 10)

            def wrapper(*args, **kwargs):
                for value in strategy.sample(n):
                    fn(*args, value, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda lo, hi: _IntegersStrategy(lo, hi)
    mod.strategies = st
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_fallback()
