"""Tests for repro.obs (ISSUE 9): the unified metrics registry, the
per-request trace span model, the exporters, end-to-end trace
propagation through the serving stack, and the fleet-lifetime counter
fix on engine exchanges.

The trace-propagation pins are the acceptance scenarios:

* a guardrail-escalated request (w4a8 -> w8a8) yields one orphan-free
  span tree whose hop-1 segments attribute the escalation re-run;
* an in-flight replica kill yields a requeue hop attributed to the
  surviving replica;
* a cancelled-then-resumed MD session's chunks trace as ``kind="chunk"``
  with session/chunk attribution across both incarnations;
* the tiling invariant — child span durations sum to the end-to-end
  latency *exactly* (the state machine closes each segment where the
  next begins), which is the <= 5% acceptance gate with zero margin
  consumed.

The swap-under-traffic test pins the satellite fix: engine dispatch /
detector counters survive ``swap_artifact`` engine exchanges instead of
silently resetting.
"""
import dataclasses
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterPool
from repro.guardrails import ForceEnvelope, GuardrailConfig
from repro.md.engine import MDConfig
from repro.models import so3krates as so3
from repro.obs import (REGISTRY, TRACER, JsonlTraceSink, MetricsRegistry,
                       PeriodicExporter, RequestTrace, configure_tracing,
                       load_traces, prometheus_text, write_metrics)
from repro.server import save_artifact
from repro.server.scheduler import (MicroBatchScheduler, RequestHandle,
                                    SchedulerConfig)
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.serving.qparams import quantize_so3_params
from repro.sessions import SessionConfig, SessionManager

CFG = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1, n_rbf=4,
                          dir_bits=6, cutoff=3.0)
SERVE4 = ServeConfig(mode="w4a8", bucket_sizes=(16,), max_batch=4,
                     path="dense")
SERVE8 = dataclasses.replace(SERVE4, mode="w8a8")
WAIT_S = 600
# every finite w4a8 result flags suspect -> escalates (test_guardrails)
HAIR = GuardrailConfig(envelope=ForceEnvelope(limits=((16, 1e-9),)))
REPO = Path(__file__).resolve().parent.parent


def _graph(n=10, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return Graph(species=rng.integers(0, CFG.n_species, n).astype(np.int32),
                 coords=rng.uniform(0, side, size=(n, 3)).astype(np.float32))


@pytest.fixture(scope="module")
def params():
    import jax
    return so3.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def qp(params):
    return {t: quantize_so3_params(params, t) for t in ("w4a8", "w8a8")}


@pytest.fixture()
def traced():
    """Enable the process tracer for one test, drain + disable after."""
    configure_tracing(enabled=True)
    TRACER.reset()
    yield TRACER
    configure_tracing(enabled=False)
    TRACER.reset()


def _assert_complete(doc):
    """One orphan-free span tree whose children tile [t0, t1] exactly."""
    spans = doc["spans"]
    root, children = spans[0], spans[1:]
    assert root["parent_id"] is None
    assert root["t1"] is not None, "unfinished root span"
    assert children, "trace has no child spans"
    for s in children:
        assert s["parent_id"] == root["span_id"], f"orphan span {s}"
        assert s["t1"] is not None, f"unclosed span {s}"
    assert children[0]["t0"] == root["t0"]
    assert children[-1]["t1"] == root["t1"]
    for a, b in zip(children, children[1:]):
        assert a["t1"] == b["t0"], "gap/overlap between segments"
    total = sum(s["t1"] - s["t0"] for s in children)
    assert total == pytest.approx(doc["duration_s"], rel=1e-9, abs=1e-9)


# -- metrics registry (pure stdlib) ------------------------------------------

class TestRegistry:
    def test_instruments_keyed_by_name_and_labels(self):
        reg = MetricsRegistry()
        a = reg.counter("reqs", surface="sched")
        b = reg.counter("reqs", surface="sched")
        c = reg.counter("reqs", surface="replica")
        assert a is b and a is not c
        a.inc()
        a.inc(2.5)
        assert a.value == 3.5 and c.value == 0.0

    def test_counter_cannot_decrease(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="cannot decrease"):
            reg.counter("x").inc(-1.0)

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("dual")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("dual")

    def test_gauge_set_and_add(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(4.0)
        g.add(-1.0)
        assert g.value == 3.0

    def test_histogram_percentiles_and_moments(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat")
        vals = [0.001 * i for i in range(1, 101)]     # 1ms .. 100ms
        for v in vals:
            h.observe(v)
        assert h.count == 100
        assert h.sum == pytest.approx(sum(vals))
        # log buckets over-estimate by <= one bucket width (~19%)
        assert 0.050 <= h.percentile(0.50) <= 0.050 * 1.19
        assert 0.095 <= h.percentile(0.95) <= 0.095 * 1.19
        snap = h.snapshot()
        assert snap["min"] == pytest.approx(0.001)
        assert snap["max"] == pytest.approx(0.100)
        assert snap["p99"] <= snap["max"] + 1e-12

    def test_histogram_underflow_bucket(self):
        reg = MetricsRegistry()
        h = reg.histogram("d")
        h.observe(0.0)
        h.observe(-1.0)
        h.observe(1.0)
        assert h.count == 3
        assert h.percentile(0.5) == 0.0     # underflow reports 0.0

    def test_disabled_registry_noops_writes(self):
        reg = MetricsRegistry()
        c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
        reg.set_enabled(False)
        c.inc()
        g.set(9.0)
        h.observe(1.0)
        assert c.value == 0.0 and g.value == 0.0 and h.count == 0
        reg.set_enabled(True)
        c.inc()
        assert c.value == 1.0

    def test_snapshot_and_flat(self):
        reg = MetricsRegistry()
        reg.counter("c", mode="w4a8").inc(2)
        reg.gauge("g").set(1.5)
        reg.histogram("h").observe(0.25)
        snap = reg.snapshot()
        assert [e["name"] for e in snap["counters"]] == ["c"]
        assert snap["counters"][0]["labels"] == {"mode": "w4a8"}
        assert snap["counters"][0]["value"] == 2.0
        assert snap["histograms"][0]["count"] == 1
        flat = reg.flat()
        assert flat['c{mode="w4a8"}'] == 2.0
        assert flat["h_count"] == 1
        reg.reset()
        assert reg.snapshot() == {"counters": [], "gauges": [],
                                  "histograms": []}


# -- exporters ----------------------------------------------------------------

class TestExport:
    def test_prometheus_text_exposition(self):
        reg = MetricsRegistry()
        reg.counter("serve_requests_total", surface="sched").inc(3)
        reg.gauge("live_replicas").set(4)
        reg.histogram("wait_s").observe(0.01)
        text = prometheus_text(registry=reg)
        assert "# TYPE serve_requests_total counter" in text
        assert 'serve_requests_total{surface="sched"} 3' in text
        assert "# TYPE live_replicas gauge" in text
        assert "# TYPE wait_s summary" in text
        assert 'wait_s{quantile="0.5"}' in text
        assert "wait_s_count 1" in text
        assert "wait_s_sum 0.01" in text

    def test_write_metrics_atomic_with_timestamp(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("n").inc()
        out = tmp_path / "metrics.prom"
        write_metrics(str(out), registry=reg)
        lines = out.read_text().splitlines()
        assert lines[0].startswith("# exported_at ")
        assert "n 1" in lines
        assert not list(tmp_path.glob("*.tmp.*"))

    def test_periodic_exporter_writes_and_final_flush(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("beat").inc()
        out = tmp_path / "m.prom"
        exp = PeriodicExporter(str(out), interval_s=0.05,
                               registry=reg).start()
        deadline = time.monotonic() + 5.0
        while exp.n_exports == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        exp.stop()
        assert exp.n_exports >= 2      # >= 1 periodic + the final flush
        assert "beat 1" in out.read_text()

    def test_jsonl_sink_roundtrip(self, tmp_path):
        path = str(tmp_path / "traces.jsonl")
        with JsonlTraceSink(path) as sink:
            sink.write({"trace_id": "r-1"})
            sink.write({"trace_id": "r-2"})
            assert sink.n_written == 2
        sink.write({"trace_id": "r-3"})    # closed: dropped, no raise
        assert [t["trace_id"] for t in load_traces(path)] == ["r-1", "r-2"]


# -- trace span model (no engine) ---------------------------------------------

class TestTraceModel:
    def test_segments_tile_exactly(self):
        rt = RequestTrace("r-1", "request", t0=10.0)
        rt.begin("serve", 11.0, replica=0)
        rt.begin("queue", 11.5)            # escalation re-queue
        rt.begin("serve", 12.0, replica=2)
        rt.finish(13.0, status="ok")
        doc = rt.to_json()
        assert doc["duration_s"] == 3.0
        names = [s["name"] for s in doc["spans"][1:]]
        assert names == ["queue", "serve", "queue", "serve"]
        _assert_complete(doc)

    def test_mutators_noop_after_finish(self):
        rt = RequestTrace("r-2", "request", t0=0.0)
        rt.finish(1.0, status="ok")
        rt.begin("serve", 2.0)
        rt.event("late", 2.0)
        rt.set_attr("x", 1)
        rt.bump_hop()
        doc = rt.to_json()
        assert doc["t1"] == 1.0 and doc["hops"] == 0
        assert doc["events"] == [] and "x" not in doc["attrs"]
        assert len(doc["spans"]) == 2      # root + the birth queue span

    def test_hop_attribution_on_events_and_spans(self):
        rt = RequestTrace("r-3", "request", t0=0.0)
        rt.begin("serve", 1.0)
        rt.bump_hop()
        rt.event("requeued", 1.5, from_replica=0)
        rt.begin("queue", 1.5)
        rt.begin("serve", 2.0)
        rt.finish(3.0)
        doc = rt.to_json()
        hops = [s["attrs"]["hop"] for s in doc["spans"][1:]]
        assert hops == [0, 0, 1, 1]
        assert doc["hops"] == 1

    def test_tracer_disabled_returns_none(self):
        configure_tracing(enabled=False)
        assert TRACER.start_request() is None

    def test_tracer_collects_and_sinks(self, traced, tmp_path):
        path = str(tmp_path / "t.jsonl")
        sink = JsonlTraceSink(path)
        configure_tracing(enabled=True, sink=sink)
        tr = traced.start_request(kind="request", t0=0.0)
        tr.finish(1.0)
        docs = traced.drain()
        assert [d["trace_id"] for d in docs] == [tr.trace_id]
        assert traced.drain() == []
        # sink export is async off the resolve path; flush() is the barrier
        assert traced.flush()
        assert load_traces(path)[0]["trace_id"] == tr.trace_id
        sink.close()

    def test_sink_errors_swallowed(self, traced):
        class Boom:
            def write(self, doc):
                raise OSError("disk full")
        configure_tracing(enabled=True, sink=Boom())
        traced.start_request(t0=0.0).finish(1.0)
        assert traced.flush()
        assert traced.n_sink_errors == 1
        assert len(traced.drain()) == 1     # trace still delivered


# -- scheduler-level propagation ----------------------------------------------

class TestSchedulerTracing:
    def test_one_complete_trace_per_request(self, qp, traced):
        engine = QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=2.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            handles = [sched.submit(_graph(seed=i)) for i in range(6)]
            results = [h.result(timeout=WAIT_S) for h in handles]
        ids = [r.trace_id for r in results]
        assert all(ids) and len(set(ids)) == 6
        docs = {d["trace_id"]: d for d in traced.drain()}
        assert set(docs) == set(ids)        # exactly one trace each
        for h in handles:
            doc = docs[h.trace.trace_id]
            assert doc["status"] == "ok" and doc["hops"] == 0
            assert doc["attrs"]["bucket"] == 16
            _assert_complete(doc)
        # flush telemetry carries the member trace ids
        recorded = [tid for f in sched._flushes for tid in f.trace_ids]
        assert set(recorded) == set(ids)

    def test_rejected_submit_finishes_trace(self, qp, traced):
        # a handle rejected at submit (oversize here) is never returned,
        # so its trace must be finished on the rejection path — no
        # unfinished trace, and the rejection is observable
        engine = QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=2.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            with pytest.raises(ValueError):
                sched.submit(_graph(n=99))
        (doc,) = traced.drain()
        assert doc["status"] == "rejected"
        assert doc["attrs"]["error"] == "ValueError"
        assert traced.n_started == traced.n_finished == 1
        _assert_complete(doc)

    def test_error_trace_finishes_with_status(self, qp, traced):
        engine = QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4)
        engine.infer_batch = lambda graphs, on_flag=None: (
            (_ for _ in ()).throw(RuntimeError("boom")))
        cfg = SchedulerConfig(max_batch=1, deadline_ms=0.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            h = sched.submit(_graph())
            with pytest.raises(RuntimeError, match="boom"):
                h.result(timeout=WAIT_S)
        (doc,) = traced.drain()
        assert doc["status"] == "error"
        assert doc["attrs"]["error"] == "RuntimeError"
        _assert_complete(doc)


# -- acceptance scenario (a): guardrail escalation ----------------------------

class TestEscalationTrace:
    def test_escalated_request_trace_attributes_the_hop(self, qp, traced):
        engines = [
            QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4,
                                           guardrails=HAIR),
            QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4,
                                           guardrails=HAIR),
            QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8),
        ]
        pool = ClusterPool(engines, ClusterConfig(
            n_replicas=3, max_batch=4, deadline_ms=2.0, warmup=False,
            max_escalations=1))
        try:
            r = pool.submit(_graph(seed=11)).result(timeout=WAIT_S)
            assert len(r.escalations) == 1 and r.replica_id == 2
            assert r.trace_id
        finally:
            pool.close()
        docs = {d["trace_id"]: d for d in traced.drain()}
        doc = docs[r.trace_id]
        _assert_complete(doc)
        assert doc["hops"] == 1
        assert doc["attrs"]["n_escalations"] == 1
        (esc,) = [e for e in doc["events"] if e["name"] == "escalated"]
        assert esc["attrs"]["from_tier"] == "w4a8"
        assert esc["attrs"]["reason"] == "force_outlier"
        # hop-1 segments: a re-queue then the w8a8 re-run
        hop1 = [s for s in doc["spans"][1:] if s["attrs"]["hop"] == 1]
        assert [s["name"] for s in hop1] == ["queue", "serve"]
        assert hop1[-1]["attrs"]["tier"] == "w8a8"
        assert hop1[-1]["attrs"]["replica"] == 2


# -- acceptance scenario (b): in-flight kill + failover requeue ---------------

class TestRequeueTrace:
    def test_killed_in_flight_request_traces_the_requeue(self, qp, traced):
        pool = ClusterPool(
            [QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8)
             for _ in range(4)],
            ClusterConfig(n_replicas=4, max_batch=4, deadline_ms=2.0,
                          warmup=False))
        try:
            rep0 = pool._replicas[0]
            # arm the in-flight failure first (accepting stays True until
            # the worker picks work), then pin a request to replica 0: the
            # flush dies and the orphan fails over to a survivor
            pool.kill_replica(0, mode="in_flight")
            h = RequestHandle(_graph(seed=7), time.monotonic(),
                              bucket_capacity=16)
            assert rep0.try_submit(h)
            r = h.result(timeout=WAIT_S)
            assert np.isfinite(r.energy) and r.replica_id != 0
        finally:
            pool.close()
        docs = {d["trace_id"]: d for d in traced.drain()}
        doc = docs[h.trace.trace_id]
        _assert_complete(doc)
        assert doc["hops"] >= 1
        requeues = [e for e in doc["events"] if e["name"] == "requeued"]
        assert requeues and requeues[0]["attrs"]["from_replica"] == 0
        last_serve = [s for s in doc["spans"][1:]
                      if s["name"] == "serve"][-1]
        assert last_serve["attrs"]["replica"] == r.replica_id != 0


# -- acceptance scenario (c): session chunks across checkpoint/resume ---------

class TestChunkTrace:
    def test_resumed_session_chunks_trace_with_attribution(
            self, qp, traced, tmp_path):
        pool = ClusterPool(
            [QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8)
             for _ in range(2)],
            ClusterConfig(n_replicas=2, max_batch=4, warmup=False,
                          max_queue=64))
        try:
            rng = np.random.default_rng(13)
            n = 12
            side = (n / 0.1) ** (1.0 / 3.0)
            sp = rng.integers(0, CFG.n_species, n).astype(np.int32)
            co = rng.uniform(0, side, size=(n, 3)).astype(np.float32)
            masses = np.full(n, 12.0, np.float32)
            scfg = SessionConfig(
                n_steps=100, chunk_steps=20, record_every=10,
                checkpoint_every=2,
                md=MDConfig(mode="w8a8", dt_fs=0.25, record_every=10))
            mgr = SessionManager(pool, str(tmp_path))
            s = mgr.start(sp, co, masses, seed=5, config=scfg)
            while s.chunks_done < 2 and not s.done():
                time.sleep(0.02)
            s.cancel()
            mgr.close()

            mgr2 = SessionManager(pool, str(tmp_path))
            resumed = mgr2.resume_all()
            assert [x.session_id for x in resumed] == [s.session_id]
            assert resumed[0].wait(WAIT_S) == "done"
            assert resumed[0].n_restores == 1
            mgr2.close()
        finally:
            pool.close()
        chunk_docs = [d for d in traced.drain() if d["kind"] == "chunk"]
        assert len(chunk_docs) >= scfg.n_chunks   # both incarnations trace
        for doc in chunk_docs:
            _assert_complete(doc)
            assert doc["attrs"]["session_id"] == s.session_id
            assert doc["attrs"]["chunk_idx"] >= 0
        # the resumed tail re-runs chunks the first incarnation completed
        idxs = sorted({d["attrs"]["chunk_idx"] for d in chunk_docs})
        assert idxs == list(range(scfg.n_chunks))
        # the restore landed in the unified metrics plane
        restored = REGISTRY.counter("session_events_total",
                                    event="checkpoint_restored")
        assert restored.value >= 1


# -- satellite: counters survive engine exchanges -----------------------------

class TestSwapCounterContinuity:
    def test_dispatch_totals_survive_swap_under_traffic(self, tmp_path):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=4)
        pool = ClusterPool.from_config(
            CFG, serve=serve,
            cluster=ClusterConfig(n_replicas=2, max_batch=4,
                                  deadline_ms=2.0, warmup=False), seed=0)
        try:
            graphs = [_graph(seed=100 + i) for i in range(8)]
            pool.infer(graphs, timeout=WAIT_S)
            before = dict(pool.stats()["engine_dispatch"])
            assert sum(before.values()) >= 1

            art = str(tmp_path / "v2.npz")
            save_artifact(art, QuantizedEngine.from_config(
                CFG, serve=serve, seed=99))
            report = pool.swap_artifact(art, warmup=False)
            assert len(report["replicas"]) == 2

            pool.infer([_graph(seed=200 + i) for i in range(4)],
                       timeout=WAIT_S)
            stats = pool.stats()
            after = stats["engine_dispatch"]
            # fleet-lifetime totals: pre-swap counts are retained and
            # post-swap traffic adds on top (the pre-fix behaviour reset
            # these to the fresh engines' zeros)
            for k, v in before.items():
                assert after.get(k, 0) >= v
            assert sum(after.values()) > sum(before.values())
            assert stats["n_engines_retired"] >= 2
        finally:
            pool.close()


# -- trace_report CLI ----------------------------------------------------------

class TestTraceReport:
    def test_report_renders_breakdown_table(self, qp, traced, tmp_path):
        path = str(tmp_path / "traces.jsonl")
        sink = JsonlTraceSink(path)
        configure_tracing(enabled=True, sink=sink)
        engine = QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4)
        cfg = SchedulerConfig(max_batch=4, deadline_ms=2.0, warmup=False)
        with MicroBatchScheduler(engine, cfg) as sched:
            hs = [sched.submit(_graph(seed=i)) for i in range(4)]
            for h in hs:
                h.result(timeout=WAIT_S)
        assert TRACER.flush()     # async export: barrier before reading
        sink.close()
        proc = subprocess.run(
            [sys.executable, str(REPO / "scripts" / "trace_report.py"),
             path], capture_output=True, text=True, timeout=120)
        assert proc.returncode == 0, proc.stderr
        assert "4 trace(s)" in proc.stdout
        for seg in ("queue wait", "compute", "escalation/requeue",
                    "end-to-end"):
            assert seg in proc.stdout
