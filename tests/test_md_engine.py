"""Tests for the device-resident MD subsystem (ISSUE 3).

Covers: the vectorized host edge-list builder against the original
per-molecule loop, the jittable device builder against the host builder,
the Verlet-skin conservativeness guarantee (zero missed cutoff edges
over 1000+ steps), skin-list trajectories matching fresh-rebuild-every-
step trajectories, bounded-drift + rotation-consistent short NVE runs on
the quantized path, replica batching independence, the ``nve_trajectory``
remainder fix, and the serving-engine MD bridge.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.md import MDConfig, MDEngine, nve_trajectory, pad_replicas
from repro.md.neighbor import build_neighbor_list, needs_rebuild
from repro.md.nve import MDState
from repro.models import so3krates as so3
from repro.serving import QuantizedEngine, ServeConfig
from repro.serving.bucketing import (EdgeList, build_edge_list, count_edges,
                                     device_edge_list)

CFG = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1, n_rbf=4,
                          dir_bits=6, cutoff=3.0)


def _padded_batch(ns, cap, seed=0, spread=2.0):
    rng = np.random.default_rng(seed)
    B = len(ns)
    species = np.zeros((B, cap), np.int32)
    coords = np.zeros((B, cap, 3), np.float32)
    mask = np.zeros((B, cap), bool)
    for b, n in enumerate(ns):
        species[b, :n] = rng.integers(0, CFG.n_species, n)
        coords[b, :n] = rng.normal(size=(n, 3)) * spread
        mask[b, :n] = True
    return species, coords, mask


def _molecule(n, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return (rng.integers(0, CFG.n_species, n).astype(np.int32),
            rng.uniform(0, side, size=(n, 3)).astype(np.float32))


def _loop_build_edge_list(coords, mask, cutoff, edge_capacity):
    """The original per-molecule Python-loop builder (pre-vectorization)
    — kept verbatim as the reference the vectorized layout is pinned to."""
    B, cap = mask.shape
    d = np.linalg.norm(coords[:, :, None, :] - coords[:, None, :, :],
                       axis=-1)
    pair = ((d < cutoff) & ~np.eye(cap, dtype=bool)[None]
            & mask[:, :, None] & mask[:, None, :])
    senders = np.zeros(B * edge_capacity, dtype=np.int32)
    receivers = np.zeros(B * edge_capacity, dtype=np.int32)
    edge_mask = np.zeros(B * edge_capacity, dtype=bool)
    n_real = 0
    for b in range(B):
        i, j = np.nonzero(pair[b])
        e = i.shape[0]
        if e > edge_capacity:
            return None
        lo = b * edge_capacity
        receivers[lo:lo + e] = b * cap + i
        senders[lo:lo + e] = b * cap + j
        edge_mask[lo:lo + e] = True
        receivers[lo + e:lo + edge_capacity] = b * cap
        senders[lo + e:lo + edge_capacity] = b * cap
        n_real += e
    return EdgeList(senders=senders, receivers=receivers,
                    edge_mask=edge_mask, edge_capacity=edge_capacity,
                    n_real=n_real)


class TestVectorizedHostBuilder:
    @pytest.mark.parametrize("ns,cap,ec", [([5, 16, 1, 9], 16, 256),
                                           ([12, 30], 32, 512),
                                           ([3], 8, 128)])
    def test_matches_loop_reference(self, ns, cap, ec):
        for seed in range(3):
            _, coords, mask = _padded_batch(ns, cap, seed=seed)
            got = build_edge_list(coords, mask, CFG.cutoff, ec)
            want = _loop_build_edge_list(coords, mask, CFG.cutoff, ec)
            assert got.n_real == want.n_real
            np.testing.assert_array_equal(got.senders, want.senders)
            np.testing.assert_array_equal(got.receivers, want.receivers)
            np.testing.assert_array_equal(got.edge_mask, want.edge_mask)

    def test_overflow_matches_loop(self):
        _, coords, mask = _padded_batch([16, 16], 16, seed=2, spread=0.4)
        assert _loop_build_edge_list(coords, mask, CFG.cutoff, 128) is None
        assert build_edge_list(coords, mask, CFG.cutoff, 128) is None

    def test_capacity_beyond_complete_graph(self):
        # ec > cap^2: every real edge still fits, surplus slots are padding
        _, coords, mask = _padded_batch([4], 4, seed=1, spread=0.5)
        el = build_edge_list(coords, mask, CFG.cutoff, 128)
        assert el is not None and el.n_real == 12
        assert el.edge_mask.sum() == 12


class TestDeviceBuilder:
    @pytest.mark.parametrize("ns,cap,ec", [([5, 16, 1, 9], 16, 256),
                                           ([12, 30, 7], 32, 512)])
    def test_matches_host(self, ns, cap, ec):
        for seed in range(3):
            _, coords, mask = _padded_batch(ns, cap, seed=10 + seed)
            host = build_edge_list(coords, mask, CFG.cutoff, ec)
            s, r, m, counts = jax.jit(
                device_edge_list, static_argnums=(2, 3))(
                jnp.asarray(coords), jnp.asarray(mask), CFG.cutoff, ec)
            np.testing.assert_array_equal(np.asarray(s), host.senders)
            np.testing.assert_array_equal(np.asarray(r), host.receivers)
            np.testing.assert_array_equal(np.asarray(m), host.edge_mask)
            assert int(np.asarray(counts).sum()) == host.n_real

    def test_overflow_flag_not_none(self):
        """Where the host builder bails with None, the device builder
        returns per-molecule counts exceeding the capacity."""
        _, coords, mask = _padded_batch([16, 16], 16, seed=2, spread=0.4)
        _, _, _, counts = device_edge_list(jnp.asarray(coords),
                                           jnp.asarray(mask),
                                           CFG.cutoff, 128)
        want = count_edges(coords, mask, CFG.cutoff)
        np.testing.assert_array_equal(np.asarray(counts), want)
        assert bool((np.asarray(counts) > 128).any())


def _engine(mode="fp32", **kw):
    params = so3.init_params(jax.random.PRNGKey(0), CFG)
    return MDEngine(CFG, params, md=MDConfig(mode=mode, dt_fs=0.25,
                                             record_every=10, **kw))


class TestSkinList:
    def test_skin_trajectory_matches_fresh_rebuild(self):
        """The same trajectory falls out whether the list is rebuilt
        every step (skin=0) or reused under the skin criterion — the
        per-step cutoff refinement makes the edge sets identical."""
        sp, co = _molecule(20, seed=3)
        spec, coords, mask = pad_replicas(sp, co, 1)
        masses = np.full(spec.shape[1], 12.0, np.float32)
        key = jax.random.PRNGKey(5)
        results = []
        for skin in (0.0, 0.6):
            eng = _engine(skin=skin)
            st = eng.init_state(key, spec, coords, mask, masses, 300.0,
                                edge_capacity=640)
            st, rec = eng.run(st, spec, mask, masses, n_steps=40)
            results.append((np.asarray(st.coords), rec))
        (c_fresh, r_fresh), (c_skin, r_skin) = results
        assert r_fresh["n_rebuilds"] == 40      # skin=0 expires every step
        assert r_skin["n_rebuilds"] < 40
        np.testing.assert_allclose(c_skin, c_fresh, atol=1e-4)
        np.testing.assert_allclose(r_skin["e_tot"], r_fresh["e_tot"],
                                   atol=1e-4)

    def test_conservative_over_1000_steps(self):
        """Acceptance: zero missed cutoff edges vs fresh rebuild over
        >= 1000 steps — the skin/2 displacement criterion is provably
        conservative, and MDConfig.track_missed audits it on device
        every step."""
        sp, co = _molecule(20, seed=4)
        spec, coords, mask = pad_replicas(sp, co, 1)
        masses = np.full(spec.shape[1], 12.0, np.float32)
        eng = _engine(skin=0.5, track_missed=True)
        st = eng.init_state(jax.random.PRNGKey(6), spec, coords, mask,
                            masses, 250.0, edge_capacity=640)
        st, rec = eng.run(st, spec, mask, masses, n_steps=1100,
                          record_every=100)
        assert rec["missed_edges"] == 0
        # the skin actually deferred rebuilds (it is a real skin list,
        # not a fresh build per step) yet still rebuilt when needed
        assert 0 < rec["n_rebuilds"] < 1100
        assert np.isfinite(rec["e_tot"]).all()

    def test_refined_mask_equals_fresh_edge_set(self):
        """Static check of the refinement identity: skin list tightened
        to the true cutoff == fresh cutoff list, as adjacency sets."""
        from repro.kernels import ops
        _, coords, mask = _padded_batch([14, 9], 16, seed=7)
        cap = 16
        nl = build_neighbor_list(jnp.asarray(coords), jnp.asarray(mask),
                                 CFG.cutoff, 0.8, 256)
        # move atoms by < skin/2 and compare edge sets at the new coords
        rng = np.random.default_rng(8)
        delta = rng.normal(size=coords.shape).astype(np.float32)
        delta *= 0.3 / np.linalg.norm(delta, axis=-1, keepdims=True)
        moved = jnp.asarray(coords + delta * mask[..., None])
        assert not bool(needs_rebuild(nl, moved, jnp.asarray(mask), 0.8))
        em = ops.refine_edge_mask(moved.reshape(-1, 3), nl.senders,
                                  nl.receivers, nl.edge_mask, CFG.cutoff)
        s2, r2, m2, _ = device_edge_list(moved, jnp.asarray(mask),
                                         CFG.cutoff, 256)
        skin_set = set(zip(np.asarray(nl.senders)[np.asarray(em)],
                           np.asarray(nl.receivers)[np.asarray(em)]))
        fresh_set = set(zip(np.asarray(s2)[np.asarray(m2)],
                            np.asarray(r2)[np.asarray(m2)]))
        assert skin_set == fresh_set


class TestMDEngineNVE:
    def test_w8a8_bounded_drift_and_finite(self):
        """Short quantized NVE run: finite, energy bounded (the paper's
        serving-side stability claim at reduced scale)."""
        sp, co = _molecule(20, seed=9)
        spec, coords, mask = pad_replicas(sp, co, 1)
        masses = np.full(spec.shape[1], 12.0, np.float32)
        eng = _engine(mode="w8a8")
        st = eng.init_state(jax.random.PRNGKey(1), spec, coords, mask,
                            masses, 200.0)
        st, rec = eng.run(st, spec, mask, masses, n_steps=120,
                          record_every=20)
        e = rec["e_tot"][:, 0]
        assert np.isfinite(e).all()
        # bounded drift: total-energy excursion small relative to the
        # kinetic energy scale of the run
        e_kin_scale = abs(rec["e_tot"][0, 0] - rec["e_pot"][0, 0])
        assert np.abs(e - e[0]).max() < 5.0 * max(e_kin_scale, 1e-3)

    def test_rotation_consistent_trajectory(self):
        """Exact SO(3) path (quant_vectors=False): integrating a rotated
        start == rotating the integrated endpoint, up to fp accumulation
        over the trajectory. The MDDQ-bounded analogue is covered by the
        LEE diagnostics in test_sparse_serving."""
        from helpers.equivariance import assert_rotation_equivariant
        sp, co = _molecule(16, seed=11)
        spec, coords, mask = pad_replicas(sp, co, 1)
        masses = np.full(spec.shape[1], 12.0, np.float32)
        eng = _engine(mode="w8a8", quant_vectors=False)
        v0 = np.asarray(eng.init_state(jax.random.PRNGKey(3), spec, coords,
                                       mask, masses, 200.0).veloc)

        def run(c, R):
            # the sampled initial velocities co-rotate with the frame
            st = eng.init_state(jax.random.PRNGKey(3), spec, c, mask,
                                masses, 200.0)
            st = st._replace(veloc=jnp.asarray(v0 @ R.T))
            e_pot, forces = eng._energy_forces(jnp.asarray(spec),
                                               jnp.asarray(c),
                                               jnp.asarray(mask), st.nlist)
            st = st._replace(forces=forces, e_pot=e_pot)
            st, _ = eng.run(st, spec, mask, masses, n_steps=25)
            return None, np.asarray(st.coords)

        assert_rotation_equivariant(run, coords, seed=2, atol=2e-3)

    def test_replica_batch_matches_single(self):
        """A replica integrated inside a padded batch matches the same
        replica integrated alone — padding exactness extends to MD."""
        sp, co = _molecule(12, seed=13)
        masses_one = np.full(16, 12.0, np.float32)
        eng = _engine(mode="w8a8")
        spec1, co1, mask1 = pad_replicas(sp, co, 1, capacity=16)
        st0 = eng.init_state(jax.random.PRNGKey(4), spec1, co1, mask1,
                             masses_one, 200.0, edge_capacity=256)
        st1, rec1 = eng.run(st0, spec1, mask1, masses_one, n_steps=20)

        specB, coB, maskB = pad_replicas(sp, co, 3, capacity=16)
        massesB = np.broadcast_to(masses_one, (3, 16))
        stB = eng.init_state(jax.random.PRNGKey(4), specB, coB, maskB,
                             massesB, 200.0, edge_capacity=256)
        # same per-replica dynamics requires same initial velocities
        stB = stB._replace(veloc=jnp.broadcast_to(st0.veloc,
                                                  stB.veloc.shape))
        stB, recB = eng.run(stB, specB, maskB, massesB, n_steps=20)
        for b in range(3):
            np.testing.assert_allclose(np.asarray(stB.coords)[b],
                                       np.asarray(st1.coords)[0],
                                       atol=1e-5)
        np.testing.assert_allclose(recB["e_tot"][:, 0], rec1["e_tot"][:, 0],
                                   atol=1e-5)

    def test_overflow_raises(self):
        sp, co = _molecule(16, seed=15, density=2.0)  # dense cluster
        spec, coords, mask = pad_replicas(sp, co, 1)
        masses = np.full(16, 12.0, np.float32)
        eng = _engine(mode="fp32")
        with pytest.raises(ValueError, match="overflow"):
            eng.init_state(jax.random.PRNGKey(0), spec, coords, mask,
                           masses, 300.0, edge_capacity=128)

    def test_serving_engine_bridge(self):
        """QuantizedEngine.md_engine shares quantized weights with the
        serving engine and runs."""
        params = so3.init_params(jax.random.PRNGKey(0), CFG)
        serve = QuantizedEngine(CFG, params,
                                ServeConfig(mode="w8a8",
                                            bucket_sizes=(16,),
                                            max_batch=4))
        eng = serve.md_engine()
        assert eng.qparams is serve.qparams
        sp, co = _molecule(12, seed=17)
        spec, coords, mask = pad_replicas(sp, co, 1, capacity=16)
        masses = np.full(16, 12.0, np.float32)
        st = eng.init_state(jax.random.PRNGKey(0), spec, coords, mask,
                            masses, 200.0)
        st, rec = eng.run(st, spec, mask, masses, n_steps=10)
        assert np.isfinite(rec["e_tot"]).all()
        with pytest.raises(ValueError, match="mode"):
            serve.md_engine(MDConfig(mode="fp32"))


class TestNveTrajectoryTail:
    def test_remainder_steps_are_integrated(self):
        """4000 @ record_every=300 used to run only 3900 steps; now the
        remainder is integrated and sampled (reduced scale: 11 @ 4)."""
        masses = jnp.ones(3)
        k = jnp.asarray([[1.0, 0, 0], [0, 1.0, 0], [0, 0, 1.0]])
        force_fn = lambda c: -c          # isotropic harmonic well
        energy_fn = lambda c: 0.5 * jnp.sum(c ** 2)
        c0 = jnp.asarray(np.random.default_rng(0).normal(size=(3, 3)),
                         jnp.float32)
        s0 = MDState(coords=c0, veloc=jnp.zeros_like(c0),
                     forces=force_fn(c0))
        s_tail, e_tail = nve_trajectory(s0, masses, force_fn, energy_fn,
                                        dt_fs=0.5, n_steps=11,
                                        record_every=4)
        s_full, e_full = nve_trajectory(s0, masses, force_fn, energy_fn,
                                        dt_fs=0.5, n_steps=11,
                                        record_every=11)
        assert e_tail.shape[0] == 3      # ceil(11 / 4)
        np.testing.assert_allclose(np.asarray(s_tail.coords),
                                   np.asarray(s_full.coords), atol=1e-6)
        np.testing.assert_allclose(float(e_tail[-1]), float(e_full[-1]),
                                   atol=1e-6)

    def test_divisible_unchanged(self):
        masses = jnp.ones(2)
        force_fn = lambda c: -c
        energy_fn = lambda c: 0.5 * jnp.sum(c ** 2)
        c0 = jnp.asarray([[1.0, 0, 0], [0, 1.0, 0]])
        s0 = MDState(coords=c0, veloc=jnp.zeros_like(c0),
                     forces=force_fn(c0))
        _, e = nve_trajectory(s0, masses, force_fn, energy_fn,
                              dt_fs=0.5, n_steps=12, record_every=4)
        assert e.shape[0] == 3
