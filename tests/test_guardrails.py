"""Tests for the runtime physics guardrail layer (ISSUE 8).

Covers: the detector primitives (non-finite, calibrated force envelope,
tier ladder helpers), engine-level raise/mark triage with the sampled
LEE probe, typed GuardrailViolation delivery through the single-engine
scheduler, typed per-request deadlines (``RequestTimeout``), the
consecutive-error counter reset pin on the replica worker, tiered-pool
escalation with bit-identical re-runs at the higher tier, the
circuit-breaker quarantine + cold-restart path, the stall watchdog
against the fault injector's engine-lock stall, the four-surface
NaN-poison acceptance (direct engine, scheduler, 4-replica pool,
MDEngine — a caller never receives a silent NaN), MD checkpoint
monitors (non-finite + energy drift), and session-level precision-tier
escalation of a drifting MD chunk.
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterPool, Replica
from repro.guardrails import (EscalationRecord, ForceEnvelope,
                              GuardrailConfig, GuardrailViolation, TIER_ORDER,
                              check_finite_tree, check_result, next_tier,
                              tier_rank)
from repro.md.engine import MDConfig, MDEngine
from repro.models import so3krates as so3
from repro.server.scheduler import (MicroBatchScheduler, RequestHandle,
                                    RequestTimeout, SchedulerConfig,
                                    SchedulerClosed, SchedulerOverloaded)
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.serving.engine import MoleculeResult
from repro.serving.qparams import quantize_so3_params
from repro.sessions import SessionConfig, SessionManager

CFG = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1, n_rbf=4,
                          dir_bits=6, cutoff=3.0)
# the dense path is the one NaN coordinates propagate through (the
# sparse host edge build drops NaN-distance pairs), so every poison
# test below forces it
SERVE4 = ServeConfig(mode="w4a8", bucket_sizes=(16,), max_batch=4,
                     path="dense")
SERVE8 = dataclasses.replace(SERVE4, mode="w8a8")
WAIT_S = 600
# hair-trigger envelope: any real molecule's forces exceed 1e-9 eV/A,
# so every finite result flags "force_outlier" (suspect)
HAIR = GuardrailConfig(envelope=ForceEnvelope(limits=((16, 1e-9),)))


def _graph(n=10, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    side = (n / density) ** (1.0 / 3.0)
    return Graph(species=rng.integers(0, CFG.n_species, n).astype(np.int32),
                 coords=rng.uniform(0, side, size=(n, 3)).astype(np.float32))


def _poison(n=10, seed=3):
    g = _graph(n, seed)
    coords = g.coords.copy()
    coords[0] = np.nan
    return Graph(species=g.species, coords=coords)


@pytest.fixture(scope="module")
def params():
    return so3.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def qp(params):
    return {t: quantize_so3_params(params, t) for t in ("w4a8", "w8a8")}


@pytest.fixture(scope="module")
def guarded_engine(qp):
    # default guardrails: non-finite check on, on_flag="raise"
    return QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4)


@pytest.fixture(scope="module")
def ref8(qp):
    return QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8)


# -- detectors (pure numpy) --------------------------------------------------

class TestDetectors:
    def test_nonfinite_is_fatal_and_first(self):
        cfg = GuardrailConfig(envelope=ForceEnvelope(limits=((16, 1e-9),)))
        flags = check_result(np.nan, np.full((16, 3), np.nan), 16, cfg)
        assert len(flags) == 1            # garbage norms are not reported
        assert flags[0].reason == "nonfinite" and flags[0].fatal

    def test_envelope_flags_suspect_outlier(self):
        cfg = GuardrailConfig(envelope=ForceEnvelope(limits=((16, 0.5),)))
        f = np.zeros((16, 3), np.float32)
        f[3, 0] = 2.0
        flags = check_result(-1.0, f, 16, cfg)
        assert [x.reason for x in flags] == ["force_outlier"]
        assert not flags[0].fatal
        assert flags[0].value == pytest.approx(2.0)
        assert flags[0].limit == pytest.approx(0.5)
        # unknown bucket -> no limit -> no flag
        assert check_result(-1.0, f, 32, cfg) == ()

    def test_clean_result_unflagged(self):
        cfg = GuardrailConfig(envelope=ForceEnvelope(limits=((16, 10.0),)))
        assert check_result(-1.0, np.ones((16, 3), np.float32), 16, cfg) == ()

    def test_calibrate_builds_per_bucket_limits(self):
        def res(cap, peak):
            f = np.zeros((cap, 3), np.float32)
            f[0, 0] = peak
            return MoleculeResult(energy=-1.0, forces=f, n_atoms=cap,
                                  bucket_capacity=cap, batch_size=1)
        env = ForceEnvelope.calibrate(
            [res(16, 2.0), res(16, 3.0), res(32, 0.01)],
            factor=4.0, floor=1.0)
        assert env.limit_for(16) == pytest.approx(12.0)   # 4 x max observed
        assert env.limit_for(32) == pytest.approx(1.0)    # floored
        assert env.limit_for(64) is None

    def test_check_finite_tree(self):
        clean = {"a": np.ones(3), "b": np.zeros((2, 2))}
        assert check_finite_tree(clean) is None
        clean["b"] = np.array([[1.0, np.inf], [0.0, 0.0]])
        assert check_finite_tree(clean) == "b"

    def test_config_validation(self):
        with pytest.raises(ValueError, match="on_flag"):
            GuardrailConfig(on_flag="explode")
        with pytest.raises(ValueError, match="lee_probe_every"):
            GuardrailConfig(lee_probe_every=-1)
        assert not GuardrailConfig(check_finite=False).active
        assert GuardrailConfig().active

    def test_tier_ladder(self):
        assert TIER_ORDER == ("w4a8", "w8a8", "fp32")
        assert [tier_rank(t) for t in TIER_ORDER] == [0, 1, 2]
        assert next_tier("w4a8") == "w8a8"
        assert next_tier("w8a8") == "fp32"
        assert next_tier("fp32") is None
        with pytest.raises(ValueError):
            tier_rank("w2a4")


# -- engine surface ----------------------------------------------------------

class TestEngineGuardrails:
    def test_poison_raises_typed_violation(self, guarded_engine):
        with pytest.raises(GuardrailViolation) as ei:
            guarded_engine.infer_batch([_poison()])
        assert ei.value.reason == "nonfinite"
        assert ei.value.severity == "fatal"
        assert ei.value.detail["mode"] == "w4a8"

    def test_mark_mode_annotates_instead_of_raising(self, guarded_engine):
        results = guarded_engine.infer_batch([_graph(), _poison()],
                                             on_flag="mark")
        assert results[0].flags == ()
        assert [f.reason for f in results[1].flags] == ["nonfinite"]
        snap = guarded_engine.guard_snapshot()
        assert snap["checked"] >= 2
        assert snap["flagged_nonfinite"] >= 1

    def test_envelope_marks_every_result(self, qp):
        eng = QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4,
                                             guardrails=HAIR)
        results = eng.infer_batch([_graph(8), _graph(12, seed=1)],
                                  on_flag="mark")
        for r in results:
            assert [f.reason for f in r.flags] == ["force_outlier"]
            assert np.isfinite(r.energy)
        assert eng.guard_snapshot()["flagged_outlier"] >= 2

    def test_lee_probe_samples_batches(self, qp):
        # generous limit: the probe runs but never flags clean traffic
        eng = QuantizedEngine.from_quantized(
            CFG, qp["w4a8"], SERVE4,
            guardrails=GuardrailConfig(lee_probe_every=1, lee_limit=1e6))
        results = eng.infer_batch([_graph(), _graph(seed=1)], on_flag="mark")
        assert all(r.flags == () for r in results)
        assert eng.guard_snapshot()["lee_probes"] == 1
        # hair-trigger limit: the same probe flags every molecule
        eng2 = QuantizedEngine.from_quantized(
            CFG, qp["w4a8"], SERVE4,
            guardrails=GuardrailConfig(lee_probe_every=1, lee_limit=0.0))
        flagged = eng2.infer_batch([_graph()], on_flag="mark")
        assert [f.reason for f in flagged[0].flags] == ["lee"]
        assert eng2.guard_snapshot()["flagged_lee"] == 1

    def test_inactive_config_skips_checks(self, qp):
        eng = QuantizedEngine.from_quantized(
            CFG, qp["w4a8"], SERVE4,
            guardrails=GuardrailConfig(check_finite=False))
        # the unguarded A/B baseline: NaN passes through unflagged
        r = eng.infer_batch([_poison()])[0]
        assert not np.isfinite(r.energy)
        assert r.flags == ()
        assert eng.guard_snapshot()["checked"] == 0


# -- scheduler surface -------------------------------------------------------

class TestSchedulerGuardrails:
    def test_poison_resolves_typed_error_clean_unaffected(self,
                                                          guarded_engine):
        with MicroBatchScheduler(
                guarded_engine,
                SchedulerConfig(max_batch=4, deadline_ms=2.0,
                                warmup=False)) as sched:
            clean = [sched.submit(_graph(seed=s)) for s in range(3)]
            bad = sched.submit(_poison())
            for h in clean:
                assert np.isfinite(h.result(timeout=WAIT_S).energy)
            with pytest.raises(GuardrailViolation) as ei:
                bad.result(timeout=WAIT_S)
            assert ei.value.reason == "nonfinite"
            assert sched.stats()["n_guard_flagged"] >= 1


# -- typed deadlines (satellite a) -------------------------------------------

class TestRequestTimeout:
    def test_unresolved_handle_times_out_typed(self):
        h = RequestHandle(None, time.monotonic())
        t0 = time.monotonic()
        with pytest.raises(RequestTimeout):
            h.result(timeout_s=0.05)
        assert time.monotonic() - t0 < 5.0
        assert issubclass(RequestTimeout, TimeoutError)

    def test_timeout_s_wins_over_legacy_timeout(self):
        h = RequestHandle(None, time.monotonic())
        with pytest.raises(RequestTimeout):
            h.result(timeout=30.0, timeout_s=0.05)

    def test_legacy_timeout_stays_catchable_as_timeouterror(self):
        # pre-PR-8 callers catch TimeoutError; the typed error is a
        # subclass, so the legacy kwarg keeps working unchanged
        h = RequestHandle(None, time.monotonic())
        with pytest.raises(TimeoutError):
            h.result(timeout=0.05)


# -- replica error-counter reset pin (satellite c) ---------------------------

class _ScriptedEngine:
    """Minimal engine stub: pops one scripted outcome per flush —
    an exception instance raises, anything else returns clean results."""

    def __init__(self, script):
        self.serve = SERVE4
        self.device = None
        self.artifact_version = ""
        self.script = list(script)

    def warmup(self):
        return 0.0

    def infer_batch(self, graphs, on_flag=None):
        act = self.script.pop(0)
        if isinstance(act, BaseException):
            raise act
        return [MoleculeResult(energy=-1.0,
                               forces=np.zeros((16, 3), np.float32),
                               n_atoms=g.n_atoms, bucket_capacity=16,
                               batch_size=len(graphs)) for g in graphs]


class TestConsecutiveErrorReset:
    def test_mid_window_success_resets_counter(self):
        """Two errors, a success, two errors again: the consecutive
        error counter must reset on the success, so the replica (with
        MAX_CONSECUTIVE_ERRORS=3) survives 4 total errors — only 3 in
        a row kill it."""
        boom = [RuntimeError(f"boom{i}") for i in range(7)]
        script = [boom[0], boom[1], "ok", boom[2], boom[3], "ok"]
        failures = []
        rep = Replica(0, _ScriptedEngine(script),
                      SchedulerConfig(max_batch=1, deadline_ms=0.0,
                                      warmup=False, max_queue=None),
                      on_failure=lambda r, orphans, e: failures.append(e),
                      warmup=False)
        try:
            for want_error in (True, True, False, True, True, False):
                h = RequestHandle(_graph(), time.monotonic(),
                                  bucket_capacity=16)
                assert rep.try_submit(h)
                if want_error:
                    with pytest.raises(RuntimeError, match="boom"):
                        h.result(timeout=WAIT_S)
                else:
                    assert h.result(timeout=WAIT_S).energy == -1.0
            assert rep.accepting
            assert failures == []
            # ...and three in a row still kill it
            rep2 = Replica(1, _ScriptedEngine([boom[4], boom[5], boom[6]]),
                           SchedulerConfig(max_batch=1, deadline_ms=0.0,
                                           warmup=False, max_queue=None),
                           on_failure=lambda r, orphans, e:
                               failures.append(e),
                           warmup=False)
            try:
                for _ in range(3):
                    h = RequestHandle(_graph(), time.monotonic(),
                                      bucket_capacity=16)
                    assert rep2.try_submit(h)
                    with pytest.raises(RuntimeError, match="boom"):
                        h.result(timeout=WAIT_S)
                deadline = time.monotonic() + 10.0
                while rep2.accepting and time.monotonic() < deadline:
                    time.sleep(0.01)
                assert not rep2.accepting
                assert len(failures) == 1
            finally:
                rep2.close()
        finally:
            rep.close()


# -- tiered escalation -------------------------------------------------------

@pytest.fixture(scope="module")
def tiered_pool(qp):
    """Two hair-trigger w4a8 traffic replicas + one w8a8 escalation
    replica: every finite w4a8 result flags suspect and escalates."""
    engines = [
        QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4,
                                       guardrails=HAIR),
        QuantizedEngine.from_quantized(CFG, qp["w4a8"], SERVE4,
                                       guardrails=HAIR),
        QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8),
    ]
    pool = ClusterPool(engines, ClusterConfig(n_replicas=3, max_batch=4,
                                              deadline_ms=2.0, warmup=False,
                                              max_escalations=1))
    yield pool
    pool.close()


class TestTieredEscalation:
    def test_escalated_result_is_bit_identical_to_direct_w8a8(
            self, tiered_pool, ref8):
        g = _graph(10, seed=11)
        r = tiered_pool.submit(g).result(timeout=WAIT_S)
        assert len(r.escalations) == 1
        rec = r.escalations[0]
        assert isinstance(rec, EscalationRecord)
        assert rec.from_tier == "w4a8"
        assert rec.to_tier == "w8a8"
        assert rec.reason == "force_outlier"
        assert r.replica_id == 2          # served by the escalation replica
        assert r.flags == ()              # w8a8 has no envelope
        direct = ref8.infer_batch([g])[0]
        assert r.energy == direct.energy
        assert np.array_equal(np.asarray(r.forces),
                              np.asarray(direct.forces))

    def test_escalation_budget_then_typed_fatal(self, tiered_pool):
        """NaN flags fatal at w4a8, re-runs once at w8a8 (still NaN),
        and with the budget spent resolves a typed error — never a
        silent NaN."""
        h = tiered_pool.submit(_poison(seed=23))
        with pytest.raises(GuardrailViolation) as ei:
            h.result(timeout=WAIT_S)
        assert ei.value.reason == "nonfinite"
        assert ei.value.detail["mode"] == "w8a8"   # failed at the top hop
        assert len(h.escalations) == 1
        assert h.escalations[0].reason == "nonfinite"

    def test_stats_expose_tiers_and_escalations(self, tiered_pool):
        st = tiered_pool.stats()
        assert st["tiers"] == {"w4a8": 2, "w8a8": 1}
        gr = st["guardrails"]
        assert gr["n_flagged"] >= 2
        assert gr["n_escalated"] >= 2
        assert gr["detectors"]["flagged_outlier"] >= 1


# -- circuit breaker / quarantine --------------------------------------------

class TestCircuitBreaker:
    def test_flag_storm_trips_breaker_and_respawns(self, qp):
        """A single-tier fleet whose every result flags suspect: the
        watchdog's breaker must quarantine + cold-restart a replica
        while every submitted request still resolves (zero lost)."""
        engines = [QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8,
                                                  guardrails=HAIR)
                   for _ in range(2)]
        pool = ClusterPool(engines, ClusterConfig(
            n_replicas=2, max_batch=4, deadline_ms=2.0, warmup=False,
            breaker_window=8, breaker_flag_rate=0.5, breaker_min_events=4,
            watchdog_interval_s=0.05, probation_s=30.0, max_quarantines=1))
        try:
            delivered = 0
            for i in range(16):
                # stop feeding once the breaker fired: a second trip
                # with the first replica still on probation would leave
                # an outstanding handle nowhere to requeue
                if pool.stats()["guardrails"]["n_breaker_trips"] >= 1:
                    break
                try:
                    r = pool.submit(_graph(seed=i)).result(timeout=WAIT_S)
                except (SchedulerOverloaded, SchedulerClosed):
                    time.sleep(0.05)
                    continue              # fleet momentarily unroutable
                # suspect with no higher tier -> delivered annotated
                assert np.isfinite(r.energy)
                assert [f.reason for f in r.flags] == ["force_outlier"]
                delivered += 1
            assert delivered >= 4         # enough to arm the breaker
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline:
                gr = pool.stats()["guardrails"]
                if gr["n_breaker_trips"] >= 1:
                    break
                time.sleep(0.05)
            gr = pool.stats()["guardrails"]
            assert gr["n_breaker_trips"] >= 1
            assert gr["n_quarantined"] >= 1
            assert gr["n_respawned"] >= 1
            # respawned replica is held on probation, not serving
            snaps = pool.stats()["replicas"]
            assert any(s["on_probation"] for s in snaps)
        finally:
            pool.close()


# -- stall watchdog ----------------------------------------------------------

class TestStallWatchdog:
    def test_stalled_worker_quarantined_requests_failover(self, qp):
        pool = ClusterPool(
            [QuantizedEngine.from_quantized(CFG, qp["w8a8"], SERVE8)
             for _ in range(2)],
            # warmup=True: the watchdog cannot tell a first-flush
            # compile from a stall, so a watchdog fleet pre-compiles
            ClusterConfig(n_replicas=2, max_batch=4, deadline_ms=2.0,
                          warmup=True, stall_timeout_s=0.4,
                          watchdog_interval_s=0.05, probation_s=0.1))
        try:
            rep0 = pool._replicas[0]
            rep0.inject_stall(30.0)
            # pin one request to the stalling replica, spread a few more
            pinned = RequestHandle(_graph(seed=41), time.monotonic(),
                                   bucket_capacity=16)
            assert rep0.try_submit(pinned)
            others = [pool.submit(_graph(seed=50 + i)) for i in range(3)]
            t0 = time.monotonic()
            results = [pinned.result(timeout=WAIT_S)] \
                + [h.result(timeout=WAIT_S) for h in others]
            # failover beat the stall: nothing waited out the 30s sleep
            assert time.monotonic() - t0 < 25.0
            for r in results:
                assert np.isfinite(r.energy)
            assert pinned.n_requeues >= 1
            assert pinned.replica_id == 1   # survivor completed it
            gr = pool.stats()["guardrails"]
            assert gr["n_stalls_detected"] >= 1
            assert gr["n_quarantined"] >= 1
            # failover resolves the handles before the cold restart
            # finishes (warmup=True re-JITs): poll for the respawn
            deadline = time.monotonic() + 60.0
            while (pool.stats()["guardrails"]["n_respawned"] < 1
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            assert pool.stats()["guardrails"]["n_respawned"] >= 1
        finally:
            pool.close()


# -- MD checkpoint monitors --------------------------------------------------

def _md_batch(n=8, seed=5):
    rng = np.random.default_rng(seed)
    side = (n / 0.1) ** (1.0 / 3.0)
    species = rng.integers(0, CFG.n_species, (1, n)).astype(np.int32)
    coords = rng.uniform(0, side, size=(1, n, 3)).astype(np.float32)
    mask = np.ones((1, n), bool)
    masses = np.full(n, 12.0, np.float32)
    return species, coords, mask, masses


class TestMDGuardrails:
    def test_clean_run_passes_finite_check(self, params):
        eng = MDEngine(CFG, params=params,
                       md=MDConfig(mode="w8a8", dt_fs=0.25, record_every=5))
        sp, co, mask, masses = _md_batch()
        st = eng.init_state(jax.random.PRNGKey(1), sp, co, mask, masses)
        _, rec = eng.run(st, sp, mask, masses, n_steps=10)
        assert np.isfinite(rec["e_tot"]).all()

    def test_nonfinite_state_raises_typed(self, params):
        eng = MDEngine(CFG, params=params,
                       md=MDConfig(mode="w8a8", dt_fs=0.25, record_every=5))
        sp, co, mask, masses = _md_batch()
        st = eng.init_state(jax.random.PRNGKey(1), sp, co, mask, masses)
        st = st._replace(veloc=np.full_like(np.asarray(st.veloc), np.nan))
        with pytest.raises(GuardrailViolation) as ei:
            eng.run(st, sp, mask, masses, n_steps=10)
        assert ei.value.reason == "nonfinite"
        assert ei.value.severity == "fatal"
        assert ei.value.detail["mode"] == "w8a8"

    def test_drift_limit_raises_suspect(self, params):
        eng = MDEngine(CFG, params=params,
                       md=MDConfig(mode="w4a8", dt_fs=0.5, record_every=5,
                                   drift_limit=1e-12))
        sp, co, mask, masses = _md_batch(seed=9)
        st = eng.init_state(jax.random.PRNGKey(2), sp, co, mask, masses)
        with pytest.raises(GuardrailViolation) as ei:
            eng.run(st, sp, mask, masses, n_steps=20)
        assert ei.value.reason == "energy_drift"
        assert ei.value.severity == "suspect"
        assert ei.value.detail["mode"] == "w4a8"
        assert ei.value.detail["value"] > ei.value.detail["limit"]

    def test_drift_limit_validation(self):
        with pytest.raises(ValueError, match="drift_limit"):
            MDConfig(drift_limit=0.0)


# -- session-level tier escalation -------------------------------------------

class TestSessionEscalation:
    def test_drifting_chunk_escalates_then_fails_typed(self, params,
                                                       tmp_path):
        """drift_limit=1e-12 fails every tier: the manager re-runs the
        chunk once at w8a8 (min_tier routing), then surfaces the typed
        error from the escalated tier."""
        pool = ClusterPool.from_tiers(
            CFG, params=params, serve=SERVE4,
            tier_plan={"w4a8": 1, "w8a8": 1},
            cluster=ClusterConfig(n_replicas=2, max_batch=4,
                                  deadline_ms=2.0, warmup=False))
        try:
            mgr = SessionManager(pool, str(tmp_path))
            rng = np.random.default_rng(13)
            n = 10
            side = (n / 0.1) ** (1.0 / 3.0)
            session = mgr.start(
                rng.integers(0, CFG.n_species, n).astype(np.int32),
                rng.uniform(0, side, size=(n, 3)).astype(np.float32),
                np.full(n, 12.0, np.float32),
                config=SessionConfig(
                    n_steps=20, chunk_steps=20, record_every=5,
                    max_escalations=1,
                    md=MDConfig(mode="w4a8", dt_fs=0.5, record_every=5,
                                drift_limit=1e-12)),
                seed=7)
            with pytest.raises(GuardrailViolation) as ei:
                session.wait(WAIT_S)
            assert ei.value.reason == "energy_drift"
            assert ei.value.detail["mode"] == "w8a8"   # the escalated tier
            assert session.status == "failed"
            assert session.n_escalations == 1
            st = pool.stats()
            assert st["sessions"]["chunk_escalations"] == 1
            assert st["sessions"]["failed"] == 1
            mgr.close()
        finally:
            pool.close()


# -- four-surface NaN-poison acceptance (satellite d) ------------------------

@pytest.fixture(scope="module")
def pool4(qp):
    pool = ClusterPool.from_quantized(
        CFG, qp["w4a8"], SERVE4,
        cluster=ClusterConfig(n_replicas=4, max_batch=4, deadline_ms=2.0,
                              warmup=False))
    yield pool
    pool.close()


class TestFourSurfacePoison:
    """One NaN molecule through each serving surface: a typed error (or
    tier escalation, covered above) every time — never a silent NaN."""

    def test_direct_engine(self, guarded_engine):
        with pytest.raises(GuardrailViolation):
            guarded_engine.infer_batch([_poison(seed=31)])

    def test_scheduler(self, guarded_engine):
        with MicroBatchScheduler(
                guarded_engine,
                SchedulerConfig(max_batch=4, deadline_ms=2.0,
                                warmup=False)) as sched:
            with pytest.raises(GuardrailViolation):
                sched.submit(_poison(seed=32)).result(timeout=WAIT_S)

    def test_replica_pool(self, pool4):
        clean = [pool4.submit(_graph(seed=60 + i)) for i in range(4)]
        bad = pool4.submit(_poison(seed=33))
        for h in clean:
            assert np.isfinite(h.result(timeout=WAIT_S).energy)
        with pytest.raises(GuardrailViolation) as ei:
            bad.result(timeout=WAIT_S)
        assert ei.value.reason == "nonfinite"
        # single-tier pool: fatal resolves locally, no escalation hops
        assert bad.escalations == []

    def test_md_engine(self, params):
        eng = MDEngine(CFG, params=params,
                       md=MDConfig(mode="w4a8", dt_fs=0.25, record_every=5))
        sp, co, mask, masses = _md_batch(seed=21)
        st = eng.init_state(jax.random.PRNGKey(3), sp, co, mask, masses)
        st = st._replace(coords=np.where(mask[..., None],
                                         np.nan, np.asarray(st.coords)))
        with pytest.raises(GuardrailViolation):
            eng.run(st, sp, mask, masses, n_steps=10)

    # kept last: the injected stalls linger on pool4's replicas until
    # their next unit of work, so nothing else should reuse the fixture
    def test_pool_result_deadline_is_typed(self, pool4):
        for rep in pool4._replicas:
            rep.inject_stall(1.0)
        h = pool4.submit(_graph(seed=70))
        with pytest.raises(RequestTimeout):
            h.result(timeout_s=0.05)
        # the same handle still resolves once the stall clears
        assert np.isfinite(h.result(timeout=WAIT_S).energy)
