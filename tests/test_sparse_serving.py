"""Tests for the sparse edge-list serving path.

Covers the bucketing edge-capacity contract (ISSUE 2): neighbour-list
layout, sparse == dense agreement on energies AND forces, exact-zero
padding, rotation equivariance of the served model on padded
multi-molecule batches, engine path dispatch with dense fallback, and
the serve-time MDDQ kernel flag.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers.equivariance import assert_rotation_equivariant
from repro.models import so3krates as so3
from repro.serving import (BucketSpec, Graph, QuantizedEngine, ServeConfig,
                           build_edge_list, count_edges,
                           default_edge_capacity, quantize_so3_params,
                           random_graphs)
from repro.serving.forward import (batched_energy_and_forces,
                                   sparse_energy_and_forces)

CFG = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2, n_rbf=8,
                          dir_bits=6, cutoff=3.0)


def _padded_batch(ns, cap, seed=0, spread=2.5):
    rng = np.random.default_rng(seed)
    B = len(ns)
    species = np.zeros((B, cap), np.int32)
    coords = np.zeros((B, cap, 3), np.float32)
    mask = np.zeros((B, cap), bool)
    for b, n in enumerate(ns):
        species[b, :n] = rng.integers(0, CFG.n_species, n)
        coords[b, :n] = rng.normal(size=(n, 3)) * spread
        mask[b, :n] = True
    return species, coords, mask


@pytest.fixture(scope="module")
def qparams_w8():
    params = so3.init_params(jax.random.PRNGKey(0), CFG)
    return quantize_so3_params(params, "w8a8")


class TestEdgeListBuilder:
    def test_layout_contract(self):
        """Per-molecule slot ranges, receiver-sorted real edges first,
        masked padding self-loops inside the molecule's node range."""
        _, coords, mask = _padded_batch([5, 12, 1], cap=16, seed=1)
        ec = 256
        el = build_edge_list(coords, mask, CFG.cutoff, ec)
        assert el is not None and el.edge_capacity == ec
        counts = count_edges(coords, mask, CFG.cutoff)
        assert el.n_real == int(counts.sum())
        for b in range(3):
            lo = b * ec
            sl = slice(lo, lo + ec)
            # every slot's endpoints live in molecule b's node range
            assert np.all(el.receivers[sl] // 16 == b)
            assert np.all(el.senders[sl] // 16 == b)
            e = int(counts[b])
            assert el.edge_mask[sl].sum() == e
            # real edges first, receiver-sorted; padding is self-loops
            assert np.all(np.diff(el.receivers[lo:lo + e]) >= 0)
            assert np.all(el.receivers[lo + e:lo + ec] == b * 16)
            assert np.all(el.senders[lo + e:lo + ec] == b * 16)
            # real edges are the dense pair set: no self-pairs, both real
            real_s, real_r = el.senders[lo:lo + e], el.receivers[lo:lo + e]
            assert np.all(real_s != real_r)
            assert mask.reshape(-1)[real_s].all()
            assert mask.reshape(-1)[real_r].all()

    def test_overflow_returns_none(self):
        _, coords, mask = _padded_batch([16, 16], cap=16, seed=2, spread=0.5)
        # spread 0.5 under cutoff 3.0 -> complete graph, 240 edges/molecule
        assert build_edge_list(coords, mask, CFG.cutoff, 128) is None
        assert build_edge_list(coords, mask, CFG.cutoff, 256) is not None

    def test_default_edge_capacity_alignment(self):
        for cap in (16, 32, 64, 128):
            ec = default_edge_capacity(cap)
            assert ec % 128 == 0
            assert ec >= min(cap * (cap - 1), 128)
        # small buckets hold the complete graph
        assert default_edge_capacity(16) >= 16 * 15

    def test_bucketspec_rejects_misaligned_capacity(self):
        with pytest.raises(ValueError, match="multiple of 128"):
            _ = BucketSpec(16, edge_capacity=200).edges


class TestSparseMatchesDense:
    @pytest.mark.parametrize("mode", ["w8a8", "w4a8"])
    @pytest.mark.parametrize("edge_kernel", [False, True])
    def test_energies_and_forces(self, mode, edge_kernel):
        """Sparse path == dense oracle <= 1e-5 on randomized padded
        batches, for both the XLA segment ops and the fused Pallas
        kernel, including exact zeros on padded atoms."""
        params = so3.init_params(jax.random.PRNGKey(1), CFG)
        qp = quantize_so3_params(params, mode)
        species, coords, mask = _padded_batch([5, 16, 9, 12], cap=16, seed=3)
        el = build_edge_list(coords, mask, CFG.cutoff, 256)
        e_d, f_d = batched_energy_and_forces(
            qp, CFG, jnp.asarray(species), jnp.asarray(coords),
            jnp.asarray(mask))
        e_s, f_s = sparse_energy_and_forces(
            qp, CFG, jnp.asarray(species), jnp.asarray(coords),
            jnp.asarray(mask), jnp.asarray(el.senders),
            jnp.asarray(el.receivers), jnp.asarray(el.edge_mask),
            edge_kernel=edge_kernel)
        np.testing.assert_allclose(np.asarray(e_s), np.asarray(e_d),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(f_s), np.asarray(f_d),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(f_s)[~mask], 0.0)

    def test_isolated_and_empty_molecules(self, qparams_w8):
        """Zero-edge molecules (single atoms, far pairs) and all-padding
        rows are finite and zero-force on the sparse path."""
        species = np.zeros((2, 16), np.int32)
        coords = np.zeros((2, 16, 3), np.float32)
        mask = np.zeros((2, 16), bool)
        species[0, :2] = 1
        coords[0, 1] = [50.0, 0, 0]   # far pair: no edges
        mask[0, :2] = True            # row 1: all padding
        el = build_edge_list(coords, mask, CFG.cutoff, 256)
        assert el.n_real == 0
        e, f = sparse_energy_and_forces(
            qparams_w8, CFG, jnp.asarray(species), jnp.asarray(coords),
            jnp.asarray(mask), jnp.asarray(el.senders),
            jnp.asarray(el.receivers), jnp.asarray(el.edge_mask))
        assert np.isfinite(np.asarray(e)).all()
        assert np.isfinite(np.asarray(f)).all()
        np.testing.assert_array_equal(np.asarray(f)[~mask], 0.0)


class TestSparseEquivariance:
    @pytest.mark.parametrize("edge_kernel", [False, True])
    def test_energy_invariant_forces_covariant(self, edge_kernel):
        """Rotating a padded multi-molecule batch leaves sparse-path
        energies invariant and rotates forces: F(R.G) == R F(G).

        quant_vectors=False isolates the architecture's exact SO(3)
        equivariance (the invariant branch is bitwise unaffected by
        rotation, so even the integer kernels commute); MDDQ's bounded
        LEE is covered separately by engine.lee_diagnostic tests.
        """
        params = so3.init_params(jax.random.PRNGKey(2), CFG)
        qp = quantize_so3_params(params, "w8a8")
        species, coords, mask = _padded_batch([7, 16, 11], cap=16, seed=5)

        def run(c, _R):
            el = build_edge_list(c, mask, CFG.cutoff, 256)
            return sparse_energy_and_forces(
                qp, CFG, jnp.asarray(species), jnp.asarray(c),
                jnp.asarray(mask), jnp.asarray(el.senders),
                jnp.asarray(el.receivers), jnp.asarray(el.edge_mask),
                quant_vectors=False, edge_kernel=edge_kernel)

        # pinned rotation: a generic R can flip an int8 rounding bin via
        # fp-level distance jitter, costing ~1e-4 on one molecule's energy
        from repro.core.lee import random_rotations
        R = np.asarray(random_rotations(jax.random.PRNGKey(4), 1)[0],
                       np.float32)
        assert_rotation_equivariant(run, coords, R=R, atol=1e-5, mask=mask)


class TestEnginePaths:
    def test_sparse_engine_matches_dense_engine(self):
        serve_s = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8,
                              path="sparse")
        serve_d = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8,
                              path="dense")
        params = so3.init_params(jax.random.PRNGKey(0), CFG)
        eng_s = QuantizedEngine(CFG, params, serve_s)
        eng_d = QuantizedEngine(CFG, params, serve_d)
        graphs = random_graphs(6, 4, 16, CFG.n_species, seed=7, density=0.1)
        rs = eng_s.infer_batch(graphs)
        rd = eng_d.infer_batch(graphs)
        assert all(r.path == "sparse" for r in rs)
        assert all(r.path == "dense" for r in rd)
        assert eng_s.dispatch_stats["sparse"] > 0
        assert eng_s.dispatch_stats["dense"] == 0
        for a, b in zip(rs, rd):
            assert abs(a.energy - b.energy) <= 1e-5
            np.testing.assert_allclose(a.forces, b.forces, atol=1e-5)

    def test_auto_profitability_heuristic(self):
        """"auto" keeps small buckets dense (edge slots ~ pair count, so
        the gather overhead cannot pay off) and goes sparse where n^2
        dwarfs the edge capacity — matching the measured crossover."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16, 32, 64, 128),
                            max_batch=8, path="auto")
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        verdicts = {b.capacity: engine._sparse_profitable(b)
                    for b in engine._buckets}
        assert verdicts == {16: False, 32: False, 64: True, 128: True}
        # forced "sparse" overrides profitability
        eng_forced = QuantizedEngine.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8, path="sparse"), seed=0)
        assert eng_forced._wants_sparse(eng_forced._buckets[0])

    def test_dense_fallback_on_edge_overflow(self):
        """A batch whose cutoff graph exceeds the edge capacity runs
        dense — same results, counted in dispatch_stats."""
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8,
                            path="sparse", edge_capacity=128)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        dense_g = [Graph(species=np.ones(16, np.int32),
                         coords=(np.random.default_rng(8).normal(
                             size=(16, 3)) * 0.5).astype(np.float32))]
        (r,) = engine.infer_batch(dense_g)
        assert r.path == "dense"
        assert engine.dispatch_stats["sparse_fallback"] == 1
        occ = engine.edge_occupancy(dense_g)
        assert occ["molecules_overflowing"] >= 1

    def test_warmup_covers_sparse_shapes(self):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8,
                            path="sparse")
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        engine.warmup()
        assert ("sparse", 8, 16, 256) in engine.compiled_shapes
        before = set(engine.compiled_shapes)
        engine.infer_batch(random_graphs(3, 4, 16, CFG.n_species, seed=9,
                                         density=0.1))
        assert engine.compiled_shapes == before

    def test_lee_diagnostic_on_sparse_path(self):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8,
                            path="sparse")
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        graphs = random_graphs(4, 4, 12, CFG.n_species, seed=11, density=0.1)
        diag = engine.lee_diagnostic(graphs, jax.random.PRNGKey(0),
                                     n_rotations=2)
        assert np.isfinite(diag["lee_mean"]) and diag["lee_mean"] >= 0.0


class TestMddqKernelFlag:
    def test_mddq_kernel_matches_reference(self, qparams_w8):
        """ServeConfig.mddq_kernel routes vector quantization through the
        Pallas encode kernel; values and forces match the fake-quant
        reference (identical codes, identical STE backward)."""
        species, coords, mask = _padded_batch([5, 10], cap=16, seed=13)
        el = build_edge_list(coords, mask, CFG.cutoff, 256)
        args = (qparams_w8, CFG, jnp.asarray(species), jnp.asarray(coords),
                jnp.asarray(mask), jnp.asarray(el.senders),
                jnp.asarray(el.receivers), jnp.asarray(el.edge_mask))
        e_ref, f_ref = sparse_energy_and_forces(*args, mddq_kernel=False)
        e_ker, f_ker = sparse_energy_and_forces(*args, mddq_kernel=True)
        np.testing.assert_allclose(np.asarray(e_ker), np.asarray(e_ref),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(f_ker), np.asarray(f_ref),
                                   atol=1e-5)

    def test_engine_end_to_end_with_mddq_kernel(self):
        serve = ServeConfig(mode="w8a8", bucket_sizes=(16,), max_batch=8,
                            path="sparse", mddq_kernel=True)
        engine = QuantizedEngine.from_config(CFG, serve=serve, seed=0)
        results = engine.infer_batch(
            random_graphs(3, 4, 12, CFG.n_species, seed=15, density=0.1))
        for r in results:
            assert np.isfinite(r.energy) and np.isfinite(r.forces).all()
