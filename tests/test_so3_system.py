"""System-level tests for the paper's model: equivariance, conservativity,
QAT behaviour, MD integration, data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers.equivariance import (
    assert_energy_rotation_invariant,
    assert_energy_translation_invariant,
    assert_permutation_equivariant,
    assert_rotation_equivariant,
)
from repro.core import lee, make_codebook, random_rotation
from repro.data.synthetic_md import make_ff, sample_dataset, sample_dataset_md
from repro.md.nve import energy_drift_rate, init_state, nve_trajectory
from repro.models import so3krates as so3

CFG = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=2, dir_bits=8)
MASSES = jnp.array([12.011] * 12 + [14.007] * 2 + [1.008] * 10)


@pytest.fixture(scope="module")
def setup():
    data = sample_dataset(jax.random.PRNGKey(0), 8)
    params = so3.init_params(jax.random.PRNGKey(1), CFG)
    return data, params


class TestEquivariance:
    def test_fp32_energy_invariant(self, setup):
        data, params = setup
        cfg = dataclasses.replace(CFG, quant="none")
        assert_energy_rotation_invariant(
            lambda c: so3.energy(params, cfg, data["species"], c),
            data["coords"][0], seed=2)

    def test_fp32_forces_equivariant(self, setup):
        data, params = setup
        cfg = dataclasses.replace(CFG, quant="none")
        assert_rotation_equivariant(
            lambda c, _R: (None, so3.forces(params, cfg, data["species"], c)),
            data["coords"][0], seed=3, atol=1e-4)

    def test_translation_invariance(self, setup):
        data, params = setup
        cfg = dataclasses.replace(CFG, quant="none")
        assert_energy_translation_invariant(
            lambda c: so3.energy(params, cfg, data["species"], c),
            data["coords"][0])

    def test_gaq_lee_bounded_by_codebook(self, setup):
        """Quantized-model LEE shrinks as the codebook refines."""
        data, params = setup
        errs = {}
        for bits in (6, 12):
            cfg = dataclasses.replace(CFG, quant="gaq_w4a8", dir_bits=bits)
            cb = make_codebook(bits)
            f = lambda c: so3.forces(params, cfg, data["species"], c, cb)
            R = random_rotation(jax.random.PRNGKey(4))
            errs[bits] = float(lee(f, data["coords"][0], R))
        assert errs[12] < errs[6] + 1e-9

    def test_permutation_equivariance(self, setup):
        """Permuting atoms permutes forces (GNN invariant)."""
        data, params = setup
        cfg = dataclasses.replace(CFG, quant="none")
        assert_permutation_equivariant(
            lambda sp, c: so3.forces(params, cfg, jnp.asarray(sp),
                                     jnp.asarray(c)),
            data["species"], data["coords"][0])


class TestConservativity:
    def test_forces_are_gradient_field(self, setup):
        """Finite-difference check F = -dE/dr."""
        data, params = setup
        cfg = dataclasses.replace(CFG, quant="none")
        coords = data["coords"][0]
        f = so3.forces(params, cfg, data["species"], coords)
        eps = 1e-3
        for (i, d) in [(0, 0), (5, 1), (13, 2)]:
            dp = coords.at[i, d].add(eps)
            dm = coords.at[i, d].add(-eps)
            ep = so3.energy(params, cfg, data["species"], dp)
            em = so3.energy(params, cfg, data["species"], dm)
            fd = -(float(ep) - float(em)) / (2 * eps)
            assert abs(fd - float(f[i, d])) < 2e-2


class TestData:
    def test_classical_ff_forces_conservative(self):
        eq, sp, ff = make_ff()
        f = ff.forces(eq)
        eps = 1e-4
        dp = eq.at[3, 1].add(eps)
        dm = eq.at[3, 1].add(-eps)
        fd = -(float(ff.energy(dp)) - float(ff.energy(dm))) / (2 * eps)
        assert abs(fd - float(f[3, 1])) < 1e-2

    def test_md_sampled_dataset_thermal(self):
        """MD frames have finite, standardized labels and move away from eq."""
        d = sample_dataset_md(jax.random.PRNGKey(0), 16, stride=10)
        assert d["coords"].shape == (16, 24, 3)
        assert np.isfinite(np.asarray(d["energy"])).all()
        assert float(jnp.std(d["energy"])) == pytest.approx(1.0, rel=0.05)
        eq, _, _ = make_ff()
        disp = jnp.linalg.norm(d["coords"] - eq[None], axis=-1).mean()
        assert 0.01 < float(disp) < 1.0


class TestNVEIntegrator:
    def test_harmonic_oscillator_energy_conserved(self):
        """Two atoms on a spring: drift ~ 0 over many periods."""
        k, r0 = 5.0, 1.5

        def energy(c):
            d = jnp.linalg.norm(c[0] - c[1])
            return k * (d - r0) ** 2

        force = lambda c: -jax.grad(energy)(c)
        coords = jnp.array([[0.0, 0, 0], [1.8, 0, 0]])
        masses = jnp.ones((2,)) * 12.0
        st = init_state(jax.random.PRNGKey(0), coords, masses, force, 300.0)
        _, e = nve_trajectory(st, masses, force, energy, 0.5, 4000, 40)
        assert float(jnp.max(e) - jnp.min(e)) < 0.02 * abs(float(e[0])) + 1e-3
        assert abs(energy_drift_rate(e, 0.5, 40, 2)) < 1e-3
