"""Tests for repro.cluster: the replica pool, the shape-aware router,
rolling artifact hot swap, and failover.

The invariants under test:

* **routing identity** — any molecule routed through a 4-replica pool
  yields the same energy/forces (<= 1e-6) as a direct
  ``engine.infer_batch([g])``, for mixed-size traffic across buckets —
  which replica served it must be unobservable in the numbers;
* **hot swap** — a rolling ``swap_artifact`` mid-traffic drops zero
  requests, and post-swap results are *bit-identical* to an engine
  cold-started from the new artifact;
* **failover** — a killed replica (including an in-flight failure)
  loses zero requests: everything it held is requeued to survivors;
* **bounded admission** — over ``max_queue`` the pool sheds with
  ``SchedulerOverloaded`` + a retry hint instead of queueing unboundedly.

These tests adapt to the device count: under plain tier-1 (1 CPU
device) all replicas share the device — every policy/failure invariant
still holds; the CI ``cluster-smoke`` job reruns them under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` where replicas
are genuinely device-pinned (``test_replicas_pinned_to_distinct_devices``
only runs there).
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.models import so3krates as so3
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.server import (SchedulerClosed, SchedulerOverloaded, load_engine,
                          save_artifact)
from repro.cluster import ClusterConfig, ClusterPool

CFG = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2, n_rbf=8,
                          dir_bits=6, cutoff=3.0)
SERVE = ServeConfig(mode="w8a8", bucket_sizes=(16, 32), max_batch=8)
RESULT_TIMEOUT = 300   # generous: CPU-interpret compiles inside flushes


def _graphs(ns, seed=0, density=0.1):
    rng = np.random.default_rng(seed)
    out = []
    for n in ns:
        side = (n / density) ** (1.0 / 3.0)
        out.append(Graph(
            species=rng.integers(0, CFG.n_species, n).astype(np.int32),
            coords=rng.uniform(0, side, (n, 3)).astype(np.float32)))
    return out


@pytest.fixture(scope="module")
def pool():
    """4 replicas (device-pinned when 4 devices exist), warmed once."""
    p = ClusterPool.from_config(
        CFG, serve=SERVE,
        cluster=ClusterConfig(n_replicas=4, deadline_ms=5.0), seed=0)
    yield p
    p.close()


@pytest.fixture(scope="module")
def ref_engine():
    """Single reference engine with the pool's exact weights (seed 0)."""
    return QuantizedEngine.from_config(CFG, serve=SERVE, seed=0)


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    """Two packed artifacts with different weights (seed 0 / seed 99)."""
    d = tmp_path_factory.mktemp("cluster_artifacts")
    paths = {}
    for tag, seed in (("v1", 0), ("v2", 99)):
        eng = QuantizedEngine.from_config(CFG, serve=SERVE, seed=seed)
        paths[tag] = str(d / f"{tag}.npz")
        save_artifact(paths[tag], eng)
    return paths


class TestRoutingIdentity:
    def test_mixed_size_traffic_matches_direct_engine(self, pool, ref_engine):
        """Molecules through the 4-replica router == per-molecule direct
        infer_batch, <= 1e-6, regardless of which replica served them."""
        graphs = _graphs([5, 30, 12, 7, 25, 16, 9, 32, 11, 28, 6, 19],
                         seed=1)
        results = pool.infer(graphs, timeout=RESULT_TIMEOUT)
        for g, r in zip(graphs, results):
            (direct,) = ref_engine.infer_batch([g])
            assert abs(r.energy - direct.energy) <= 1e-6
            np.testing.assert_allclose(r.forces, direct.forces, atol=1e-6)
            assert r.n_atoms == g.n_atoms

    def test_replica_id_tagged_into_results_and_stats(self, pool):
        """Results and flush telemetry carry replica ids; routing spreads
        load across more than one replica under concurrent traffic."""
        graphs = _graphs([10, 24, 12, 30, 8, 26, 14, 20] * 3, seed=2)
        results = pool.infer(graphs, timeout=RESULT_TIMEOUT)
        used = {r.replica_id for r in results}
        assert used <= set(range(pool.n_replicas))
        assert len(used) > 1, "JSQ router never spread load"
        stats = pool.stats()
        assert stats["n_completed"] >= len(graphs)
        assert set(stats["router"]["routed_per_replica"]) <= {
            str(i) for i in range(pool.n_replicas)}
        # per-replica flush breakdown (stats.py) covers the used replicas
        assert {int(k) for k in stats["per_replica"]} >= used
        for snap in stats["replicas"]:
            assert snap["alive"]
            assert snap["heartbeat_age_s"] >= 0.0

    def test_bucket_affinity_prefers_samebucket_queue(self, pool):
        """With equal queue depths, the router sends a request to the
        replica already holding its shape class (batch-formation
        affinity) — probed through the routing function directly."""
        rep = pool._route(16)
        (g,) = _graphs([10], seed=3)
        h_probe = pool.submit(g)
        # while that request waits (deadline 5ms, so race-free only via
        # depth probe): the router must now prefer rep for bucket 16 if
        # its queue holds it
        target = pool._route(16)
        if rep.depth_of(16) > 0:          # not yet flushed
            assert target.replica_id == rep.replica_id
        h_probe.result(timeout=RESULT_TIMEOUT)

    def test_oversize_molecule_raises_at_submit(self, pool):
        with pytest.raises(ValueError, match="exceeds the largest"):
            pool.submit(_graphs([100], seed=4)[0])

    def test_single_replica_pool_is_degenerate_scheduler(self, ref_engine):
        """n_replicas=1 behaves exactly like the single-engine path."""
        p = ClusterPool.from_config(
            CFG, serve=SERVE,
            cluster=ClusterConfig(n_replicas=1, deadline_ms=5.0,
                                  warmup=False), seed=0)
        graphs = _graphs([9, 22, 13], seed=5)
        with p:
            results = p.infer(graphs, timeout=RESULT_TIMEOUT)
        for g, r in zip(graphs, results):
            (direct,) = ref_engine.infer_batch([g])
            assert abs(r.energy - direct.energy) <= 1e-6
            assert r.replica_id == 0

    @pytest.mark.skipif(len(jax.devices()) < 2,
                        reason="needs >1 JAX device (cluster-smoke CI runs "
                               "with xla_force_host_platform_device_count=4)")
    def test_replicas_pinned_to_distinct_devices(self, pool):
        """Weights live on the replica's own device and results still
        match — the device placement is unobservable in the numbers."""
        devices = [r.engine.device for r in pool._replicas]
        n_dev = len(jax.devices())
        assert len({str(d) for d in devices}) == min(pool.n_replicas, n_dev)
        for rep in pool._replicas:
            leaf = next(iter(rep.engine.qparams.values()))
            data = leaf.data if hasattr(leaf, "data") else leaf
            assert data.devices() == {rep.engine.device}


class TestBoundedAdmission:
    def test_shed_with_retry_after_when_queues_full(self):
        """Beyond max_queue on every replica, submit sheds with
        SchedulerOverloaded carrying a retry_after_s hint."""
        p = ClusterPool.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8),
            cluster=ClusterConfig(n_replicas=2, max_batch=8,
                                  deadline_ms=60_000.0, max_queue=2,
                                  warmup=False), seed=0)
        graphs = _graphs([10] * 5, seed=6)
        admitted = [p.submit(g) for g in graphs[:4]]   # 2 per replica
        with pytest.raises(SchedulerOverloaded) as ei:
            p.submit(graphs[4])
        assert ei.value.retry_after_s > 0
        assert p.stats()["n_shed"] == 1
        p.close()                                       # drains the 4
        for h in admitted:
            assert np.isfinite(h.result().energy)

    def test_closed_pool_raises_scheduler_closed(self):
        p = ClusterPool.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8),
            cluster=ClusterConfig(n_replicas=1, warmup=False), seed=0)
        p.close()
        with pytest.raises(SchedulerClosed):
            p.submit(_graphs([8], seed=7)[0])


class TestHotSwap:
    def test_rolling_swap_mid_traffic_bit_exact_zero_drops(self, artifacts):
        """Swap v1 -> v2 under live traffic: no request drops or errors,
        post-swap results are bit-exact with a fresh engine loaded from
        v2, and results are version-tagged."""
        pool = ClusterPool.from_artifact(
            artifacts["v1"],
            cluster=ClusterConfig(n_replicas=2, deadline_ms=5.0))
        v1_tag = pool._replicas[0].engine.artifact_version
        rng = np.random.default_rng(8)
        stop = threading.Event()
        completed, errors = [], []

        def client():
            while not stop.is_set():
                (g,) = _graphs([int(rng.integers(5, 17))],
                               seed=int(rng.integers(1 << 30)))
                try:
                    h = pool.submit(g)
                    completed.append(h.result(timeout=RESULT_TIMEOUT))
                except BaseException as e:   # pragma: no cover - fail loud
                    errors.append(e)

        threads = [threading.Thread(target=client) for _ in range(2)]
        for t in threads:
            t.start()
        time.sleep(0.5)
        report = pool.swap_artifact(artifacts["v2"])
        time.sleep(0.5)
        stop.set()
        for t in threads:
            t.join()
        assert not errors
        assert len(report["replicas"]) == 2
        v2_tag = report["version_tag"]
        assert v2_tag != v1_tag
        # every request served during the swap ran one version or the other
        assert {r.artifact_version for r in completed} <= {v1_tag, v2_tag}
        assert any(r.artifact_version == v2_tag for r in completed)
        # post-swap: bit-exact against a cold-started v2 engine
        ref2 = load_engine(artifacts["v2"])
        graphs = _graphs([6, 12, 16], seed=9)
        for g, r in zip(graphs, pool.infer(graphs,
                                           timeout=RESULT_TIMEOUT)):
            (direct,) = ref2.infer_batch([g])
            assert r.energy == direct.energy            # bit-exact
            np.testing.assert_array_equal(r.forces, direct.forces)
            assert r.artifact_version == v2_tag
        pool.close()

    def test_swap_rejects_mode_and_architecture_mismatch(self, artifacts,
                                                         tmp_path):
        from repro.server import ArtifactError
        pool = ClusterPool.from_artifact(
            artifacts["v1"],
            cluster=ClusterConfig(n_replicas=1, warmup=False))
        other_cfg = so3.So3kratesConfig(feat=16, vec_feat=4, n_layers=1,
                                        n_rbf=8, dir_bits=6, cutoff=3.0)
        other = QuantizedEngine.from_config(
            other_cfg, serve=ServeConfig(mode="w8a8", bucket_sizes=(16, 32),
                                         max_batch=8), seed=0)
        bad_arch = str(tmp_path / "arch.npz")
        save_artifact(bad_arch, other)
        with pytest.raises(ArtifactError, match="model config"):
            pool.swap_artifact(bad_arch)
        w4 = QuantizedEngine.from_config(
            CFG, serve=ServeConfig(mode="w4a8", bucket_sizes=(16, 32),
                                   max_batch=8), seed=0)
        bad_mode = str(tmp_path / "mode.npz")
        save_artifact(bad_mode, w4)
        with pytest.raises(ArtifactError, match="mode"):
            pool.swap_artifact(bad_mode)
        pool.close()


class TestFailover:
    def test_killed_replica_requeues_zero_loss(self):
        """Kill one of two replicas in flight under traffic: every
        admitted request still completes (on the survivor), telemetry
        records the failover."""
        pool = ClusterPool.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8),
            cluster=ClusterConfig(n_replicas=2, deadline_ms=5.0), seed=0)
        rng = np.random.default_rng(10)
        stop = threading.Event()
        handles, errors = [], []

        def client():
            while not stop.is_set():
                (g,) = _graphs([int(rng.integers(5, 17))],
                               seed=int(rng.integers(1 << 30)))
                try:
                    handles.append(pool.submit(g))
                except BaseException as e:  # pragma: no cover - fail loud
                    errors.append(e)
                time.sleep(0.002)

        t = threading.Thread(target=client)
        t.start()
        time.sleep(0.4)
        pool.kill_replica(0, mode="in_flight")
        time.sleep(0.8)
        stop.set()
        t.join()
        assert not errors
        results = [h.result(timeout=RESULT_TIMEOUT) for h in handles]
        assert all(np.isfinite(r.energy) for r in results)
        stats = pool.stats()
        assert stats["n_live"] == 1
        assert stats["router"]["n_failures"] >= 1
        # post-kill traffic keeps flowing on the survivor
        (g,) = _graphs([11], seed=11)
        r = pool.infer([g], timeout=RESULT_TIMEOUT)[0]
        assert r.replica_id == 1
        pool.close()

    def test_poison_request_does_not_cascade_kill(self):
        """An engine exception resolves to that flush's handles (same
        as the single-engine scheduler) — the replica survives and
        keeps serving. Requeueing the poison flush would cascade-kill
        every survivor; only a run of MAX_CONSECUTIVE_ERRORS erroring
        flushes marks the replica broken."""
        pool = ClusterPool.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8),
            cluster=ClusterConfig(n_replicas=2, deadline_ms=5.0,
                                  warmup=False), seed=0)
        rep0 = pool._replicas[0]          # bucket 16's home replica
        real_infer = rep0.engine.infer_batch
        calls = {"n": 0}

        def flaky(graphs, on_flag=None):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient engine failure")
            return real_infer(graphs, on_flag=on_flag)

        rep0.engine.infer_batch = flaky
        (g,) = _graphs([10], seed=13)
        with pytest.raises(RuntimeError, match="transient"):
            pool.submit(g).result(timeout=RESULT_TIMEOUT)
        # same replica serves the retry: no death, no failover
        r = pool.submit(g).result(timeout=RESULT_TIMEOUT)
        assert np.isfinite(r.energy) and r.replica_id == 0
        stats = pool.stats()
        assert stats["n_live"] == 2
        assert stats["router"]["n_failures"] == 0
        assert stats["replicas"][0]["n_errors"] == 1
        pool.close()

    def test_persistently_broken_replica_fails_over(self):
        """MAX_CONSECUTIVE_ERRORS erroring flushes in a row = the
        replica itself is broken: it dies and later traffic flows to
        survivors (a hard device failure errors every flush)."""
        from repro.cluster import Replica
        pool = ClusterPool.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8),
            cluster=ClusterConfig(n_replicas=2, deadline_ms=5.0,
                                  warmup=False), seed=0)
        rep0 = pool._replicas[0]

        def dead(graphs, on_flag=None):
            raise RuntimeError("device lost")

        rep0.engine.infer_batch = dead
        (g,) = _graphs([10], seed=14)
        errors = 0
        for _ in range(Replica.MAX_CONSECUTIVE_ERRORS + 2):
            try:
                r = pool.submit(g).result(timeout=RESULT_TIMEOUT)
                assert r.replica_id == 1      # survivor took over
            except RuntimeError:
                errors += 1
        assert errors >= Replica.MAX_CONSECUTIVE_ERRORS
        # the broken replica is out; the survivor keeps serving
        assert pool.stats()["n_live"] == 1
        r = pool.submit(g).result(timeout=RESULT_TIMEOUT)
        assert r.replica_id == 1
        pool.close()

    def test_all_replicas_dead_resolves_not_hangs(self):
        """With no survivors, queued requests resolve with the failure
        error instead of hanging, and submit raises SchedulerClosed."""
        pool = ClusterPool.from_config(
            CFG, serve=ServeConfig(mode="w8a8", bucket_sizes=(16,),
                                   max_batch=8),
            cluster=ClusterConfig(n_replicas=2, deadline_ms=60_000.0,
                                  max_requeues=2, warmup=False), seed=0)
        graphs = _graphs([10, 12, 9], seed=12)
        handles = [pool.submit(g) for g in graphs]
        pool.kill_replica(0)
        pool.kill_replica(1)
        deadline = time.monotonic() + 30
        for h in handles:
            with pytest.raises(Exception):
                h.result(timeout=max(deadline - time.monotonic(), 1))
        with pytest.raises(SchedulerClosed):
            pool.submit(graphs[0])
        pool.close()
