"""Tests for the fault-tolerant checkpoint manager (ISSUE 7).

Covers: save/restore round-trip (structure-preserving and the
structure-free ``restore_arrays``), digest verification at restore
(bitflip and torn-write rejection with the typed ``CheckpointError``),
keep-N garbage collection, ``latest_step()`` falling back past a
corrupted newest step, the crash-orphan ``step_N.tmp.*`` sweep, and
manifest ``extra`` round-trip.
"""
import glob
import json
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointError, CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "coords": rng.normal(size=(2, 8, 3)).astype(np.float32),
        "veloc": rng.normal(size=(2, 8, 3)).astype(np.float32),
        "nl": {"senders": rng.integers(0, 16, 64).astype(np.int32),
               "mask": rng.integers(0, 2, 64).astype(bool),
               "overflow": np.asarray(False)},
        "step": np.int64(7),
    }


def _flip_byte(path, offset=16):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def _array_files(cm, step):
    return sorted(glob.glob(os.path.join(cm.dir, f"step_{step}", "*.npy")))


class TestRoundTrip:
    def test_save_restore_tree(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=3)
        tree = _tree()
        cm.save(1, tree, extra={"chunks_done": 1, "mode": "w8a8"})
        out = cm.restore(1, like=tree)
        for key in ("coords", "veloc"):
            np.testing.assert_array_equal(np.asarray(out[key]), tree[key])
        np.testing.assert_array_equal(np.asarray(out["nl"]["senders"]),
                                      tree["nl"]["senders"])
        assert int(np.asarray(out["step"])) == 7
        assert cm.extra(1) == {"chunks_done": 1, "mode": "w8a8"}

    def test_restore_arrays_structure_free(self, tmp_path):
        """The resume-after-process-death path: no live `like` tree,
        arrays come back keyed by flattened path."""
        cm = CheckpointManager(str(tmp_path))
        cm.save(2, _tree(1))
        arrays = cm.restore_arrays(2)
        assert set(arrays) == {"coords", "veloc", "nl/senders", "nl/mask",
                               "nl/overflow", "step"}
        np.testing.assert_array_equal(arrays["coords"], _tree(1)["coords"])

    def test_missing_step_raises_typed(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        with pytest.raises(CheckpointError, match="no checkpoint"):
            cm.restore(5, like=_tree())
        with pytest.raises(CheckpointError):
            cm.restore_arrays(5)

    def test_missing_key_raises_typed(self, tmp_path):
        """A `like` tree the manifest can't satisfy must refuse loudly,
        not return a partial tree."""
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, _tree())
        with pytest.raises(CheckpointError, match="missing from the"):
            cm.restore(1, like={"coords": np.zeros(1), "nope": np.zeros(1)})


class TestCorruptionRejection:
    def test_bitflip_rejected_at_restore(self, tmp_path):
        """The satellite bug: restore used to trust bytes is_valid()
        would reject. A flipped byte must raise CheckpointError."""
        cm = CheckpointManager(str(tmp_path))
        tree = _tree()
        cm.save(1, tree)
        _flip_byte(_array_files(cm, 1)[0])
        assert not cm.is_valid(1)
        with pytest.raises(CheckpointError, match="SHA-256"):
            cm.restore(1, like=tree)
        with pytest.raises(CheckpointError, match="SHA-256"):
            cm.restore_arrays(1)

    def test_torn_write_rejected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        tree = _tree()
        cm.save(1, tree)
        f = _array_files(cm, 1)[-1]
        with open(f, "r+b") as fh:
            fh.truncate(os.path.getsize(f) // 2)
        with pytest.raises(CheckpointError):
            cm.restore(1, like=tree)

    def test_unreadable_manifest_rejected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, _tree())
        with open(os.path.join(cm.dir, "step_1", "manifest.json"), "w") as f:
            f.write("{not json")
        assert not cm.is_valid(1)
        with pytest.raises(CheckpointError, match="manifest"):
            cm.restore(1, like=_tree())

    def test_latest_step_skips_corrupted_newest(self, tmp_path):
        """Auto-resume must land on the newest *valid* step — a torn
        newest checkpoint falls back to the previous one."""
        cm = CheckpointManager(str(tmp_path), keep=5)
        tree = _tree()
        for s in (1, 2, 3):
            cm.save(s, tree)
        _flip_byte(_array_files(cm, 3)[0])
        assert cm.all_steps() == [1, 2, 3]
        assert cm.latest_step() == 2
        out = cm.restore(cm.latest_step(), like=tree)
        np.testing.assert_array_equal(np.asarray(out["coords"]),
                                      tree["coords"])


class TestGC:
    def test_keep_n(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        for s in range(1, 6):
            cm.save(s, _tree(s))
        assert cm.all_steps() == [4, 5]

    def test_orphan_tmp_swept_and_ignored(self, tmp_path):
        """A hard kill between mkdtemp and rename leaks step_N.tmp.* —
        all_steps()/latest_step() must never offer it, and the next
        save's GC must remove it from disk."""
        cm = CheckpointManager(str(tmp_path), keep=2)
        cm.save(1, _tree())
        orphan = os.path.join(cm.dir, "step_7.tmp.deadbeef")
        os.makedirs(orphan)
        with open(os.path.join(orphan, "junk.npy"), "wb") as f:
            f.write(b"partial")
        assert cm.all_steps() == [1]        # tmp never listed
        assert cm.latest_step() == 1
        cm.save(2, _tree())
        assert not os.path.exists(orphan)   # swept by _gc
        assert cm.all_steps() == [1, 2]

    def test_failed_save_leaves_no_tmp(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))

        class Boom:
            def __array__(self):
                raise RuntimeError("device fell over")

        with pytest.raises(RuntimeError, match="fell over"):
            cm.save(1, {"bad": Boom()})
        assert [n for n in os.listdir(cm.dir) if "tmp" in n] == []
        assert cm.all_steps() == []

    def test_overwrite_same_step(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, _tree(0))
        cm.save(1, _tree(9))
        out = cm.restore_arrays(1)
        np.testing.assert_array_equal(out["coords"], _tree(9)["coords"])

    def test_manifest_records_shapes_and_hashes(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(3, _tree())
        with open(os.path.join(cm.dir, "step_3", "manifest.json")) as f:
            manifest = json.load(f)
        meta = manifest["arrays"]["coords"]
        assert meta["shape"] == [2, 8, 3] and meta["dtype"] == "float32"
        assert len(meta["sha256"]) == 64
