"""One cluster replica: a device-pinned engine + worker thread + queue.

A :class:`Replica` is the ``n_replicas=1`` building block the pool
(``repro.cluster.pool``) stands up N of: it owns

* one :class:`~repro.serving.engine.QuantizedEngine` pinned to one JAX
  device (weights committed there, jitted forwards compiled for it),
* one :class:`~repro.server.scheduler.BatchQueue` — the *same*
  queueing/flush policy object the single-engine
  ``MicroBatchScheduler`` runs, so batch formation semantics are
  identical at every replica count,
* one worker thread that warms the engine up, then serves flushes.

What a replica adds over the single-engine scheduler is the cluster's
failure and upgrade surface:

* **engine hot swap** — ``swap_engine(new_engine)`` exchanges the
  serving engine under a lock that is held during each flush, so the
  in-flight flush finishes on the old weights, everything after runs
  the new ones, and no request is ever dropped (the pool drives this
  one replica at a time for a zero-downtime rolling swap);
* **failure** — ``kill()`` (the injectable abrupt failure used by
  tests and ``benchmarks/cluster_bench.py``) takes the *failover
  path*: the replica stops accepting, hands every unresolved handle —
  queued and, for in-flight kills, the flush being attempted — to the
  pool's ``on_failure`` callback for requeue onto survivors, and its
  thread exits. A real **engine exception** during a flush resolves
  the error to that flush's handles (exactly like the single-engine
  scheduler — a poison request must not be requeued to cascade-kill
  survivors); only ``MAX_CONSECUTIVE_ERRORS`` erroring flushes in a
  row are treated as the replica itself being broken, taking the
  failover path for the *queued* (never-attempted) requests. A replica
  never silently eats requests;
* **heartbeat telemetry** — ``snapshot()`` reports liveness, queue
  depth, completions, the serving artifact version, and the age of the
  last completed flush (the heartbeat the pool surfaces in
  ``stats()``).

Locking: the replica's condition variable guards its queue and flags
(never held during engine work); ``_engine_lock`` is held for the
duration of each flush and by ``swap_engine``. The pool may take
replica locks while holding its own; replica worker threads call back
into the pool only with no replica lock held — that ordering
(pool -> replica, never the reverse) is what makes the whole thing
deadlock-free.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.serving.engine import QuantizedEngine
from repro.server.scheduler import BatchQueue, RequestHandle, SchedulerConfig
from repro.server.stats import FlushRecord

__all__ = ["Replica", "ReplicaFailed"]


class ReplicaFailed(RuntimeError):
    """A replica died (injected kill or engine failure). Requests that
    exhausted their failover requeue budget resolve with this error."""


class Replica:
    """One engine + queue + worker thread of a cluster pool."""

    # erroring flushes in a row before the replica declares itself
    # broken (a hard device failure errors every flush; a poison
    # request only errors its own — see module doc)
    MAX_CONSECUTIVE_ERRORS = 3

    def __init__(self, replica_id: int, engine: QuantizedEngine,
                 config: SchedulerConfig,
                 on_failure: Callable[["Replica", List[RequestHandle],
                                       BaseException], None],
                 warmup: bool = True):
        self.replica_id = replica_id
        self.engine = engine
        self.config = config
        self.warmup_s = 0.0
        self.ready = threading.Event()      # set once warmup finished (or failed)
        self._queue = BatchQueue(engine.serve.buckets(), config)
        self._lock = threading.Condition()
        self._engine_lock = threading.Lock()  # held per flush and per swap
        self._accepting = True
        self._closing = False
        self._fail_next_flush = False
        self._fail_error: Optional[BaseException] = None
        self._on_failure = on_failure
        self._do_warmup = warmup
        self._flushes: List[FlushRecord] = []
        self._n_completed = 0
        self._n_errors = 0              # flush errors resolved to handles
        self._consecutive_errors = 0
        self._last_beat = time.monotonic()
        self._worker = threading.Thread(
            target=self._run, name=f"cluster-replica-{replica_id}",
            daemon=True)
        self._worker.start()

    # -- pool side -----------------------------------------------------------

    @property
    def device(self):
        return self.engine.device

    @property
    def accepting(self) -> bool:
        with self._lock:
            return self._accepting and not self._closing

    def depth(self) -> int:
        with self._lock:
            return self._queue.depth()

    def depth_of(self, capacity: int) -> int:
        with self._lock:
            return self._queue.depth_of(capacity)

    def try_submit(self, handle: RequestHandle, force: bool = False) -> bool:
        """Admit one routed handle. Returns False — so the router picks
        another replica — when this one has died, is closing, or (unless
        ``force``, the failover-requeue path: already-admitted requests
        are never shed) its queue is at the bound."""
        with self._lock:
            if not self._accepting or self._closing:
                return False
            if not force and self._queue.is_full():
                return False
            self._queue.append(handle)
            self._lock.notify()
            return True

    def swap_engine(self, new_engine: QuantizedEngine) -> float:
        """Exchange the serving engine. Blocks until the in-flight flush
        (if any) completes on the old engine; queued and future requests
        run the new one. Returns seconds spent waiting + swapping. The
        caller (the pool's rolling swap) is responsible for warming
        ``new_engine`` first so post-swap traffic never compiles."""
        t0 = time.monotonic()
        with self._engine_lock:
            self.engine = new_engine
        return time.monotonic() - t0

    def kill(self, mode: str = "drain") -> None:
        """Inject a replica failure. ``mode="drain"``: stop before the
        next flush — queued requests become orphans for the pool to
        requeue. ``mode="in_flight"``: additionally fail the flush being
        formed, so requests that were already popped out of the queue
        (in flight) exercise the requeue path too."""
        if mode not in ("drain", "in_flight"):
            raise ValueError(f"unknown kill mode {mode!r}")
        with self._lock:
            self._fail_error = ReplicaFailed(
                f"replica {self.replica_id} killed ({mode})")
            if mode == "in_flight":
                self._fail_next_flush = True
            else:
                self._accepting = False
            self._lock.notify()

    def begin_close(self) -> None:
        """Phase 1 of shutdown: stop admitting, let the worker drain."""
        with self._lock:
            self._closing = True
            self._lock.notify()

    def join(self) -> None:
        self._worker.join()

    def close(self) -> None:
        self.begin_close()
        self.join()

    # -- telemetry -----------------------------------------------------------

    def records(self) -> List[FlushRecord]:
        with self._lock:
            return list(self._flushes)

    def recent_service_s(self, k: int = 4) -> List[float]:
        """Last k flushes' service times (cheap slice under the lock —
        the pool's retry_after estimate polls this per shed request)."""
        with self._lock:
            return [f.service_s for f in self._flushes[-k:]]

    def reset_records(self) -> None:
        """Zero phase-local telemetry: flush records and the
        completion/error counters (liveness state is untouched)."""
        with self._lock:
            self._flushes.clear()
            self._n_completed = 0
            self._n_errors = 0

    def snapshot(self) -> Dict[str, object]:
        """Heartbeat/health snapshot (stats.py style) for pool.stats()."""
        now = time.monotonic()
        with self._lock:
            sizes = [f.n_requests for f in self._flushes]
            return {
                "replica_id": self.replica_id,
                "device": str(self.engine.device) if self.engine.device
                          is not None else "default",
                "alive": self._accepting,
                "artifact_version": self.engine.artifact_version,
                "queue_depth": self._queue.depth(),
                "n_completed": self._n_completed,
                "n_errors": self._n_errors,
                "n_flushes": len(self._flushes),
                "mean_batch": (sum(sizes) / len(sizes)) if sizes else 0.0,
                "warmup_s": self.warmup_s,
                "heartbeat_age_s": now - self._last_beat,
            }

    # -- worker side ---------------------------------------------------------

    def _die(self, in_flight: List[RequestHandle],
             error: BaseException) -> None:
        """Stop serving and hand every unresolved handle to the pool.
        Called from the worker thread with no locks held."""
        with self._lock:
            self._accepting = False
            orphans = in_flight + self._queue.drain_all()
        self._on_failure(self, orphans, error)

    def _run(self):
        try:
            if self._do_warmup:
                self.warmup_s = self.engine.warmup()
        except BaseException as e:
            self.ready.set()
            self._die([], e)
            return
        with self._lock:
            self._last_beat = time.monotonic()
        self.ready.set()

        while True:
            in_flight: List[RequestHandle] = []
            with self._lock:
                while True:
                    now = time.monotonic()
                    if not self._accepting:          # killed (drain mode)
                        err = self._fail_error or ReplicaFailed(
                            f"replica {self.replica_id} failed")
                        picked = None
                        break
                    depth = self._queue.depth()     # pre-pop, FlushRecord
                    picked = self._queue.pick_flush(now,
                                                    drain=self._closing)
                    if picked is not None:
                        break
                    if self._closing and depth == 0:
                        return
                    ddl = self._queue.oldest_deadline()
                    self._lock.wait(
                        None if ddl is None else max(ddl - now, 0))
                if picked is not None and self._fail_next_flush:
                    # injected in-flight failure: these handles were
                    # popped (in flight) when the replica died
                    err = self._fail_error or ReplicaFailed(
                        f"replica {self.replica_id} failed in flight")
                    in_flight = picked[1]
                    picked = None
                    self._accepting = False
            if picked is None:
                self._die(in_flight, err)
                return
            cap, handles, reason = picked
            wait_s = time.monotonic() - handles[0].t_submit
            t0 = time.monotonic()
            flush_error = None
            with self._engine_lock:   # swap waits for the flush, not v.v.
                engine = self.engine
                try:
                    results = engine.infer_batch([h.graph for h in handles])
                except BaseException as e:
                    flush_error = e
            if flush_error is not None:
                # resolve the error to this flush's handles (same as the
                # single-engine scheduler) — requeueing a poison request
                # would cascade-kill survivors. Only a run of erroring
                # flushes means the replica itself is broken: then fail
                # over the queued (never-attempted) work. All of this
                # runs with no locks held (_die's contract).
                for h in handles:
                    h._resolve(error=flush_error,
                               replica_id=self.replica_id)
                with self._lock:
                    self._n_errors += 1
                    self._consecutive_errors += 1
                    broken = (self._consecutive_errors
                              >= self.MAX_CONSECUTIVE_ERRORS)
                if broken:
                    self._die([], flush_error)
                    return
                continue
            service_s = time.monotonic() - t0
            results = [dataclasses.replace(r, replica_id=self.replica_id)
                       for r in results]
            with self._lock:
                self._n_completed += len(handles)
                self._consecutive_errors = 0
                self._last_beat = time.monotonic()
                self._flushes.append(FlushRecord(
                    capacity=cap, n_requests=len(handles), reason=reason,
                    queue_depth=depth, wait_s=wait_s, service_s=service_s,
                    path=results[0].path, batch_size=results[0].batch_size,
                    replica_id=self.replica_id))
            for h, r in zip(handles, results):
                h._resolve(result=r, replica_id=self.replica_id)
