"""One cluster replica: a device-pinned engine + worker thread + queue.

A :class:`Replica` is the ``n_replicas=1`` building block the pool
(``repro.cluster.pool``) stands up N of: it owns

* one :class:`~repro.serving.engine.QuantizedEngine` pinned to one JAX
  device (weights committed there, jitted forwards compiled for it),
* one :class:`~repro.server.scheduler.BatchQueue` — the *same*
  queueing/flush policy object the single-engine
  ``MicroBatchScheduler`` runs, so batch formation semantics are
  identical at every replica count,
* one worker thread that warms the engine up, then serves flushes.

What a replica adds over the single-engine scheduler is the cluster's
failure and upgrade surface:

* **engine hot swap** — ``swap_engine(new_engine)`` exchanges the
  serving engine under a lock that is held during each flush, so the
  in-flight flush finishes on the old weights, everything after runs
  the new ones, and no request is ever dropped (the pool drives this
  one replica at a time for a zero-downtime rolling swap);
* **failure** — ``kill()`` (the injectable abrupt failure used by
  tests and ``benchmarks/cluster_bench.py``) takes the *failover
  path*: the replica stops accepting, hands every unresolved handle —
  queued and, for in-flight kills, the flush being attempted — to the
  pool's ``on_failure`` callback for requeue onto survivors, and its
  thread exits. A real **engine exception** during a flush resolves
  the error to that flush's handles (exactly like the single-engine
  scheduler — a poison request must not be requeued to cascade-kill
  survivors); only ``MAX_CONSECUTIVE_ERRORS`` erroring flushes in a
  row are treated as the replica itself being broken, taking the
  failover path for the *queued* (never-attempted) requests. A replica
  never silently eats requests;
* **heartbeat telemetry** — ``snapshot()`` reports liveness, queue
  depth, completions, the serving artifact version, and the age of the
  last completed flush (the heartbeat the pool surfaces in
  ``stats()``);
* **session chunks** — a :class:`ChunkHandle` (one MD ``lax.scan``
  segment from ``repro.sessions``) queues beside one-shot traffic and
  runs on the worker thread under the same engine lock as a flush.
  Flushes go first: latency-sensitive batches preempt bulk MD work at
  every chunk boundary. Queued chunks fail over with the one-shot
  orphans; an in-flight ``kill(mode="in_flight")`` fails whichever work
  was picked — flush or chunk. ``inject_stall`` adds the slow-flush
  fault the session chaos harness schedules.

Locking: the replica's condition variable guards its queue and flags
(never held during engine work); ``_engine_lock`` is held for the
duration of each flush and by ``swap_engine``. The pool may take
replica locks while holding its own; replica worker threads call back
into the pool only with no replica lock held — that ordering
(pool -> replica, never the reverse) is what makes the whole thing
deadlock-free.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

from repro.guardrails import GuardrailViolation
from repro.obs.metrics import REGISTRY
from repro.serving.engine import QuantizedEngine
from repro.server.scheduler import BatchQueue, RequestHandle, SchedulerConfig
from repro.server.stats import FlushRecord

__all__ = ["ChunkHandle", "Replica", "ReplicaFailed"]


class ReplicaFailed(RuntimeError):
    """A replica died (injected kill or engine failure). Requests that
    exhausted their failover requeue budget resolve with this error."""


class ChunkHandle(RequestHandle):
    """A unit of *session* work: an opaque ``fn(engine) -> result``
    closure (in practice one MD ``lax.scan`` segment from
    ``repro.sessions``) that a replica's worker runs on its pinned
    engine, under the same ``_engine_lock`` as a flush — so a rolling
    ``swap_engine`` waits for an in-flight chunk and every later chunk
    sees the post-swap engine.

    It rides the existing :class:`RequestHandle` future/failover
    machinery: ``bucket_capacity`` is the session molecule's shape
    class (chunks share JSQ + affinity routing with same-shape one-shot
    traffic), ``n_requeues`` counts failovers, and a dying replica
    hands queued chunks to the pool's ``on_failure`` exactly like
    one-shot requests. Unlike a flush, a chunk that raises resolves the
    error to *this* handle only — the session manager, which holds the
    authoritative pre-chunk state, decides whether to re-submit.
    """

    __slots__ = ("fn", "session_id", "chunk_idx")

    _trace_kind = "chunk"

    def __init__(self, fn: Callable[[QuantizedEngine], Any],
                 t_submit: float, bucket_capacity: int = 0,
                 session_id: str = "", chunk_idx: int = 0):
        super().__init__(None, t_submit, bucket_capacity)
        self.fn = fn
        self.session_id = session_id
        self.chunk_idx = chunk_idx
        if self.trace is not None:
            self.trace.set_attr("session_id", session_id)
            self.trace.set_attr("chunk_idx", chunk_idx)


class Replica:
    """One engine + queue + worker thread of a cluster pool."""

    # erroring flushes in a row before the replica declares itself
    # broken (a hard device failure errors every flush; a poison
    # request only errors its own — see module doc)
    MAX_CONSECUTIVE_ERRORS = 3

    def __init__(self, replica_id: int, engine: QuantizedEngine,
                 config: SchedulerConfig,
                 on_failure: Callable[["Replica", List[RequestHandle],
                                       BaseException], None],
                 warmup: bool = True,
                 on_flagged: Optional[Callable] = None,
                 breaker_window: int = 0):
        """``on_flagged(replica, handle, result) -> bool`` is the pool's
        guardrail triage hook, called (with no replica locks held) for
        each flush result whose detectors fired: True means the pool
        took ownership (requeued the handle one precision tier up),
        False means this replica resolves it locally (typed error for
        fatal flags, annotated delivery for suspect ones).
        ``breaker_window`` sizes the sliding flagged-rate window the
        pool's circuit breaker reads via :meth:`flag_window` (0 = keep
        none)."""
        self.replica_id = replica_id
        self.engine = engine
        self.config = config
        self.warmup_s = 0.0
        self.ready = threading.Event()      # set once warmup finished (or failed)
        self._queue = BatchQueue(engine.serve.buckets(), config)
        self._chunks: Deque[ChunkHandle] = deque()   # session segments
        self._lock = threading.Condition()
        self._engine_lock = threading.Lock()  # held per flush and per swap
        self._accepting = True
        self._closing = False
        self._fail_next_flush = False
        self._fail_error: Optional[BaseException] = None
        self._on_failure = on_failure
        self._on_flagged = on_flagged
        self._do_warmup = warmup
        self._flushes: List[FlushRecord] = []
        self._n_completed = 0
        self._n_errors = 0              # flush errors resolved to handles
        self._n_chunks_completed = 0
        self._n_chunk_errors = 0
        self._chunk_service_s = 0.0
        self._stall_s = 0.0             # injected slow-flush fault (one-shot)
        self._n_stalls_injected = 0
        self._consecutive_errors = 0
        self._n_flagged = 0             # flush results with guardrail flags
        self._recent_flags: Deque[bool] = deque(maxlen=max(breaker_window, 0))
        # watchdog surface: when the worker picked work and what it holds
        self._busy_since: Optional[float] = None
        self._in_flight: List[RequestHandle] = []
        # set by expropriate(): the pool already rehomed every handle;
        # the (possibly stuck) worker must exit silently when it wakes
        self._expropriated = False
        self._admit_at = 0.0            # monotonic probation gate
        self._last_beat = time.monotonic()
        # fleet-level obs plane: instruments are shared across replicas
        # (and across engine exchanges) by (name, labels) identity
        self._m_wait = REGISTRY.histogram("serve_queue_wait_seconds",
                                          surface="replica")
        self._m_service = REGISTRY.histogram("serve_flush_seconds",
                                             surface="replica")
        self._m_completed = REGISTRY.counter(
            "serve_requests_total", surface="replica", event="completed")
        self._m_chunks = {
            k: REGISTRY.counter("cluster_chunks_total", event=k)
            for k in ("completed", "error")}
        # health-plane feeds: live per-replica queue depth (anomaly
        # detectors) and a per-replica service histogram (latency-skew
        # detection needs the replica label; the fleet-level
        # serve_flush_seconds{surface="replica"} aggregate stays as-is)
        self._m_depth = REGISTRY.gauge("cluster_queue_depth",
                                       replica=str(replica_id))
        self._m_service_r = REGISTRY.histogram("replica_flush_seconds",
                                               replica=str(replica_id))
        self._worker = threading.Thread(
            target=self._run, name=f"cluster-replica-{replica_id}",
            daemon=True)
        self._worker.start()

    # -- pool side -----------------------------------------------------------

    @property
    def device(self):
        return self.engine.device

    @property
    def tier(self) -> str:
        """Precision tier = the engine's serving mode (w4a8/w8a8/fp32)."""
        return self.engine.serve.mode

    @property
    def accepting(self) -> bool:
        with self._lock:
            return (self._accepting and not self._closing
                    and time.monotonic() >= self._admit_at)

    def depth(self) -> int:
        """Queued one-shot requests + queued session chunks: chunks are
        real load, so JSQ routing and the admission bound must see them."""
        with self._lock:
            return self._queue.depth() + len(self._chunks)

    def depth_of(self, capacity: int) -> int:
        with self._lock:
            return self._queue.depth_of(capacity)

    def try_submit(self, handle: RequestHandle, force: bool = False) -> bool:
        """Admit one routed handle (one-shot request or session
        :class:`ChunkHandle`). Returns False — so the router picks
        another replica — when this one has died, is closing, or (unless
        ``force``, the failover-requeue path: already-admitted requests
        are never shed) its total depth is at the bound."""
        with self._lock:
            if not self._accepting or self._closing \
                    or time.monotonic() < self._admit_at:
                return False
            mq = self.config.max_queue
            if (not force and mq is not None
                    and self._queue.depth() + len(self._chunks) >= mq):
                return False
            if isinstance(handle, ChunkHandle):
                self._chunks.append(handle)
            else:
                self._queue.append(handle)
            self._m_depth.set(self._queue.depth() + len(self._chunks))
            self._lock.notify()
            return True

    def inject_stall(self, seconds: float) -> None:
        """Fault injection: the next unit of engine work (flush or
        chunk) sleeps ``seconds`` while holding the engine lock — the
        'slow flush' failure mode (GC pause, thermal throttle, a
        straggler device) that delays everything behind it without
        killing anything."""
        with self._lock:
            self._stall_s = float(seconds)
            self._n_stalls_injected += 1

    def swap_engine(self, new_engine: QuantizedEngine) -> float:
        """Exchange the serving engine. Blocks until the in-flight flush
        (if any) completes on the old engine; queued and future requests
        run the new one. Returns seconds spent waiting + swapping. The
        caller (the pool's rolling swap) is responsible for warming
        ``new_engine`` first so post-swap traffic never compiles."""
        t0 = time.monotonic()
        with self._engine_lock:
            self.engine = new_engine
        return time.monotonic() - t0

    def hold_admission(self, seconds: float) -> None:
        """Probation gate: ``accepting`` stays False (and ``try_submit``
        refuses) until ``seconds`` from now — how the pool re-admits a
        quarantined replica's replacement only after its probation
        window (warmup typically overlaps the hold)."""
        with self._lock:
            self._admit_at = time.monotonic() + float(seconds)

    def busy_duration(self) -> Optional[float]:
        """Seconds the worker has been inside its current unit of work
        (None when idle) — the stall signal the pool watchdog polls. A
        healthy flush holds this for milliseconds; an engine-lock stall
        holds it for the stall's duration."""
        with self._lock:
            if self._busy_since is None:
                return None
            return time.monotonic() - self._busy_since

    def flag_window(self):
        """(events, flagged) over the sliding breaker window — the
        flagged-rate the pool's circuit breaker trips on."""
        with self._lock:
            return len(self._recent_flags), sum(self._recent_flags)

    def expropriate(self, error: BaseException) -> List[RequestHandle]:
        """Forcibly take every unresolved handle away from this replica
        — called by the pool's watchdog (stalled worker) or circuit
        breaker (quarantine), from *outside* the worker thread, without
        touching the engine lock the worker may be stuck holding.

        The replica stops accepting; queued requests, queued chunks,
        and the in-flight work the worker is currently executing are
        all returned for the pool to requeue. The worker, whenever it
        wakes, sees ``_expropriated``, still resolves its (now
        possibly duplicate) results — first resolution wins at the
        handle — and exits without the ``_die`` failover path, which
        the pool already performed on its behalf."""
        with self._lock:
            self._expropriated = True
            self._accepting = False
            orphans = (list(self._in_flight) + self._queue.drain_all()
                       + list(self._chunks))
            self._in_flight = []
            self._chunks.clear()
            self._m_depth.set(0.0)
            self._lock.notify()
        return [h for h in orphans if not h.done()]

    def kill(self, mode: str = "drain") -> None:
        """Inject a replica failure. ``mode="drain"``: stop before the
        next flush — queued requests become orphans for the pool to
        requeue. ``mode="in_flight"``: additionally fail the flush being
        formed, so requests that were already popped out of the queue
        (in flight) exercise the requeue path too."""
        if mode not in ("drain", "in_flight"):
            raise ValueError(f"unknown kill mode {mode!r}")
        with self._lock:
            self._fail_error = ReplicaFailed(
                f"replica {self.replica_id} killed ({mode})")
            if mode == "in_flight":
                self._fail_next_flush = True
            else:
                self._accepting = False
            self._lock.notify()

    def begin_close(self) -> None:
        """Phase 1 of shutdown: stop admitting, let the worker drain."""
        with self._lock:
            self._closing = True
            self._lock.notify()

    def join(self) -> None:
        self._worker.join()

    def close(self) -> None:
        self.begin_close()
        self.join()

    # -- telemetry -----------------------------------------------------------

    def records(self) -> List[FlushRecord]:
        with self._lock:
            return list(self._flushes)

    def recent_service_s(self, k: int = 4) -> List[float]:
        """Last k flushes' service times (cheap slice under the lock —
        the pool's retry_after estimate polls this per shed request)."""
        with self._lock:
            return [f.service_s for f in self._flushes[-k:]]

    def reset_records(self) -> None:
        """Zero phase-local telemetry: flush records and the
        completion/error counters (liveness state is untouched)."""
        with self._lock:
            self._flushes.clear()
            self._n_completed = 0
            self._n_errors = 0

    def snapshot(self) -> Dict[str, object]:
        """Heartbeat/health snapshot (stats.py style) for pool.stats()."""
        now = time.monotonic()
        with self._lock:
            sizes = [f.n_requests for f in self._flushes]
            return {
                "replica_id": self.replica_id,
                "device": str(self.engine.device) if self.engine.device
                          is not None else "default",
                "alive": self._accepting,
                "tier": self.engine.serve.mode,
                "on_probation": now < self._admit_at,
                "busy_s": (now - self._busy_since
                           if self._busy_since is not None else 0.0),
                "n_flagged": self._n_flagged,
                "artifact_version": self.engine.artifact_version,
                "queue_depth": self._queue.depth() + len(self._chunks),
                "chunk_depth": len(self._chunks),
                "n_completed": self._n_completed,
                "n_errors": self._n_errors,
                "n_chunks_completed": self._n_chunks_completed,
                "n_chunk_errors": self._n_chunk_errors,
                "chunk_service_s": self._chunk_service_s,
                "n_stalls_injected": self._n_stalls_injected,
                "n_flushes": len(self._flushes),
                "mean_batch": (sum(sizes) / len(sizes)) if sizes else 0.0,
                "warmup_s": self.warmup_s,
                "heartbeat_age_s": now - self._last_beat,
            }

    # -- worker side ---------------------------------------------------------

    def _die(self, in_flight: List[RequestHandle],
             error: BaseException) -> None:
        """Stop serving and hand every unresolved handle to the pool.
        Called from the worker thread with no locks held."""
        with self._lock:
            self._accepting = False
            orphans = in_flight + self._queue.drain_all() + list(self._chunks)
            self._chunks.clear()
            # a dead replica holds nothing: leaving the last pre-death
            # depth in the gauge would skew the summed fleet signal
            self._m_depth.set(0.0)
        self._on_failure(self, orphans, error)

    def _take_stall(self) -> float:
        with self._lock:
            s, self._stall_s = self._stall_s, 0.0
            return s

    def _run_chunk(self, chunk: ChunkHandle) -> bool:
        """Execute one session chunk on the worker thread. Returns False
        when the replica declared itself broken (a run of consecutive
        errors) and the worker must exit.

        A chunk exception resolves the error to the chunk's own handle —
        never a blind pool requeue: the session manager holds the
        authoritative pre-chunk state and decides whether re-running is
        safe (it always is, chunks are pure functions of that state, but
        the *decision* belongs to the layer that can also checkpoint)."""
        t0 = time.monotonic()
        if chunk.trace is not None:
            chunk.trace.begin("serve", t0, replica=self.replica_id,
                              tier=self.tier)
        chunk_error = None
        stall = self._take_stall()
        with self._engine_lock:   # swaps wait for the chunk, not v.v.
            if stall:
                time.sleep(stall)
            engine = self.engine
            try:
                result = chunk.fn(engine)
            except BaseException as e:
                chunk_error = e
        if chunk_error is not None:
            with self._lock:
                self._busy_since = None
                self._in_flight = []
                if self._expropriated:
                    # pool already rehomed the chunk — do NOT resolve
                    # the error (the re-run elsewhere must win); exit
                    return False
                self._n_chunk_errors += 1
                self._consecutive_errors += 1
                broken = (self._consecutive_errors
                          >= self.MAX_CONSECUTIVE_ERRORS)
            self._m_chunks["error"].inc()
            chunk._resolve(error=chunk_error, replica_id=self.replica_id)
            if broken:
                self._die([], chunk_error)
                return False
            return True
        with self._lock:
            self._busy_since = None
            self._in_flight = []
            expropriated = self._expropriated
            self._n_chunks_completed += 1
            self._chunk_service_s += time.monotonic() - t0
            self._consecutive_errors = 0
            self._last_beat = time.monotonic()
        # a genuine result is still the best resolution — first resolve
        # wins if the pool's re-run already answered
        self._m_chunks["completed"].inc()
        chunk._resolve(result=result, replica_id=self.replica_id)
        return not expropriated

    def _run(self):
        try:
            if self._do_warmup:
                self.warmup_s = self.engine.warmup()
        except BaseException as e:
            self.ready.set()
            self._die([], e)
            return
        with self._lock:
            self._last_beat = time.monotonic()
        self.ready.set()

        while True:
            in_flight: List[RequestHandle] = []
            chunk: Optional[ChunkHandle] = None
            with self._lock:
                while True:
                    now = time.monotonic()
                    if self._expropriated:
                        # pool watchdog/breaker already rehomed every
                        # handle — exit without the _die failover path
                        return
                    if not self._accepting:          # killed (drain mode)
                        err = self._fail_error or ReplicaFailed(
                            f"replica {self.replica_id} failed")
                        picked = None
                        break
                    depth = self._queue.depth()     # pre-pop, FlushRecord
                    picked = self._queue.pick_flush(now,
                                                    drain=self._closing)
                    if picked is not None:
                        break
                    # flush-first, then chunks: latency-sensitive
                    # one-shot batches preempt bulk MD work at every
                    # chunk boundary (the chunk length is the session
                    # layer's latency/throughput knob — see
                    # docs/sessions.md)
                    if self._chunks:
                        chunk = self._chunks.popleft()
                        break
                    if self._closing and depth == 0:
                        return
                    ddl = self._queue.oldest_deadline()
                    self._lock.wait(
                        None if ddl is None else max(ddl - now, 0))
                if (picked is not None or chunk is not None) \
                        and self._fail_next_flush:
                    # injected in-flight failure: this work was popped
                    # (in flight) when the replica died
                    err = self._fail_error or ReplicaFailed(
                        f"replica {self.replica_id} failed in flight")
                    in_flight = picked[1] if picked is not None else [chunk]
                    picked = None
                    chunk = None
                    self._accepting = False
                if picked is not None or chunk is not None:
                    # watchdog surface: what the worker holds, since when
                    self._busy_since = time.monotonic()
                    self._in_flight = (list(picked[1]) if picked is not None
                                       else [chunk])
                    self._m_depth.set(self._queue.depth()
                                      + len(self._chunks))
            if picked is None and chunk is None:
                self._die(in_flight, err)
                return
            if chunk is not None:
                if not self._run_chunk(chunk):
                    return
                continue
            cap, handles, reason = picked
            wait_s = time.monotonic() - handles[0].t_submit
            t0 = time.monotonic()
            for h in handles:
                if h.trace is not None:
                    h.trace.begin("serve", t0, replica=self.replica_id,
                                  tier=self.tier, bucket=cap,
                                  flush_reason=reason)
            flush_error = None
            stall = self._take_stall()
            with self._engine_lock:   # swap waits for the flush, not v.v.
                if stall:
                    time.sleep(stall)
                engine = self.engine
                try:
                    results = engine.infer_batch(
                        [h.graph for h in handles], on_flag="mark")
                except BaseException as e:
                    flush_error = e
            if flush_error is not None:
                # resolve the error to this flush's handles (same as the
                # single-engine scheduler) — requeueing a poison request
                # would cascade-kill survivors. Only a run of erroring
                # flushes means the replica itself is broken: then fail
                # over the queued (never-attempted) work. All of this
                # runs with no locks held (_die's contract).
                with self._lock:
                    self._busy_since = None
                    self._in_flight = []
                    if self._expropriated:
                        # pool already requeued these handles elsewhere —
                        # resolving the error here could beat the re-run
                        return
                    self._n_errors += 1
                    self._consecutive_errors += 1
                    broken = (self._consecutive_errors
                              >= self.MAX_CONSECUTIVE_ERRORS)
                for h in handles:
                    h._resolve(error=flush_error,
                               replica_id=self.replica_id)
                if broken:
                    self._die([], flush_error)
                    return
                continue
            service_s = time.monotonic() - t0
            # stamp the escalation audit trail the pool appended to each
            # handle (and the obs trace id) into its delivered result
            results = [dataclasses.replace(
                           r, replica_id=self.replica_id,
                           escalations=tuple(h.escalations),
                           trace_id=(h.trace.trace_id
                                     if h.trace is not None else ""))
                       for h, r in zip(handles, results)]
            trace_ids = tuple(h.trace.trace_id for h in handles
                              if h.trace is not None)
            # stub engines in tests may not expose the profiling hook
            bd = getattr(engine, "last_infer_breakdown", None) or {}
            with self._lock:
                self._busy_since = None
                self._in_flight = []
                expropriated = self._expropriated
                self._n_completed += len(handles)
                self._consecutive_errors = 0
                self._last_beat = time.monotonic()
                self._flushes.append(FlushRecord(
                    capacity=cap, n_requests=len(handles), reason=reason,
                    queue_depth=depth, wait_s=wait_s, service_s=service_s,
                    path=results[0].path, batch_size=results[0].batch_size,
                    replica_id=self.replica_id, trace_ids=trace_ids,
                    prep_s=bd.get("prep_s", 0.0),
                    dispatch_s=bd.get("dispatch_s", 0.0),
                    sync_s=bd.get("sync_s", 0.0),
                    t_start=t0))
                # feed the circuit-breaker window (flush results only —
                # chunk health is the session layer's concern)
                for r in results:
                    self._recent_flags.append(bool(r.flags))
                self._n_flagged += sum(1 for r in results if r.flags)
            self._m_completed.inc(len(handles))
            self._m_wait.observe(wait_s)
            self._m_service.observe(service_s)
            self._m_service_r.observe(service_s)
            REGISTRY.counter("serve_flushes_total", surface="replica",
                             reason=reason).inc()
            for h, r in zip(handles, results):
                if h.trace is not None and r.flags:
                    for f in r.flags:
                        h.trace.event("guardrail_flag", reason=f.reason,
                                      severity=f.severity,
                                      replica=self.replica_id,
                                      tier=self.tier)
                if r.flags:
                    # triage, hook first (no replica locks held): the
                    # pool may take ownership and re-run one tier up
                    if self._on_flagged is not None \
                            and self._on_flagged(self, h, r):
                        continue
                    fatal = next((f for f in r.flags if f.fatal), None)
                    if fatal is not None:
                        h._resolve(error=GuardrailViolation(
                            f"guardrail {fatal.reason}: result withheld "
                            f"(replica {self.replica_id}, tier {self.tier})",
                            reason=fatal.reason, severity=fatal.severity,
                            detail={"value": fatal.value,
                                    "limit": fatal.limit,
                                    "mode": self.tier,
                                    "replica_id": self.replica_id}),
                            replica_id=self.replica_id)
                        continue
                    # suspect-only with nowhere to go: deliver annotated
                h._resolve(result=r, replica_id=self.replica_id)
            if expropriated:
                return
