"""repro.cluster — multi-replica serving runtime over ``repro.server``.

Where ``repro.server`` answers "one engine, online traffic",
this package answers the next production question: N engines. W4A8
artifacts are small and cold-start fast (see BENCH_server.json), so
replicating engines across devices is cheap — this is the runtime that
fans traffic out across them:

* :class:`ClusterPool` / :class:`ClusterConfig` — a replica pool (one
  device-pinned ``QuantizedEngine`` + worker thread + the *same*
  ``BatchQueue`` flush policy as the single-engine scheduler, per
  replica) behind a shape-class-aware join-shortest-queue router with
  bounded admission (shed + ``retry_after_s``), rolling zero-downtime
  artifact hot swap (``swap_artifact``), and failover
  (``kill_replica`` → queued/in-flight requests requeue to survivors);
* :class:`Replica` / :class:`ReplicaFailed` — the per-replica worker
  and its failure error.

PR 8 adds the runtime health layer (docs/guardrails.md): mixed-precision
fleets (``ClusterPool.from_tiers``) whose flagged results transparently
re-run one tier up, a flagged-rate circuit breaker + stall watchdog that
quarantine and cold-restart sick replicas, and typed per-request
deadlines (``RequestHandle.result(timeout_s=...)``).

On CPU, simulate N devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
process imports jax); on TPU the real device list is used. See
docs/cluster.md for the router policy, the swap protocol, and the
failure model; ``benchmarks/cluster_bench.py`` measures the scaling
curve and writes ``BENCH_cluster.json``.
"""
from repro.cluster.pool import ClusterConfig, ClusterPool, pick_devices
from repro.cluster.replica import Replica, ReplicaFailed

__all__ = ["ClusterConfig", "ClusterPool", "Replica", "ReplicaFailed",
           "pick_devices"]
