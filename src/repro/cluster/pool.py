"""Replica pool + shape-class-aware router: the multi-engine runtime.

``ClusterPool`` stands up N :class:`~repro.cluster.replica.Replica`\\ s —
one :class:`~repro.serving.engine.QuantizedEngine` per JAX device (on
CPU, simulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; on TPU the real
device list is used) — behind one ``submit()`` that looks exactly like
the single-engine ``MicroBatchScheduler``'s, so the traffic drivers in
``repro.server.traffic`` run unchanged against either.

**Routing** (``_route``) is join-shortest-queue with bucket affinity:

1. replicas whose queue is at ``max_queue`` are ineligible; if none is
   eligible the request is **shed** with ``SchedulerOverloaded`` and a
   ``retry_after_s`` hint (bounded admission — under overload the pool
   refuses loudly rather than queueing without bound);
2. among eligible replicas, candidates are those within
   ``affinity_slack`` of the shortest queue (the JSQ core: load
   balance first);
3. among candidates, prefer the replica already holding queued requests
   of the *same shape class* (batches fill faster and flush "full"
   instead of waiting out the deadline), then the shape class's static
   home replica (so a lightly loaded cluster keeps each bucket's
   compiled shapes hot on the same engine), then the shortest queue.

**Rolling hot swap** (``swap_artifact``): load a packed artifact once
(checksums verified), then for each replica — one at a time, the rest
keep serving — build a new engine on that replica's device from the
already-deserialized weights, *warm it up*, and exchange engines under
the replica's flush lock. The in-flight flush finishes on the old
weights; everything after runs the new ones; zero requests are dropped
and the artifact's content tag is stamped into every subsequent
result's ``artifact_version``.

**Failover**: a replica that dies (injected ``kill_replica`` or a real
engine exception) hands its queued and in-flight handles back to the
pool, which requeues them onto surviving replicas — a request is only
resolved with the replica's error after ``max_requeues`` failovers, or
when no survivor remains. ``stats()`` merges per-replica heartbeat
snapshots with router counters and the shared flush telemetry.

**Guardrails** (docs/guardrails.md): a pool may mix precision tiers
(``from_tiers`` — w4a8 traffic replicas backed by w8a8/fp32 escalation
replicas running singleton flushes). A flush result whose engine-side
detectors fired is triaged through :meth:`_on_flagged`: re-run one tier
up (audit trail in ``MoleculeResult.escalations``, bounded by
``max_escalations``), else a typed ``GuardrailViolation`` (fatal) or
annotated delivery (suspect). A watchdog thread quarantines replicas
whose worker stalls past ``stall_timeout_s`` or whose sliding-window
flagged rate trips the circuit breaker: handles are expropriated and
requeued (zero lost), the engine cold-restarts on the same device, and
the replacement serves again only after ``probation_s``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax

from repro.guardrails import (EscalationRecord, GuardrailConfig,
                              GuardrailViolation, tier_rank)
from repro.models import so3krates as so3
from repro.obs.metrics import REGISTRY
from repro.serving.bucketing import Graph, assign_bucket
from repro.serving.engine import QuantizedEngine, MoleculeResult, ServeConfig
from repro.serving.qparams import fp32_bytes, quantize_so3_params
from repro.server.artifact import (ArtifactError, ensure_mode_matches,
                                   load_artifact)
from repro.server.scheduler import (RequestHandle, SchedulerClosed,
                                    SchedulerConfig, SchedulerOverloaded)
from repro.server.stats import flush_summary
from repro.cluster.replica import ChunkHandle, Replica, ReplicaFailed

__all__ = ["ClusterConfig", "ClusterPool", "pick_devices"]


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Pool-level knobs. Batch formation inside each replica follows the
    same ``max_batch``/``deadline_ms`` semantics as ``SchedulerConfig``
    (it *is* the same ``BatchQueue`` policy)."""
    n_replicas: int = 2
    max_batch: int = 8
    deadline_ms: float = 20.0
    warmup: bool = True          # replicas pre-compile before serving
    # bounded admission per replica; the pool sheds when every live
    # replica is at the bound (None = unbounded)
    max_queue: Optional[int] = None
    # JSQ slack: a replica may be preferred for shape-class affinity as
    # long as its queue is within this many requests of the shortest
    affinity_slack: int = 2
    # failovers a single request may survive before its error resolves
    max_requeues: int = 2
    # -- guardrails / tiered escalation (all defaults keep them off) --
    # precision-tier re-runs one flagged request may receive before its
    # replica resolves it locally (typed error for fatal, annotated
    # delivery for suspect)
    max_escalations: int = 1
    # sliding window of recent flush results each replica keeps for the
    # circuit breaker (0 = keep none)
    breaker_window: int = 20
    # breaker trip condition: flagged fraction of the window above this
    # rate (None = breaker off), evaluated only once the window holds at
    # least breaker_min_events results — a single flagged request on a
    # cold window must not quarantine a healthy replica
    breaker_flag_rate: Optional[float] = None
    breaker_min_events: int = 10
    # a quarantined replica's respawned engine serves again only after
    # this probation hold (its warmup typically overlaps it)
    probation_s: float = 5.0
    # pool watchdog: a worker busy on one unit of work longer than this
    # is declared stalled and quarantined (None = watchdog off)
    stall_timeout_s: Optional[float] = None
    watchdog_interval_s: float = 0.25
    # quarantines one replica id may survive before it is left dead
    # (a replica that keeps tripping is hardware/weights, not luck)
    max_quarantines: int = 2

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.affinity_slack < 0:
            raise ValueError("affinity_slack must be >= 0")
        if self.max_escalations < 0:
            raise ValueError("max_escalations must be >= 0")
        if self.breaker_window < 0:
            raise ValueError("breaker_window must be >= 0")
        if self.breaker_flag_rate is not None \
                and not (0.0 <= self.breaker_flag_rate <= 1.0):
            raise ValueError("breaker_flag_rate must be in [0, 1] or None")
        if self.stall_timeout_s is not None and self.stall_timeout_s <= 0:
            raise ValueError("stall_timeout_s must be > 0 or None")
        if self.watchdog_interval_s <= 0:
            raise ValueError("watchdog_interval_s must be > 0")

    def scheduler_config(self) -> SchedulerConfig:
        # warmup/max_queue are pool-driven (parallel warmup, router-side
        # shedding); the per-replica queue enforces the bound defensively
        return SchedulerConfig(max_batch=self.max_batch,
                               deadline_ms=self.deadline_ms,
                               warmup=False, max_queue=self.max_queue)


def pick_devices(n: int) -> List[Optional[jax.Device]]:
    """First ``n`` JAX devices, reusing the ladder round-robin (with a
    warning) when fewer exist — on CPU, start the process with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to simulate
    N devices (see docs/cluster.md)."""
    devs = jax.devices()
    if len(devs) < n:
        warnings.warn(
            f"cluster wants {n} replicas but only {len(devs)} JAX "
            f"device(s) exist — replicas will share devices. On CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax to simulate distinct devices.")
    return [devs[i % len(devs)] for i in range(n)]


class ClusterPool:
    """N device-pinned engine replicas behind one shape-aware router."""

    def __init__(self, engines: Sequence[QuantizedEngine],
                 cluster: ClusterConfig = ClusterConfig(),
                 wait_ready: bool = True):
        """Build from pre-constructed (already device-pinned) engines —
        one replica each; ``len(engines)`` overrides
        ``cluster.n_replicas``. Prefer the ``from_config`` /
        ``from_artifact`` constructors."""
        if not engines:
            raise ValueError("need at least one engine")
        # engines must agree on everything *except* precision mode: a
        # tiered fleet (w4a8 traffic replicas + w8a8/fp32 escalation
        # replicas) differs only in mode, so bucket ladders and batch
        # formation stay identical across the whole pool
        norm = {dataclasses.replace(e.serve, mode=engines[0].serve.mode)
                for e in engines}
        if len(norm) != 1:
            raise ValueError(
                "all replica engines must share one ServeConfig "
                "(precision mode may differ for a tiered fleet)")
        ranks = [tier_rank(e.serve.mode) for e in engines]
        self._primary_rank = min(ranks)
        # the pool's nominal serve is the primary (cheapest) tier's —
        # that is the tier ordinary traffic routes to
        self.serve = engines[ranks.index(self._primary_rank)].serve
        self.model_cfg = engines[0].model_cfg
        self.cluster = dataclasses.replace(cluster, n_replicas=len(engines))
        if cluster.max_batch > self.serve.max_batch:
            raise ValueError(
                f"ClusterConfig.max_batch {cluster.max_batch} exceeds "
                f"ServeConfig.max_batch {self.serve.max_batch}")
        self._buckets = self.serve.buckets()
        self._lock = threading.Lock()
        self._open = True
        self._n_routed = 0
        self._n_shed = 0
        self._n_requeued = 0
        self._n_failures = 0
        self._n_chunks_routed = 0
        self._n_chunks_requeued = 0
        self._routed_per_replica: Dict[int, int] = {}
        # extra stats() sections registered by higher layers (the
        # session manager attaches its recovery telemetry here so one
        # pool.stats() call shows the whole serving+sessions picture)
        self._stats_sources: Dict[str, object] = {}
        self._retry_cache = (0.0, 0.0)   # (monotonic stamp, estimate)
        # guardrail / escalation / quarantine telemetry
        self._n_flagged = 0
        self._n_escalated = 0
        self._n_escalation_failures = 0
        self._n_quarantined = 0
        self._n_respawned = 0
        self._n_permanent_deaths = 0
        self._n_stalls_detected = 0
        self._n_breaker_trips = 0
        self._quarantine_counts: Dict[int, int] = {}
        # fleet-lifetime accumulators for counters of engines this pool
        # retired (rolling swap_artifact exchanges, quarantine
        # cold-restarts): without these, stats() summed only the
        # *current* engines' dispatch/guardrail counters and every
        # exchange silently zeroed the fleet totals
        self._retired_dispatch: Dict[str, int] = {}
        self._retired_detectors: Dict[str, int] = {}
        self._n_engines_retired = 0
        # static bucket -> home replica map (affinity tie-break): spread
        # the ladder round-robin over *primary-tier* replicas so each
        # "owns" some shape classes (escalation replicas never get homes)
        primary_ids = [i for i, r in enumerate(ranks)
                       if r == self._primary_rank]
        caps = sorted(b.capacity for b in self._buckets)
        self._home = {cap: primary_ids[i % len(primary_ids)]
                      for i, cap in enumerate(caps)}
        sched_cfg = self.cluster.scheduler_config()
        # escalation tiers run singleton flushes (max_batch=1, zero
        # deadline, unbounded queue): an escalated re-run is then
        # bit-identical to a direct batch-of-1 call on that tier
        esc_cfg = SchedulerConfig(max_batch=1, deadline_ms=0.0,
                                  warmup=False, max_queue=None)
        self._replicas = [
            Replica(i, eng,
                    sched_cfg if ranks[i] == self._primary_rank else esc_cfg,
                    on_failure=self._on_replica_failure,
                    warmup=cluster.warmup,
                    on_flagged=self._on_flagged,
                    breaker_window=cluster.breaker_window)
            for i, eng in enumerate(engines)]
        # health-plane linkage (watch_alerts): recent alerts the pool
        # has been handed, surfaced under stats()["alerts"]
        self._alerts_seen: deque = deque(maxlen=64)
        self._n_alerts_seen = 0
        self._alert_unsub = None
        self._watchdog: Optional[threading.Thread] = None
        self._watchdog_stop = threading.Event()
        if (cluster.stall_timeout_s is not None
                or cluster.breaker_flag_rate is not None):
            self._watchdog = threading.Thread(
                target=self._watch, name="cluster-watchdog", daemon=True)
            self._watchdog.start()
        self._publish_fleet_gauges()
        if wait_ready:
            self.wait_ready()

    # -- construction --------------------------------------------------------

    @classmethod
    def from_quantized(cls, model_cfg: so3.So3kratesConfig, qparams,
                       serve: ServeConfig,
                       cluster: ClusterConfig = ClusterConfig(),
                       fp32_nbytes: Optional[int] = None,
                       devices: Optional[Sequence] = None,
                       artifact_version: str = "",
                       guardrails: Optional[GuardrailConfig] = None
                       ) -> "ClusterPool":
        """One engine per device from a single serving-format tree (each
        replica gets its own committed copy via ``jax.device_put``)."""
        if devices is None:
            devices = pick_devices(cluster.n_replicas)
        engines = [QuantizedEngine.from_quantized(
            model_cfg, qparams, serve, fp32_nbytes=fp32_nbytes,
            device=d, artifact_version=artifact_version,
            guardrails=guardrails) for d in devices]
        return cls(engines, cluster)

    @classmethod
    def from_config(cls, model_cfg: so3.So3kratesConfig,
                    params=None, serve: ServeConfig = ServeConfig(),
                    cluster: ClusterConfig = ClusterConfig(),
                    seed: int = 0,
                    devices: Optional[Sequence] = None,
                    guardrails: Optional[GuardrailConfig] = None
                    ) -> "ClusterPool":
        """Quantize fp32 params once (random init when None), replicate
        the serving tree across devices."""
        base = QuantizedEngine.from_config(model_cfg, params=params,
                                           serve=serve, seed=seed)
        return cls.from_quantized(
            model_cfg, base.qparams, serve, cluster,
            fp32_nbytes=base.memory_report()["fp32_bytes"], devices=devices,
            guardrails=guardrails)

    @classmethod
    def from_tiers(cls, model_cfg: so3.So3kratesConfig, params=None,
                   serve: ServeConfig = ServeConfig(),
                   tier_plan: Optional[Dict[str, int]] = None,
                   cluster: ClusterConfig = ClusterConfig(),
                   seed: int = 0,
                   devices: Optional[Sequence] = None,
                   guardrails: Optional[GuardrailConfig] = None
                   ) -> "ClusterPool":
        """Mixed-precision fleet from ONE fp32 params tree (random init
        when None): ``tier_plan`` maps precision tier -> replica count,
        e.g. ``{"w4a8": 2, "w8a8": 1, "fp32": 1}`` — two cheap traffic
        replicas backed by one escalation replica each at w8a8 and fp32.
        Every tier is quantized from the *same* weights, so an escalated
        re-run answers the same model at higher precision. Replicas are
        ordered cheapest tier first (ids 0..N-1); ``devices`` (when
        given) must cover the total replica count."""
        if tier_plan is None:
            tier_plan = {"w4a8": 2, "w8a8": 1, "fp32": 1}
        plan = sorted(tier_plan.items(), key=lambda kv: tier_rank(kv[0]))
        total = sum(n for _, n in plan)
        if total < 1:
            raise ValueError("tier_plan must place at least one replica")
        if params is None:
            params = so3.init_params(jax.random.PRNGKey(seed), model_cfg)
        if devices is None:
            devices = pick_devices(total)
        elif len(devices) < total:
            raise ValueError(f"tier_plan wants {total} replicas but only "
                             f"{len(devices)} devices were given")
        nbytes = fp32_bytes(params)
        engines, i = [], 0
        for tier, n in plan:
            if n <= 0:
                continue
            qp = quantize_so3_params(params, tier)
            tier_serve = dataclasses.replace(serve, mode=tier)
            for _ in range(n):
                engines.append(QuantizedEngine.from_quantized(
                    model_cfg, qp, tier_serve, fp32_nbytes=nbytes,
                    device=devices[i], guardrails=guardrails))
                i += 1
        return cls(engines, cluster)

    @classmethod
    def from_artifact(cls, path: str, serve: Optional[ServeConfig] = None,
                      cluster: ClusterConfig = ClusterConfig(),
                      devices: Optional[Sequence] = None) -> "ClusterPool":
        """Cold-start a whole pool from one packed artifact: a single
        deserialize + checksum pass, then per-device replication."""
        art = load_artifact(path)
        if serve is None:
            serve = art.serve
        else:
            ensure_mode_matches(art.serve.mode, serve.mode)
        return cls.from_quantized(
            art.model_cfg, art.qparams, serve, cluster,
            fp32_nbytes=art.fp32_bytes, devices=devices,
            artifact_version=art.version_tag)

    def wait_ready(self, timeout: Optional[float] = None) -> None:
        """Block until every replica finished (parallel) warmup."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for r in self._replicas:
            left = None if deadline is None else max(deadline
                                                     - time.monotonic(), 0)
            if not r.ready.wait(left):
                raise TimeoutError(
                    f"replica {r.replica_id} not ready within {timeout}s")

    # -- client side ---------------------------------------------------------

    def submit(self, graph: Graph) -> RequestHandle:
        """Route one molecule to a replica. Raises like ``infer_batch``
        for off-ladder molecules, :class:`SchedulerClosed` when the pool
        is closed or no replica survives, :class:`SchedulerOverloaded`
        (with ``retry_after_s``) when bounded admission sheds."""
        handle = RequestHandle(graph, time.monotonic())
        try:
            handle.bucket_capacity = assign_bucket(graph.n_atoms,
                                                   self._buckets).capacity
            if handle.trace is not None:
                handle.trace.set_attr("bucket", handle.bucket_capacity)
            # a replica can die between routing and admission: re-route,
            # the alive set is re-read each attempt
            for _ in range(2 * len(self._replicas)):
                rep = self._route(handle.bucket_capacity)
                if rep.try_submit(handle):
                    with self._lock:
                        self._n_routed += 1
                        self._routed_per_replica[rep.replica_id] = (
                            self._routed_per_replica.get(
                                rep.replica_id, 0) + 1)
                    REGISTRY.counter("serve_requests_total",
                                     surface="pool",
                                     event="submitted").inc()
                    return handle
            with self._lock:
                self._n_shed += 1
            REGISTRY.counter("serve_requests_total", surface="pool",
                             event="shed").inc()
            raise SchedulerOverloaded(
                "no replica admitted the request (queues filled while "
                "routing)", self._retry_after())
        except BaseException as e:
            handle._reject(e)
            raise

    def submit_chunk(self, fn, bucket_capacity: int,
                     preferred_replica: Optional[int] = None,
                     session_id: str = "",
                     chunk_idx: int = 0,
                     min_tier: Optional[str] = None) -> ChunkHandle:
        """Route one session chunk (``fn(engine) -> result``) to a
        replica, under the same admission/affinity policy as one-shot
        traffic. ``bucket_capacity`` must be on the pool's bucket ladder
        (the session molecule's shape class — chunks share batch-affinity
        state with same-shape inference). ``preferred_replica`` is a
        stickiness hint: the replica that ran the previous chunk keeps
        the trajectory when it is live and below the admission bound,
        so device-resident arrays and compiled segment shapes stay warm;
        routing silently falls back to JSQ when it is not. Raises
        :class:`SchedulerOverloaded`/:class:`SchedulerClosed` exactly
        like :meth:`submit` — the session manager's typed
        retry-with-backoff handles sheds. ``min_tier`` routes the chunk
        to a replica at (or above) that precision tier — the session
        manager's guardrail escalation re-runs a flagged MD chunk one
        tier up through this."""
        if bucket_capacity not in self._home:
            raise ValueError(
                f"bucket_capacity {bucket_capacity} is not on the pool's "
                f"ladder {sorted(self._home)}")
        handle = ChunkHandle(fn, time.monotonic(),
                             bucket_capacity=bucket_capacity,
                             session_id=session_id, chunk_idx=chunk_idx)
        min_rank = (self._primary_rank if min_tier is None
                    else tier_rank(min_tier))
        mq = self.cluster.max_queue
        try:
            if preferred_replica is not None:
                for rep in self._replicas:
                    if (rep.replica_id == preferred_replica
                            and rep.accepting
                            and tier_rank(rep.tier) >= min_rank
                            and (mq is None or rep.depth() < mq)
                            and rep.try_submit(handle)):
                        with self._lock:
                            self._n_chunks_routed += 1
                            self._routed_per_replica[rep.replica_id] = (
                                self._routed_per_replica.get(
                                    rep.replica_id, 0) + 1)
                        return handle
            for _ in range(2 * len(self._replicas)):
                rep = self._route(handle.bucket_capacity, min_rank=min_rank)
                if rep.try_submit(handle):
                    with self._lock:
                        self._n_chunks_routed += 1
                        self._routed_per_replica[rep.replica_id] = (
                            self._routed_per_replica.get(
                                rep.replica_id, 0) + 1)
                    return handle
            with self._lock:
                self._n_shed += 1
            raise SchedulerOverloaded(
                "no replica admitted the chunk (queues filled while "
                "routing)", self._retry_after())
        except BaseException as e:
            handle._reject(e)
            raise

    def infer(self, graphs: Sequence[Graph],
              timeout: Optional[float] = None,
              timeout_s: Optional[float] = None) -> List[MoleculeResult]:
        """Convenience: submit all, wait for all (in input order).
        ``timeout_s`` raises the typed
        :class:`~repro.server.scheduler.RequestTimeout` per request."""
        handles = [self.submit(g) for g in graphs]
        return [h.result(timeout=timeout, timeout_s=timeout_s)
                for h in handles]

    def close(self) -> None:
        """Stop admitting, drain every replica, join their workers."""
        with self._lock:
            if not self._open:
                return
            self._open = False
        if self._watchdog is not None:
            self._watchdog_stop.set()
            self._watchdog.join()
        if self._alert_unsub is not None:
            self._alert_unsub()
            self._alert_unsub = None
        for r in self._replicas:
            r.begin_close()
        for r in self._replicas:
            if not r._expropriated:   # an expropriated stuck worker may
                r.join()              # sleep past close — don't wait on it

    def __enter__(self) -> "ClusterPool":
        return self

    def __exit__(self, *exc):
        self.close()

    # -- routing -------------------------------------------------------------

    def _live(self) -> List[Replica]:
        return [r for r in self._replicas if r.accepting]

    def _retry_after(self) -> float:
        """Backoff hint for shed requests: about one flush's service
        time from recent telemetry, floored at the batching deadline.
        Cached for 0.5 s — sheds happen at the offered request rate
        during overload, exactly when per-shed replica-lock sweeps
        would contend with the serving workers."""
        now = time.monotonic()
        with self._lock:
            stamp, est = self._retry_cache
            if now - stamp < 0.5 and est > 0.0:
                return est
        recent = [s for r in self._replicas for s in r.recent_service_s()]
        est = (sum(recent) / len(recent)) if recent else 0.0
        est = max(est, self.cluster.deadline_ms * 1e-3, 0.01)
        with self._lock:
            self._retry_cache = (now, est)
        return est

    def _route(self, cap: int, ignore_bound: bool = False,
               min_rank: Optional[int] = None) -> Replica:
        """JSQ + bucket affinity over live replicas (see module doc).

        Tier selection: ordinary traffic (``min_rank=None``) routes to
        the primary (cheapest) tier; escalated work passes the minimum
        acceptable ``tier_rank``. Either way the *lowest* qualifying
        tier with a live replica is used — so when every primary
        replica is gone, traffic degrades up-tier (more precise, more
        expensive) rather than failing."""
        with self._lock:
            if not self._open:
                raise SchedulerClosed("cluster pool is closed")
        floor = self._primary_rank if min_rank is None else min_rank
        live = [r for r in self._live() if tier_rank(r.tier) >= floor]
        if not live:
            raise SchedulerClosed("no live replicas")
        lo = min(tier_rank(r.tier) for r in live)
        live = [r for r in live if tier_rank(r.tier) == lo]
        depths = {r.replica_id: r.depth() for r in live}
        mq = self.cluster.max_queue
        if mq is not None and not ignore_bound:
            ok = [r for r in live if depths[r.replica_id] < mq]
            if not ok:
                with self._lock:
                    self._n_shed += 1
                retry = self._retry_after()
                raise SchedulerOverloaded(
                    f"all {len(live)} live replica queues at max_queue="
                    f"{mq}: request shed (retry in ~{retry:.3f}s)", retry)
        else:
            ok = live
        d_min = min(depths[r.replica_id] for r in ok)
        cands = [r for r in ok
                 if depths[r.replica_id] <= d_min + self.cluster.affinity_slack]
        home = self._home.get(cap, 0)

        def preference(r: Replica):
            return (-r.depth_of(cap),                  # fill same-shape batches
                    0 if r.replica_id == home else 1,  # bucket's home replica
                    depths[r.replica_id],              # then shortest queue
                    r.replica_id)
        return min(cands, key=preference)

    # -- failover ------------------------------------------------------------

    def _on_replica_failure(self, rep: Replica,
                            orphans: List[RequestHandle],
                            error: BaseException) -> None:
        """Called from a dying replica's worker thread (no locks held):
        requeue its queued + in-flight handles onto survivors."""
        with self._lock:
            self._n_failures += 1
        REGISTRY.counter("pool_events_total",
                         event="replica_failure").inc()
        self._requeue_orphans(rep, orphans, error)

    def _requeue_orphans(self, rep: Replica, orphans: List[RequestHandle],
                         error: BaseException) -> None:
        """Requeue a dead/quarantined replica's handles onto survivors:
        same precision tier first, then (when none remains) the lowest
        live tier — a request is resolved with ``error`` only after
        ``max_requeues`` failovers or when no survivor admits it."""
        rep_rank = tier_rank(rep.tier)
        tries = ((rep_rank,) if rep_rank == self._primary_rank
                 else (rep_rank, self._primary_rank))
        for h in orphans:
            h.n_requeues += 1
            if h.n_requeues > self.cluster.max_requeues:
                if h.trace is not None:
                    h.trace.event("requeue_budget_exhausted",
                                  from_replica=rep.replica_id,
                                  n_requeues=h.n_requeues)
                h._resolve(error=error, replica_id=rep.replica_id)
                continue
            if h.trace is not None:
                # re-enter a queue *before* any survivor can pick the
                # handle: the hop's queue segment starts here (it
                # closes the dead replica's serve segment for in-flight
                # work; queued orphans just start a fresh queue segment)
                h.trace.bump_hop()
                h.trace.event("requeued", from_replica=rep.replica_id,
                              error=type(error).__name__)
                h.trace.begin("queue")
            REGISTRY.counter("pool_events_total", event="requeued").inc()
            placed = False
            for min_rank in tries:
                for _ in range(2 * len(self._replicas)):
                    try:
                        # never shed an already-admitted request:
                        # failover requeue bypasses the admission bound
                        surv = self._route(h.bucket_capacity,
                                           ignore_bound=True,
                                           min_rank=min_rank)
                    except (SchedulerClosed, SchedulerOverloaded):
                        break
                    if surv.try_submit(h, force=True):
                        placed = True
                        break
                if placed:
                    break
            if placed:
                with self._lock:
                    self._n_requeued += 1
                    if isinstance(h, ChunkHandle):
                        self._n_chunks_requeued += 1
            else:
                h._resolve(error=error, replica_id=rep.replica_id)

    # -- guardrail escalation ------------------------------------------------

    def _on_flagged(self, rep: Replica, handle: RequestHandle,
                    result: MoleculeResult) -> bool:
        """Replica guardrail-triage hook (called from its worker thread,
        no replica locks held): re-run a flagged request one precision
        tier up when the ladder and the escalation budget allow. True =
        pool took ownership (the handle now sits in a higher-tier
        replica's queue); False = the flagging replica resolves it
        locally."""
        with self._lock:
            self._n_flagged += 1
        if len(handle.escalations) >= self.cluster.max_escalations:
            return False
        from_rank = tier_rank(rep.tier)
        targets = sorted(
            (r for r in self._replicas
             if r is not rep and r.accepting
             and tier_rank(r.tier) > from_rank),
            key=lambda r: (tier_rank(r.tier), r.depth(), r.replica_id))
        reason = result.flags[0].reason if result.flags else "flagged"
        if handle.trace is not None and targets:
            # hop bookkeeping *before* the first try_submit: once a
            # target admits the handle its worker may open the next
            # serve segment immediately, so the escalation's queue
            # segment must already be the open one
            handle.trace.bump_hop()
            handle.trace.event("escalated", from_tier=rep.tier,
                               from_replica=rep.replica_id, reason=reason)
            handle.trace.begin("queue", tier=targets[0].tier,
                               escalated=True)
        for tgt in targets:
            # append the audit hop *before* submitting: the target's
            # flush stamps handle.escalations into its result
            handle.escalations.append(EscalationRecord(
                from_tier=rep.tier, to_tier=tgt.tier, reason=reason,
                from_replica=rep.replica_id))
            if tgt.try_submit(handle, force=True):
                with self._lock:
                    self._n_escalated += 1
                REGISTRY.counter("pool_events_total",
                                 event="escalated").inc()
                return True
            handle.escalations.pop()
        if handle.trace is not None and targets:
            # no target admitted: the flagging replica resolves locally;
            # the optimistic queue segment closes at resolve (~0s)
            handle.trace.event("escalation_failed", from_tier=rep.tier)
        with self._lock:
            self._n_escalation_failures += 1
        REGISTRY.counter("pool_events_total",
                         event="escalation_failed").inc()
        return False

    # -- watchdog / circuit breaker / quarantine -----------------------------

    def _watch(self) -> None:
        """Pool watchdog loop: every ``watchdog_interval_s`` sweep the
        replicas for (a) a worker stuck on one unit of work past
        ``stall_timeout_s`` — the engine-lock stall ``sessions.faults``
        injects — and (b) a flagged-rate circuit-breaker trip. Either
        quarantines the replica: its handles are expropriated and
        requeued (zero requests lost), the engine is cold-restarted on
        the same device, and the replacement is re-admitted only after
        ``probation_s``."""
        c = self.cluster
        while not self._watchdog_stop.wait(c.watchdog_interval_s):
            with self._lock:
                if not self._open:
                    return
            for idx, rep in enumerate(list(self._replicas)):
                if rep._expropriated:
                    continue        # already quarantined, worker winding down
                if c.stall_timeout_s is not None:
                    busy = rep.busy_duration()
                    if busy is not None and busy > c.stall_timeout_s:
                        with self._lock:
                            self._n_stalls_detected += 1
                        REGISTRY.counter("pool_events_total",
                                         event="stall_detected").inc()
                        self._quarantine(idx, GuardrailViolation(
                            f"replica {rep.replica_id} stalled: busy "
                            f"{busy:.2f}s > stall_timeout_s="
                            f"{c.stall_timeout_s}s", reason="stall"))
                        continue
                if c.breaker_flag_rate is not None:
                    events, flagged = rep.flag_window()
                    if (events >= c.breaker_min_events
                            and flagged / events > c.breaker_flag_rate):
                        with self._lock:
                            self._n_breaker_trips += 1
                        self._quarantine(idx, GuardrailViolation(
                            f"replica {rep.replica_id} circuit breaker: "
                            f"{flagged}/{events} recent flushes flagged "
                            f"(> {c.breaker_flag_rate:.0%})",
                            reason="breaker"))

    def _quarantine(self, idx: int, error: GuardrailViolation) -> None:
        """Take a sick replica out of service: expropriate + requeue its
        handles, cold-restart its engine on the same device, hold the
        replacement on probation. A replica id that trips more than
        ``max_quarantines`` times stays dead — a replica that keeps
        tripping is a hardware or weights problem, not bad luck."""
        rep = self._replicas[idx]
        with self._lock:
            if not self._open:
                return
            n = self._quarantine_counts.get(rep.replica_id, 0) + 1
            self._quarantine_counts[rep.replica_id] = n
            self._n_quarantined += 1
        REGISTRY.counter("pool_events_total",
                         event="quarantined").inc()
        orphans = rep.expropriate(error)
        self._requeue_orphans(rep, orphans, error)
        if n > self.cluster.max_quarantines:
            with self._lock:
                self._n_permanent_deaths += 1
            return
        old = rep.engine
        # the expropriated worker runs no further flushes on old (its
        # handles are gone); fold its counters into the fleet totals
        # before the cold restart discards the engine
        self._retire_engine_counters(old)
        eng = QuantizedEngine.from_quantized(
            old.model_cfg, old.qparams, old.serve,
            device=old.device, artifact_version=old.artifact_version,
            guardrails=old.guardrails)
        fresh = Replica(rep.replica_id, eng, rep.config,
                        on_failure=self._on_replica_failure,
                        warmup=self.cluster.warmup,
                        on_flagged=self._on_flagged,
                        breaker_window=self.cluster.breaker_window)
        fresh.hold_admission(self.cluster.probation_s)
        self._replicas[idx] = fresh
        with self._lock:
            self._n_respawned += 1
        self._publish_fleet_gauges()

    def kill_replica(self, replica_id: int, mode: str = "drain") -> None:
        """Injectable failure (tests, chaos drills, cluster_bench):
        replica ``replica_id`` dies; its requests fail over to
        survivors. ``mode="in_flight"`` also fails the flush being
        formed — see :meth:`Replica.kill`."""
        self._replicas[replica_id].kill(mode)

    def _retire_engine_counters(self, engine: QuantizedEngine) -> None:
        """Fold a retiring engine's dispatch/guardrail counters into the
        pool's fleet-lifetime accumulators before the engine is dropped
        (swap_artifact exchange, quarantine cold-restart) — ``stats()``
        adds these back so fleet totals survive engine exchanges. The
        process-wide ``repro.obs`` registry needs no such handling: its
        instruments are keyed by (name, labels), not by engine."""
        dispatch = engine.stats_snapshot()
        detectors = engine.guard_snapshot()
        with self._lock:
            for k, v in dispatch.items():
                self._retired_dispatch[k] = (
                    self._retired_dispatch.get(k, 0) + v)
            for k, v in detectors.items():
                self._retired_detectors[k] = (
                    self._retired_detectors.get(k, 0) + v)
            self._n_engines_retired += 1

    # -- rolling weight swap -------------------------------------------------

    def swap_artifact(self, path: str,
                      warmup: bool = True) -> Dict[str, object]:
        """Zero-downtime rolling weight swap from a packed artifact.

        The artifact is read and checksum-verified once; each live
        replica then gets a fresh engine on its own device — warmed up
        *before* the exchange, while the old engine (and every other
        replica) keeps serving — and swaps under its flush lock. At any
        instant at most one replica is briefly paused (bounded by one
        flush), the rest serve; no request is dropped. Results carry the
        new ``artifact_version`` from the first post-swap flush of each
        replica onward.
        """
        art = load_artifact(path)
        ensure_mode_matches(art.serve.mode, self.serve.mode)
        if art.model_cfg != self.model_cfg:
            raise ArtifactError(
                "artifact model config does not match the pool's — a "
                "rolling swap replaces weights, not architecture")
        report = []
        for rep in self._replicas:
            if not rep.accepting:
                continue             # dead replicas don't get new weights
            if tier_rank(rep.tier) != tier_rank(art.serve.mode):
                continue             # escalation tiers keep their own weights
            t0 = time.monotonic()
            eng = QuantizedEngine.from_quantized(
                art.model_cfg, art.qparams, self.serve,
                fp32_nbytes=art.fp32_bytes, device=rep.device,
                artifact_version=art.version_tag)
            warm_s = eng.warmup() if warmup else 0.0
            old_engine = rep.engine
            pause_s = rep.swap_engine(eng)
            # swap_engine held the flush lock: once it returns, the old
            # engine serves no more work and its counters are final
            self._retire_engine_counters(old_engine)
            REGISTRY.counter("pool_events_total",
                             event="engine_swapped").inc()
            report.append({"replica_id": rep.replica_id,
                           "warmup_s": warm_s, "pause_s": pause_s,
                           "total_s": time.monotonic() - t0})
        return {"version_tag": art.version_tag, "replicas": report}

    # -- telemetry -----------------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self._replicas)

    def queue_depth(self) -> int:
        return sum(r.depth() for r in self._replicas)

    def _publish_fleet_gauges(self) -> None:
        """Fleet composition into the obs registry (``obs_top`` reads
        the exported file, not ``stats()``): live replicas per tier."""
        tiers: Dict[str, int] = {}
        for r in self._replicas:
            if r.accepting or r.busy_duration() is not None:
                tiers[r.tier] = tiers.get(r.tier, 0) + 1
        for tier, n in tiers.items():
            REGISTRY.gauge("cluster_replicas", tier=tier).set(n)

    def watch_alerts(self, bus) -> "ClusterPool":
        """Subscribe the pool to an :class:`~repro.obs.slo.AlertBus`:
        alerts are recorded (bounded history, ``stats()["alerts"]``)
        and counted under ``pool_events_total{event="alert"}`` so the
        fleet's own heartbeat carries the health plane's verdicts.
        *Acting* on alerts stays the guardrail/watchdog layer's job —
        the bus hands the pool attributed evidence, not commands.
        Returns ``self`` so ``ClusterPool.from_config(...)
        .watch_alerts(bus)`` chains."""
        def _on_alert(alert) -> None:
            with self._lock:
                self._alerts_seen.append(alert)
                self._n_alerts_seen += 1
            REGISTRY.counter("pool_events_total", event="alert").inc()
        if self._alert_unsub is not None:
            self._alert_unsub()
        self._alert_unsub = bus.subscribe(_on_alert)
        return self

    def flush_records(self) -> List:
        """Every replica's :class:`FlushRecord` list, merged — the
        flush-slice source for ``repro.obs.timeline.chrome_trace``."""
        return [f for r in self._replicas for f in r.records()]

    def warmup_records(self) -> List[Dict]:
        """Per-replica warmup/compile report entries (each tagged with
        its ``replica`` id) — the compile-slice source for the
        timeline export."""
        out: List[Dict] = []
        for r in self._replicas:
            for rec in getattr(r.engine, "warmup_report", None) or []:
                out.append({"replica": r.replica_id, **rec})
        return out

    def reset_stats(self) -> None:
        """Zero per-phase telemetry (flush records, completion/error and
        router counters, engine dispatch counters) — benches call this
        between phases so rates reconcile within the phase. Liveness
        state is untouched."""
        for r in self._replicas:
            r.reset_records()
            r.engine.reset_stats()
        with self._lock:
            self._n_routed = 0
            self._n_shed = 0
            self._n_requeued = 0
            self._n_failures = 0
            self._n_chunks_routed = 0
            self._n_chunks_requeued = 0
            self._routed_per_replica = {}
            self._retry_cache = (0.0, 0.0)
            # per-phase view: retired-engine accumulators zero with the
            # engine counters they extend (fleet-lifetime totals live in
            # the process-wide obs registry, which reset_stats never
            # touches)
            self._retired_dispatch = {}
            self._retired_detectors = {}

    def attach_stats_source(self, name: str, fn) -> None:
        """Register an extra ``stats()`` section: ``fn()`` must return a
        JSON-able dict, reported under ``name``. ``repro.sessions``
        attaches its session/fault/checkpoint telemetry here so
        operators (and the sessions bench) read one merged snapshot."""
        with self._lock:
            self._stats_sources[name] = fn

    def stats(self) -> Dict[str, object]:
        """Cluster-wide snapshot: per-replica health/heartbeat, router
        counters (routing balance, sheds, failovers), merged flush
        telemetry (per-replica breakdown included), and the summed
        engine dispatch counters — same headline keys as
        ``MicroBatchScheduler.stats()`` so drivers and benches read
        either."""
        replicas = [r.snapshot() for r in self._replicas]
        flushes = [f for r in self._replicas for f in r.records()]
        with self._lock:
            router = {
                "n_routed": self._n_routed,
                "n_shed": self._n_shed,
                "n_requeued": self._n_requeued,
                "n_failures": self._n_failures,
                "n_chunks_routed": self._n_chunks_routed,
                "n_chunks_requeued": self._n_chunks_requeued,
                "routed_per_replica": {
                    str(k): v for k, v in
                    sorted(self._routed_per_replica.items())},
            }
            sources = dict(self._stats_sources)
        # fleet totals = current engines + engines retired by swaps /
        # quarantine cold-restarts (the satellite fix: exchanges used to
        # silently zero these)
        with self._lock:
            dispatch: Dict[str, int] = dict(self._retired_dispatch)
            n_retired = self._n_engines_retired
        for r in self._replicas:
            for k, v in r.engine.stats_snapshot().items():
                dispatch[k] = dispatch.get(k, 0) + v
        out: Dict[str, object] = {
            "n_replicas": len(self._replicas),
            "n_live": len(self._live()),
            "n_submitted": router["n_routed"],
            "n_completed": sum(r["n_completed"] for r in replicas),
            "n_shed": router["n_shed"],
            "warmup_s": max((r["warmup_s"] for r in replicas), default=0.0),
            "replicas": replicas,
            "router": router,
            "n_engines_retired": n_retired,
        }
        out["chunks"] = {
            "n_routed": router["n_chunks_routed"],
            "n_requeued": router["n_chunks_requeued"],
            "n_completed": sum(r["n_chunks_completed"] for r in replicas),
            "n_errors": sum(r["n_chunk_errors"] for r in replicas),
            "n_stalls_injected": sum(r["n_stalls_injected"]
                                     for r in replicas),
        }
        tiers: Dict[str, int] = {}
        for r in self._replicas:
            tiers[r.tier] = tiers.get(r.tier, 0) + 1
        with self._lock:
            detectors: Dict[str, int] = dict(self._retired_detectors)
        for r in self._replicas:
            for k, v in r.engine.guard_snapshot().items():
                detectors[k] = detectors.get(k, 0) + v
        with self._lock:
            out["tiers"] = tiers
            out["guardrails"] = {
                "n_flagged": self._n_flagged,
                "n_escalated": self._n_escalated,
                "n_escalation_failures": self._n_escalation_failures,
                "n_quarantined": self._n_quarantined,
                "n_breaker_trips": self._n_breaker_trips,
                "n_stalls_detected": self._n_stalls_detected,
                "n_respawned": self._n_respawned,
                "n_permanent_deaths": self._n_permanent_deaths,
                "detectors": detectors,
            }
        with self._lock:
            out["alerts"] = {
                "n_seen": self._n_alerts_seen,
                "recent": [a.to_json() for a in self._alerts_seen],
            }
        for name, fn in sources.items():
            try:
                out[name] = fn()
            except Exception as e:   # a sick stats source must not
                out[name] = {"error": repr(e)}  # break the heartbeat
        out.update(flush_summary(flushes))
        out["engine_dispatch"] = dispatch
        return out
