"""Linear (invariant-branch) quantizers.

Implements the paper's invariant-branch scheme: symmetric linear quantization
with straight-through-estimator gradients, per-tensor or per-channel scales,
for both weights (W4/W8) and activations (A8).

All fake-quant functions are differentiable via STE and jit-safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "qmax",
    "abs_max_scale",
    "quantize",
    "dequantize",
    "fake_quant",
    "fake_quant_ste",
    "pack_int4",
    "unpack_int4",
    "quantize_log_magnitude",
    "dequantize_log_magnitude",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of a symmetric linear quantizer."""

    bits: int = 8
    # axis (or axes) along which a separate scale is computed; None = per-tensor
    channel_axis: Optional[int] = None
    # numerical floor for scales so zero tensors don't produce inf
    eps: float = 1e-8

    @property
    def levels(self) -> int:
        return 2 ** self.bits


def qmax(bits: int) -> int:
    """Largest representable magnitude of a signed symmetric b-bit grid."""
    return 2 ** (bits - 1) - 1


def abs_max_scale(x: jnp.ndarray, bits: int, channel_axis: Optional[int] = None,
                  eps: float = 1e-8) -> jnp.ndarray:
    """Symmetric abs-max calibration: scale s.t. max|x| maps to qmax."""
    if channel_axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        axes = tuple(i for i in range(x.ndim) if i != channel_axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    return jnp.maximum(amax, eps) / qmax(bits)


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Real quantization to a signed integer grid (returns int8 storage)."""
    q = jnp.clip(jnp.round(x / scale), -qmax(bits), qmax(bits))
    return q.astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(scale.dtype) * scale


@jax.custom_vjp
def _ste_round(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize without STE (gradients are zero a.e.)."""
    q = jnp.clip(jnp.round(x / scale), -qmax(bits), qmax(bits))
    return q * scale


def fake_quant_ste(x: jnp.ndarray, bits: int = 8,
                   channel_axis: Optional[int] = None,
                   scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Differentiable fake quantization with straight-through rounding.

    The clip is expressed with jnp.clip on the pre-round value so gradients
    outside the representable range are zero (standard QAT saturation).
    """
    if scale is None:
        scale = abs_max_scale(jax.lax.stop_gradient(x), bits, channel_axis)
    m = qmax(bits)
    y = jnp.clip(x / scale, -m, m)
    return _ste_round(y) * scale


# ---------------------------------------------------------------------------
# int4 packing (two nibbles per byte) — storage format for W4 weights.
# ---------------------------------------------------------------------------

def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int values in [-8, 7] pairwise along the last axis into uint8.

    Last axis must be even. out.shape[-1] == q.shape[-1] // 2.
    """
    if q.shape[-1] % 2 != 0:
        raise ValueError(f"last dim must be even, got {q.shape}")
    q = q.astype(jnp.int32) & 0xF  # two's complement nibble
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_int4; returns int8 values in [-8, 7]."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    # sign-extend nibbles
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Log-domain magnitude quantizer (the Q_m of MDDQ).
# Vector magnitudes follow a Chi distribution (paper §III-D); a log-domain
# grid allocates resolution multiplicatively, which keeps *relative* magnitude
# error uniform — the right notion for force vectors spanning decades.
# ---------------------------------------------------------------------------

def quantize_log_magnitude(m: jnp.ndarray, bits: int = 8,
                           m_min: float = 1e-6, m_max: float = 1e3) -> jnp.ndarray:
    """Quantize positive magnitudes on a log grid. Returns integer codes."""
    levels = 2 ** bits - 1
    lm = jnp.log(jnp.clip(m, m_min, m_max))
    lo, hi = jnp.log(m_min), jnp.log(m_max)
    t = (lm - lo) / (hi - lo)
    return jnp.clip(jnp.round(t * levels), 0, levels).astype(jnp.int32)


def dequantize_log_magnitude(code: jnp.ndarray, bits: int = 8,
                             m_min: float = 1e-6, m_max: float = 1e3) -> jnp.ndarray:
    levels = 2 ** bits - 1
    lo, hi = jnp.log(m_min), jnp.log(m_max)
    t = code.astype(jnp.float32) / levels
    return jnp.exp(lo + t * (hi - lo))
