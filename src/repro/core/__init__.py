"""GAQ core: the paper's contribution as composable JAX modules."""
from .quantizers import (
    QuantConfig,
    abs_max_scale,
    dequantize,
    fake_quant,
    fake_quant_ste,
    pack_int4,
    qmax,
    quantize,
    unpack_int4,
)
from .codebook import (
    covering_radius,
    fibonacci_sphere,
    make_codebook,
    nearest_code,
    octahedral_sphere,
    quantize_direction,
)
from .mddq import MDDQConfig, mddq_decode, mddq_encode, mddq_fake_quant
from .ste import geometric_ste_direction, identity_ste
from .lee import lee, lee_regularizer, random_rotation, random_rotations
from .attention_norm import (
    cosine_attention_logits,
    l2_normalize,
    robust_attention_weights,
)

__all__ = [
    "QuantConfig", "abs_max_scale", "dequantize", "fake_quant",
    "fake_quant_ste", "pack_int4", "qmax", "quantize", "unpack_int4",
    "covering_radius", "fibonacci_sphere", "make_codebook", "nearest_code",
    "octahedral_sphere", "quantize_direction",
    "MDDQConfig", "mddq_decode", "mddq_encode", "mddq_fake_quant",
    "geometric_ste_direction", "identity_ste",
    "lee", "lee_regularizer", "random_rotation", "random_rotations",
    "cosine_attention_logits", "l2_normalize", "robust_attention_weights",
]
