"""Magnitude-Direction Decoupled Quantization (MDDQ) — paper Definition 3.1.

Q(v) = Q_m(||v||) * Q_d(v / ||v||)

* Q_m: scalar quantizer on R_+ — either symmetric-linear (shared scale) or
  log-domain (default; magnitudes are Chi-distributed, log grid keeps relative
  error uniform).
* Q_d: nearest-codeword lookup in a spherical codebook C subset S^2.

Both a *real* path (integer codes, for storage/serving) and a *fake-quant*
path (quantize-dequantize with Geometric STE, for QAT) are provided.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .codebook import make_codebook, nearest_code
from .quantizers import (
    abs_max_scale,
    fake_quant_ste,
    quantize_log_magnitude,
    dequantize_log_magnitude,
)
from .ste import geometric_ste_direction, identity_ste

__all__ = ["MDDQConfig", "mddq_fake_quant", "mddq_encode", "mddq_decode"]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class MDDQConfig:
    direction_bits: int = 8          # codebook size = 2**direction_bits
    magnitude_bits: int = 8
    codebook_kind: str = "fibonacci"  # or "octahedral"
    magnitude_domain: str = "log"     # or "linear"
    geometric_ste: bool = True        # False -> plain STE (ablation)
    m_min: float = 1e-6
    m_max: float = 1e3

    def codebook(self) -> jnp.ndarray:
        return make_codebook(self.direction_bits, self.codebook_kind)


def _split(v: jnp.ndarray):
    # NaN-safe norm: d||v||/dv at v = 0 is 0/0; clamping the squared norm
    # before the sqrt makes the gradient exactly zero there instead, so
    # zero vectors (isolated atoms, padded batch slots) stay differentiable.
    m2 = jnp.sum(v * v, axis=-1, keepdims=True)
    m = jnp.sqrt(jnp.maximum(m2, _EPS * _EPS))
    u = v / jnp.maximum(m, _EPS)
    return m, u


def mddq_fake_quant(v: jnp.ndarray, cfg: MDDQConfig,
                    codebook: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Differentiable MDDQ for QAT. v: (..., 3) -> (..., 3).

    Gradients: magnitude path uses linear STE; direction path uses Geometric
    STE (tangent projection) unless cfg.geometric_ste is False.
    """
    if codebook is None:
        codebook = cfg.codebook()
    m, u = _split(v)

    # -- direction: snap to nearest codeword (non-differentiable) + STE
    q_dir = codebook[nearest_code(jax.lax.stop_gradient(u), codebook)]
    ste = geometric_ste_direction if cfg.geometric_ste else identity_ste
    u_hat = ste(u, q_dir)

    # -- magnitude
    if cfg.magnitude_domain == "log":
        code = quantize_log_magnitude(jax.lax.stop_gradient(m),
                                      cfg.magnitude_bits, cfg.m_min, cfg.m_max)
        m_q = dequantize_log_magnitude(code, cfg.magnitude_bits,
                                       cfg.m_min, cfg.m_max)
        # straight-through on the magnitude: m + stop_grad(m_q - m)
        m_hat = m + jax.lax.stop_gradient(m_q - m)
    else:
        m_hat = fake_quant_ste(m, cfg.magnitude_bits, channel_axis=None)

    # zero vectors stay zero (direction undefined); <= because the safe
    # norm in _split floors m at exactly _EPS for v == 0
    is_zero = m <= _EPS
    return jnp.where(is_zero, 0.0, m_hat * u_hat)


def mddq_encode(v: jnp.ndarray, cfg: MDDQConfig,
                codebook: Optional[jnp.ndarray] = None):
    """Real encoding: (..., 3) float -> (dir_idx int32 (...,), mag_code int32 (...,)).

    Storage cost per vector: direction_bits + magnitude_bits (e.g. 16 bits vs
    96 bits fp32 = 6x compression at the paper's 8+8 setting).
    """
    if codebook is None:
        codebook = cfg.codebook()
    m, u = _split(v)
    dir_idx = nearest_code(u, codebook)
    if cfg.magnitude_domain == "log":
        mag = quantize_log_magnitude(m[..., 0], cfg.magnitude_bits,
                                     cfg.m_min, cfg.m_max)
    else:
        scale = abs_max_scale(m, cfg.magnitude_bits)
        mag = jnp.clip(jnp.round(m[..., 0] / scale[..., 0]),
                       0, 2 ** cfg.magnitude_bits - 1).astype(jnp.int32)
    return dir_idx, mag


def mddq_decode(dir_idx: jnp.ndarray, mag_code: jnp.ndarray, cfg: MDDQConfig,
                codebook: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    if codebook is None:
        codebook = cfg.codebook()
    u = codebook[dir_idx]
    if cfg.magnitude_domain != "log":
        raise NotImplementedError("linear-domain decode requires stored scale")
    m = dequantize_log_magnitude(mag_code, cfg.magnitude_bits,
                                 cfg.m_min, cfg.m_max)
    return u * m[..., None]
