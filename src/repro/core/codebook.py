"""Spherical codebooks for the direction quantizer Q_d : S^2 -> C.

The paper requires a finite codebook C subset S^2 whose nearest-neighbour map
approximately commutes with rotations. We provide:

* ``fibonacci_sphere`` — near-uniform covering of S^2 (the default; covering
  radius decays ~ 1/sqrt(N), close to optimal for large N).
* ``octahedral_sphere`` — a grid symmetric under the octahedral subgroup of
  SO(3); exact commutation holds for the 24 rotations of that subgroup, which
  empirically lowers the *average* commutation error for small N.
* ``covering_radius`` — Monte-Carlo estimate of delta_d (Eq. 6).
* ``nearest_code`` — the Q_d map itself (argmax of dot products; on S^2 the
  geodesic-nearest codeword is the max-cosine codeword).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "fibonacci_sphere",
    "octahedral_sphere",
    "make_codebook",
    "nearest_code",
    "quantize_direction",
    "covering_radius",
]


def fibonacci_sphere(n: int) -> np.ndarray:
    """n near-uniform points on S^2 via the Fibonacci lattice. (n, 3) float32."""
    i = np.arange(n, dtype=np.float64) + 0.5
    phi = np.arccos(1.0 - 2.0 * i / n)           # polar angle
    golden = np.pi * (1.0 + 5.0 ** 0.5)           # golden angle * 2
    theta = golden * i
    x = np.sin(phi) * np.cos(theta)
    y = np.sin(phi) * np.sin(theta)
    z = np.cos(phi)
    pts = np.stack([x, y, z], axis=-1)
    return (pts / np.linalg.norm(pts, axis=-1, keepdims=True)).astype(np.float32)


def octahedral_sphere(n: int) -> np.ndarray:
    """Codebook closed under the octahedral rotation subgroup.

    Takes a Fibonacci seed restricted to one fundamental domain and replicates
    it by the 24 rotation matrices of the cube/octahedron group, then dedups.
    Resulting size is <= n (rounded to a multiple of orbit sizes).
    """
    group = _octahedral_rotations()
    seed_n = max(1, n // 24)
    seed = fibonacci_sphere(seed_n * 4)  # oversample, keep fundamental domain
    # fundamental domain of the octahedral group: x >= y >= z >= 0 (approx)
    mask = (seed[:, 0] >= seed[:, 1]) & (seed[:, 1] >= seed[:, 2]) & (seed[:, 2] >= 0)
    seed = seed[mask][:seed_n]
    if len(seed) == 0:
        seed = np.array([[1.0, 0.0, 0.0]], dtype=np.float32)
    orbit = np.einsum("gij,nj->gni", group, seed).reshape(-1, 3)
    # dedup points that coincide (seed on a symmetry axis has small orbit)
    rounded = np.round(orbit * 1e5).astype(np.int64)
    _, idx = np.unique(rounded, axis=0, return_index=True)
    pts = orbit[np.sort(idx)]
    return (pts / np.linalg.norm(pts, axis=-1, keepdims=True)).astype(np.float32)


def _octahedral_rotations() -> np.ndarray:
    """The 24 rotation matrices of the octahedral group (signed permutations
    with determinant +1)."""
    mats = []
    import itertools
    for perm in itertools.permutations(range(3)):
        for signs in itertools.product([1, -1], repeat=3):
            m = np.zeros((3, 3))
            for r, c in enumerate(perm):
                m[r, c] = signs[r]
            if np.isclose(np.linalg.det(m), 1.0):
                mats.append(m)
    out = np.stack(mats).astype(np.float32)
    assert out.shape[0] == 24
    return out


@functools.lru_cache(maxsize=None)
def make_codebook(bits: int = 8, kind: str = "fibonacci") -> jnp.ndarray:
    """Codebook with 2**bits entries (or the closest achievable size).

    Cached: the host-side lattice construction is pure in (bits, kind)
    and gets called per forward by serving/engine code — a 16-bit
    codebook is 65536 numpy trig evaluations we only want once. The
    returned jax array is immutable, so sharing one instance is safe.
    The conversion is forced to evaluate eagerly: the first call may
    happen inside a jit trace (e.g. ``sparse_energy(codebook=None)``
    under jit), and staging it there would cache a tracer that escapes
    into every later trace.
    """
    n = 2 ** bits
    if kind == "fibonacci":
        pts = fibonacci_sphere(n)
    elif kind == "octahedral":
        pts = octahedral_sphere(n)
    else:
        raise ValueError(f"unknown codebook kind {kind!r}")
    with jax.ensure_compile_time_eval():
        return jnp.asarray(pts)


_NEAREST_CHUNK = 4096


def nearest_code(u: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Index of the geodesic-nearest codeword for each unit vector.

    u: (..., 3); codebook: (N, 3). Returns int32 (...,).
    Large codebooks (16-bit = 65536 entries) are scanned in chunks so the
    score matrix never materializes at full width (the Pallas kernel tiles
    the same way in VMEM).
    """
    n = codebook.shape[0]
    if n <= _NEAREST_CHUNK:
        scores = jnp.einsum("...d,nd->...n", u, codebook)
        return jnp.argmax(scores, axis=-1).astype(jnp.int32)

    pad = (-n) % _NEAREST_CHUNK
    cb = jnp.concatenate([codebook, jnp.tile(codebook[:1], (pad, 1))]) \
        if pad else codebook
    chunks = cb.reshape(-1, _NEAREST_CHUNK, 3)

    def step(carry, ck):
        best, idx, base = carry
        scores = jnp.einsum("...d,nd->...n", u, ck[0])
        s = jnp.max(scores, axis=-1)
        i = jnp.argmax(scores, axis=-1).astype(jnp.int32) + base
        take = s > best
        return (jnp.where(take, s, best), jnp.where(take, i, idx),
                base + _NEAREST_CHUNK), None

    init = (jnp.full(u.shape[:-1], -2.0, u.dtype),
            jnp.zeros(u.shape[:-1], jnp.int32), jnp.int32(0))
    (best, idx, _), _ = jax.lax.scan(step, init, chunks[:, None])
    return idx


def quantize_direction(u: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Q_d: snap unit vectors to their nearest codeword. Shape-preserving."""
    idx = nearest_code(u, codebook)
    return codebook[idx]


def covering_radius(codebook: jnp.ndarray, n_samples: int = 200_000,
                    seed: int = 0) -> float:
    """Monte-Carlo estimate of delta_d = sup_u min_c angle(u, c) (radians)."""
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (n_samples, 3))
    u = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
    cos = jnp.einsum("sd,nd->sn", u, codebook)
    best = jnp.max(cos, axis=-1)
    return float(jnp.max(jnp.arccos(jnp.clip(best, -1.0, 1.0))))
