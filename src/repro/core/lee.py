"""Local Equivariance Error (LEE) — paper Eq. 1 — metric and regularizer.

LEE(f; G, R) = || f(rho_in(R) . G) - rho_out(R) f(G) ||_2

For force-field models: rho_in rotates atom coordinates (and any input
vectors), rho_out rotates predicted per-atom force vectors; scalar outputs
(energies) are invariant so their rho_out is identity.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["random_rotation", "random_rotations", "lee", "lee_regularizer"]


def random_rotation(key: jax.Array) -> jnp.ndarray:
    """Uniform (Haar) random rotation via normalized quaternion. (3,3)."""
    q = jax.random.normal(key, (4,))
    q = q / jnp.linalg.norm(q)
    w, x, y, z = q[0], q[1], q[2], q[3]
    return jnp.array([
        [1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)],
        [2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)],
        [2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)],
    ])


def random_rotations(key: jax.Array, n: int) -> jnp.ndarray:
    return jax.vmap(random_rotation)(jax.random.split(key, n))


def lee(force_fn: Callable[[jnp.ndarray], jnp.ndarray],
        coords: jnp.ndarray, rot: jnp.ndarray) -> jnp.ndarray:
    """LEE for a force model. coords: (n_atoms, 3); rot: (3, 3).

    force_fn maps coordinates -> per-atom forces (n_atoms, 3). Other inputs
    (atom types etc.) should be closed over.
    """
    f_rot_in = force_fn(coords @ rot.T)      # f(R . G)
    rot_f = force_fn(coords) @ rot.T          # rho(R) f(G)
    return jnp.linalg.norm(f_rot_in - rot_f)


def lee_regularizer(force_fn: Callable[[jnp.ndarray], jnp.ndarray],
                    coords: jnp.ndarray, key: jax.Array,
                    n_rotations: int = 1) -> jnp.ndarray:
    """E_R[LEE] estimated with n_rotations samples; differentiable."""
    rots = random_rotations(key, n_rotations)
    errs = jax.vmap(lambda R: lee(force_fn, coords, R))(rots)
    return jnp.mean(errs)
