"""Robust attention normalization (paper §III-E).

Cosine attention: l2-normalize queries and keys, logits = tau * <q_hat, k_hat>
(+ optional invariant bias), softmax. Bounds logits in [-tau, tau] so low-bit
rounding of q/k cannot let one large magnitude dominate the softmax.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["l2_normalize", "cosine_attention_logits", "robust_attention_weights"]

_EPS = 1e-6


def l2_normalize(x: jnp.ndarray, axis: int = -1, eps: float = _EPS) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=axis, keepdims=True), eps)


def cosine_attention_logits(q: jnp.ndarray, k: jnp.ndarray, tau: float = 10.0,
                            bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (..., n_q, d), k: (..., n_k, d) -> logits (..., n_q, n_k)."""
    qh = l2_normalize(q)
    kh = l2_normalize(k)
    logits = tau * jnp.einsum("...qd,...kd->...qk", qh, kh)
    if bias is not None:
        logits = logits + bias
    return logits


def robust_attention_weights(q: jnp.ndarray, k: jnp.ndarray, tau: float = 10.0,
                             bias: Optional[jnp.ndarray] = None,
                             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logits = cosine_attention_logits(q, k, tau, bias)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e9)
    return jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True)) / jnp.sum(
        jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True)),
        axis=-1, keepdims=True)
