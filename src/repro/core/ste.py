"""Straight-through estimators, including the paper's Geometric STE.

Geometric STE (paper Eq. 8): for a unit direction u quantized to codeword q,
the backward pass projects the incoming gradient onto the tangent space of S^2
at u:  dL/du := (I - u u^T) dL/dq.  Radial components are structurally invalid
(MDDQ fixes ||u|| = 1) and act as noise under plain STE; projecting them out
keeps the first-order update on the manifold (Prop III.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["geometric_ste_direction", "identity_ste"]


@jax.custom_vjp
def identity_ste(u: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Plain STE: forward -> q, backward -> pass gradient straight to u."""
    return q


def _id_fwd(u, q):
    return q, None


def _id_bwd(_, g):
    return (g, None)


identity_ste.defvjp(_id_fwd, _id_bwd)


@jax.custom_vjp
def geometric_ste_direction(u: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Forward: quantized direction q. Backward: tangent-projected gradient.

    u: (..., 3) unit directions (pre-quantization)
    q: (..., 3) codebook directions (stop-gradient side)
    """
    return q


def _geo_fwd(u, q):
    return q, u


def _geo_bwd(u, g):
    # (I - u u^T) g  ==  g - u <u, g>
    radial = jnp.sum(u * g, axis=-1, keepdims=True)
    return (g - u * radial, None)


geometric_ste_direction.defvjp(_geo_fwd, _geo_bwd)
