"""Hand-rolled AdamW (no optax in this container) + cosine LR schedule.

Pure-pytree implementation; state is a dict of pytrees so it shards exactly
like the parameters (same PartitionSpecs) under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0  # global-norm clip; 0 disables

    def init(self, params) -> AdamWState:
        z = jax.tree.map(jnp.zeros_like, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                          nu=jax.tree.map(jnp.zeros_like, params))

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip > 0:
            gnorm = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: self.b2 * n + (1 - self.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, m, n):
            return p - lr * ((m / bc1) / (jnp.sqrt(n / bc2) + self.eps)
                             + self.weight_decay * p)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.0) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (base_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f
