"""Gradient compression with error feedback — the paper's bandwidth
multiplier applied to the interconnect.

Two layers:

1. `ef_compress` / `ErrorFeedbackState`: an optimizer-side transformation —
   each step, (grad + residual) is quantized to int8 per-leaf; the
   quantization error is carried to the next step (error feedback keeps the
   long-run update unbiased; Karimireddy et al. 2019). This models the
   numerics of a compressed all-reduce and is what the training loop uses.

2. `int8_psum`: a shard_map collective that actually moves int8 over the
   wire — quantize locally, psum int32 accumulators + f32 scales, dequantize
   — demonstrating the 4x all-reduce byte reduction end-to-end on a real
   mesh axis. The launcher enables it under `--grad-compression wire`.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import abs_max_scale, dequantize, quantize


class ErrorFeedbackState(NamedTuple):
    residual: Any


def ef_init(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(jax.tree.map(jnp.zeros_like, params))


def ef_compress(grads, state: ErrorFeedbackState, bits: int = 8
                ) -> Tuple[Any, ErrorFeedbackState]:
    """Quantize (grads + residual); carry the error. Returns dequantized
    grads (what a compressed all-reduce would deliver) + new state."""

    def leaf(g, r):
        tot = g + r
        scale = abs_max_scale(tot, bits)
        q = quantize(tot, scale, bits)
        deq = dequantize(q, scale)
        return deq, tot - deq

    flat = jax.tree.map(leaf, grads, state.residual)
    deq = jax.tree.map(lambda x: x[0], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda x: x[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, ErrorFeedbackState(res)


def int8_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-reduce with int8 payload (inside shard_map).

    Quantizes the local contribution, sums int8 payloads in int32 (exact),
    and shares the max scale. Wire bytes: N (int8) + epsilon, vs 4N fp32.
    """
    scale = abs_max_scale(x, 8)
    # share one scale so dequantization after the sum is linear & exact
    scale = jax.lax.pmax(scale, axis_name)
    q = quantize(x, scale, 8).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def int8_psum_tree(grads, axis_name: str):
    return jax.tree.map(lambda g: int8_psum(g, axis_name), grads)
