"""Synthetic token pipeline: deterministic, shardable, host-prefetched.

Stands in for a real corpus: a mixture of Zipf-distributed unigrams and
repeated n-gram motifs so a language model has real structure to learn
(loss decreases materially, unlike uniform noise). Each host draws only its
own shard (seeded by host id) — the multi-host pattern — and a bounded
prefetch queue decouples generation from step time (straggler mitigation at
the input layer).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.models.lm.config import LMConfig


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    return p / p.sum()


def synthetic_token_batches(cfg: LMConfig, batch: int, seq: int,
                            seed: int = 0, host_id: int = 0,
                            prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {'tokens' or 'embeds', 'labels'} batches forever."""
    rng = np.random.default_rng(seed * 1000003 + host_id)
    probs = _zipf_probs(cfg.vocab)
    motifs = [rng.integers(0, cfg.vocab, size=rng.integers(4, 12))
              for _ in range(32)]

    def make_batch():
        toks = rng.choice(cfg.vocab, size=(batch, seq + 1), p=probs)
        # splice in motifs: repeated structure = learnable signal
        for b in range(batch):
            pos = 0
            while pos < seq:
                if rng.random() < 0.5:
                    m = motifs[rng.integers(0, len(motifs))]
                    end = min(pos + len(m), seq + 1)
                    toks[b, pos:end] = m[:end - pos]
                    pos = end
                else:
                    pos += rng.integers(2, 8)
        batch_d = {"labels": toks[:, 1:].astype(np.int32)}
        if cfg.frontend == "token":
            batch_d["tokens"] = toks[:, :-1].astype(np.int32)
        else:
            # modality stub: embed tokens through a fixed random table
            table_rng = np.random.default_rng(42)
            table = table_rng.standard_normal((cfg.vocab, cfg.d_model)
                                              ).astype(np.float32) * 0.02
            batch_d["embeds"] = table[toks[:, :-1]]
        return batch_d

    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        while not stop.is_set():
            try:
                q.put(make_batch(), timeout=1.0)
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
