"""Synthetic molecular-dynamics dataset (rMD17 stand-in).

rMD17/azobenzene is not downloadable in this offline container, so we build an
azobenzene-like 24-atom molecule (C12 H10 N2) with a classical force field
(harmonic bonds + harmonic angles + Lennard-Jones non-bonded) and sample
configurations around equilibrium. Energies/forces labels come from the
classical potential; the *relative* quantization claims of the paper (naive
INT8 breaks symmetry/stability, GAQ preserves both) are what we validate.

Units: eV, Angstrom (so "meV" numbers are 1e-3 of these energies).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# species codes
C, N, H = 6, 7, 1
SPECIES_MAP = {1: 0, 6: 1, 7: 2}  # -> embedding rows


def azobenzene_topology():
    """Coordinates (24,3), species (24,), bonds [(i,j,r0,k)], angles [(i,j,k,th0,ka)].

    Atom order: ring A carbons 0-5, ring B carbons 6-11, N 12-13, H 14-23.
    """
    cc, ch, cn, nn = 1.39, 1.08, 1.43, 1.25
    coords = np.zeros((24, 3))
    # two hexagons in the xy-plane, bridged by N=N
    for r, (cx, sign) in enumerate([(-2.85, -1), (2.85, 1)]):
        for i in range(6):
            ang = np.pi / 3 * i + (np.pi / 6 if sign > 0 else -np.pi / 6)
            coords[6 * r + i] = [cx + cc * np.cos(ang), cc * np.sin(ang), 0.0]
    # N atoms between the rings
    coords[12] = [-0.95, 0.30, 0.0]
    coords[13] = [0.95, -0.30, 0.0]
    species = np.array([C] * 12 + [N] * 2 + [H] * 10)

    bonds: List[Tuple[int, int, float, float]] = []
    kb, kbh = 25.0, 28.0  # eV / A^2
    for r in range(2):
        for i in range(6):
            bonds.append((6 * r + i, 6 * r + (i + 1) % 6, cc, kb))
    # ring-N bonds: attach N12 to ring-A atom closest, N13 to ring-B
    ra = int(np.argmin(np.linalg.norm(coords[0:6] - coords[12], axis=1)))
    rb = int(np.argmin(np.linalg.norm(coords[6:12] - coords[13], axis=1))) + 6
    bonds.append((ra, 12, cn, kb))
    bonds.append((rb, 13, cn, kb))
    bonds.append((12, 13, nn, 35.0))
    # hydrogens on the remaining ring carbons
    h_idx = 14
    for r, ring in enumerate([range(0, 6), range(6, 12)]):
        center = coords[list(ring)].mean(0)
        for ci in ring:
            if ci in (ra, rb):
                continue
            direction = coords[ci] - center
            direction /= np.linalg.norm(direction)
            coords[h_idx] = coords[ci] + ch * direction
            bonds.append((ci, h_idx, ch, kbh))
            h_idx += 1
    assert h_idx == 24

    # angles: for every atom with >= 2 bonds, all bonded pairs
    adj = {i: [] for i in range(24)}
    for i, j, *_ in bonds:
        adj[i].append(j)
        adj[j].append(i)
    angles: List[Tuple[int, int, int, float, float]] = []
    for j in range(24):
        nb = adj[j]
        for a in range(len(nb)):
            for b in range(a + 1, len(nb)):
                i, k = nb[a], nb[b]
                v1 = coords[i] - coords[j]
                v2 = coords[k] - coords[j]
                th0 = float(np.arccos(np.clip(
                    v1 @ v2 / (np.linalg.norm(v1) * np.linalg.norm(v2)), -1, 1)))
                angles.append((i, j, k, th0, 3.0))
    return coords, species, bonds, angles


@dataclasses.dataclass(frozen=True)
class ClassicalFF:
    bond_idx: jnp.ndarray    # (B, 2) int
    bond_r0: jnp.ndarray     # (B,)
    bond_k: jnp.ndarray      # (B,)
    angle_idx: jnp.ndarray   # (A, 3) int
    angle_th0: jnp.ndarray   # (A,)
    angle_k: jnp.ndarray     # (A,)
    nb_pairs: jnp.ndarray    # (P, 2) non-bonded pairs
    lj_eps: float = 0.002
    lj_sigma: float = 2.4

    def energy(self, coords: jnp.ndarray) -> jnp.ndarray:
        ri = coords[self.bond_idx[:, 0]]
        rj = coords[self.bond_idx[:, 1]]
        d = jnp.linalg.norm(ri - rj, axis=-1)
        e_bond = jnp.sum(self.bond_k * (d - self.bond_r0) ** 2)

        a = coords[self.angle_idx[:, 0]] - coords[self.angle_idx[:, 1]]
        b = coords[self.angle_idx[:, 2]] - coords[self.angle_idx[:, 1]]
        cos = jnp.sum(a * b, -1) / (
            jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-9)
        th = jnp.arccos(jnp.clip(cos, -1 + 1e-7, 1 - 1e-7))
        e_angle = jnp.sum(self.angle_k * (th - self.angle_th0) ** 2)

        rij = coords[self.nb_pairs[:, 0]] - coords[self.nb_pairs[:, 1]]
        d2 = jnp.sum(rij ** 2, -1)
        s6 = (self.lj_sigma ** 2 / d2) ** 3
        e_lj = jnp.sum(4 * self.lj_eps * (s6 ** 2 - s6))
        return e_bond + e_angle + e_lj

    def forces(self, coords: jnp.ndarray) -> jnp.ndarray:
        return -jax.grad(self.energy)(coords)


def make_ff() -> Tuple[jnp.ndarray, jnp.ndarray, ClassicalFF]:
    coords, species, bonds, angles = azobenzene_topology()
    bonded = {(min(i, j), max(i, j)) for i, j, *_ in bonds}
    # 1-3 pairs (share an angle) are also excluded from LJ
    for i, j, k, *_ in angles:
        bonded.add((min(i, k), max(i, k)))
    nb = [(i, j) for i in range(24) for j in range(i + 1, 24)
          if (i, j) not in bonded]
    ff = ClassicalFF(
        bond_idx=jnp.array([(i, j) for i, j, *_ in bonds]),
        bond_r0=jnp.array([b[2] for b in bonds]),
        bond_k=jnp.array([b[3] for b in bonds]),
        angle_idx=jnp.array([(i, j, k) for i, j, k, *_ in angles]),
        angle_th0=jnp.array([a[3] for a in angles]),
        angle_k=jnp.array([a[4] for a in angles]),
        nb_pairs=jnp.array(nb),
    )
    sp = jnp.array([SPECIES_MAP[int(s)] for s in species])
    return jnp.asarray(coords), sp, ff


def sample_dataset(key: jax.Array, n_samples: int, sigma: float = 0.04,
                   standardize: bool = True, sigma_mixture: bool = True):
    """Perturb equilibrium geometry; label with the classical FF.

    Returns dict with coords (S, 24, 3), energy (S,), forces (S, 24, 3),
    species (24,), plus standardization constants e_shift / e_scale so MAEs
    can be reported in the original eV units
    (E_orig = E * e_scale + e_shift, F_orig = F * e_scale).
    """
    eq, species, ff = make_ff()
    k1, k2 = jax.random.split(key)
    noise = jax.random.normal(k1, (n_samples,) + eq.shape)
    if sigma_mixture:
        # broaden PES coverage so learned potentials stay stable in MD
        sigmas = jnp.array([0.02, 0.05, 0.08, 0.12])
        sig = sigmas[jax.random.randint(k2, (n_samples,), 0, len(sigmas))]
        noise = noise * sig[:, None, None]
    else:
        noise = noise * sigma
    coords = eq[None] + noise
    e = jax.vmap(ff.energy)(coords)
    f = jax.vmap(ff.forces)(coords)
    e_shift = jnp.mean(e) if standardize else jnp.zeros(())
    e_scale = jnp.maximum(jnp.std(e), 1e-6) if standardize else jnp.ones(())
    return {"coords": coords, "energy": (e - e_shift) / e_scale,
            "forces": f / e_scale, "species": species,
            "e_shift": e_shift, "e_scale": e_scale}


def sample_dataset_md(key: jax.Array, n_samples: int,
                      temperature_K: float = 300.0, dt_fs: float = 0.5,
                      stride: int = 40, standardize: bool = True):
    """Sample configurations from a classical-FF NVE trajectory at the given
    temperature — the rMD17 protocol (frames of an MD run), which covers the
    thermally accessible region so learned potentials stay stable in MD.
    """
    from repro.md.nve import _FS, init_state

    eq, species, ff = make_ff()
    masses = jnp.array([12.011] * 12 + [14.007] * 2 + [1.008] * 10)
    state = init_state(key, eq, masses, ff.forces, temperature_K)
    dt = dt_fs * _FS
    inv_m = (1.0 / masses)[:, None]

    def step(s, _):
        r, v, f = s
        v_half = v + 0.5 * dt * f * inv_m
        r_new = r + dt * v_half
        f_new = ff.forces(r_new)
        v_new = v_half + 0.5 * dt * f_new * inv_m
        return (r_new, v_new, f_new), None

    def frame(s, _):
        s, _ = jax.lax.scan(step, s, None, length=stride)
        return s, s[0]

    s0 = (state.coords, state.veloc, state.forces)
    _, coords = jax.lax.scan(frame, s0, None, length=n_samples)
    e = jax.vmap(ff.energy)(coords)
    f = jax.vmap(ff.forces)(coords)
    e_shift = jnp.mean(e) if standardize else jnp.zeros(())
    e_scale = jnp.maximum(jnp.std(e), 1e-6) if standardize else jnp.ones(())
    return {"coords": coords, "energy": (e - e_shift) / e_scale,
            "forces": f / e_scale, "species": species,
            "e_shift": e_shift, "e_scale": e_scale}
