"""Chunked linear-RNN scan (Mamba2 / SSD) + Mamba2 block.

The SSD recurrence  S_t = a_t * S_{t-1} + B_t (x'_t)^T ,  y_t = C_t . S_t
(per head; a_t scalar decay, S in R^{N x P}) is evaluated with the standard
chunked algorithm: intra-chunk attention-like einsums + an inter-chunk
lax.scan carrying the (H, N, P) state. Work is O(S * L) for chunk length L —
sub-quadratic, which is what qualifies zamba2/xlstm for the long_500k shape.

The same primitive implements mLSTM (xlstm.py): N=d_k, P=d_v(+1 for the
normalizer), decay = log sigmoid(forget gate), x' = input-gate * value.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, qlinear, rmsnorm


def chunked_linear_rnn(log_a: jnp.ndarray, B_in: jnp.ndarray,
                       C_out: jnp.ndarray, x: jnp.ndarray,
                       chunk: int, init_state: jnp.ndarray | None = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked scan for y_t = C_t . (sum_{s<=t} prod_{r in (s,t]} a_r B_s x_s^T).

    log_a: (Bt, S, H)      per-step log decay (<= 0 for stability)
    B_in : (Bt, S, G, N)   write keys (gate/dt pre-absorbed into x)
    C_out: (Bt, S, G, N)   read keys
    x    : (Bt, S, H, P)   values (pre-scaled by dt/input-gate)
    Heads are grouped: head h uses B/C group h // (H // G).
    Returns y (Bt, S, H, P) and final state (Bt, H, N, P).
    """
    Bt, S, H, P = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    Hg = H // G
    L = min(chunk, S)
    assert S % L == 0, f"S={S} % chunk {L} != 0"
    nc = S // L

    # reshape to (Bt, nc, L, ...) and split heads into (G, Hg)
    la = log_a.reshape(Bt, nc, L, G, Hg)
    xs = x.reshape(Bt, nc, L, G, Hg, P)
    Bi = B_in.reshape(Bt, nc, L, G, N)
    Co = C_out.reshape(Bt, nc, L, G, N)

    lcum = jnp.cumsum(la, axis=2)                       # inclusive cumsum
    if init_state is None:
        init_state = jnp.zeros((Bt, G, Hg, N, P), jnp.float32)

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]               # (L, L) t >= s

    def one_chunk(state, inputs):
        la_c, lc_c, x_c, b_c, c_c = inputs              # leading dim Bt
        # intra-chunk: scores[t, s] = exp(l_t - l_s) (C_t . B_s), s <= t
        cb = jnp.einsum("blgn,bmgn->bglm", c_c, b_c)    # (Bt, G, L, L)
        dec = lc_c[:, :, None] - lc_c[:, None, :]       # l_t - l_s: (Bt,L,L,G,Hg)
        dec = jnp.where(causal[None, :, :, None, None], dec, -1e30)
        w = jnp.exp(dec) * cb.transpose(0, 2, 3, 1)[..., None]   # (Bt,L,L,G,Hg)
        y_intra = jnp.einsum("blmgh,bmghp->blghp", w.astype(x_c.dtype), x_c)

        # inter-chunk: y_inter[t] = exp(l_t) C_t . S_prev
        read = jnp.exp(lc_c)[..., None] * c_c[:, :, :, None, :]  # (Bt,L,G,Hg,N)
        y_inter = jnp.einsum("blghn,bghnp->blghp", read.astype(x_c.dtype),
                             state.astype(x_c.dtype))

        # state update: S_new = exp(l_L) S_prev + sum_s exp(l_L - l_s) B_s x_s^T
        tail = lc_c[:, -1:, :, :] - lc_c                # l_L - l_s
        wsrc = jnp.exp(tail)[..., None] * x_c            # (Bt,L,G,Hg,P)
        contrib = jnp.einsum("blgn,blghp->bghnp", b_c, wsrc.astype(jnp.float32))
        decay_L = jnp.exp(lc_c[:, -1])[..., None, None]  # (Bt,G,Hg,1,1)
        state = decay_L * state + contrib
        return state, y_intra + y_inter

    # move chunk axis to the front for scan
    def tr(a):
        return jnp.moveaxis(a, 1, 0)

    state, ys = jax.lax.scan(one_chunk, init_state,
                             (tr(la), tr(lcum), tr(xs), tr(Bi), tr(Co)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, S, H, P)
    return y, state.reshape(Bt, H, N, P)


def linear_rnn_step(state, log_a, B_in, C_out, x):
    """Single decode step. state: (Bt,H,N,P); log_a: (Bt,H); B_in/C_out:
    (Bt,G,N); x: (Bt,H,P). Returns (y (Bt,H,P), new_state)."""
    Bt, H, N, P = state.shape
    G = B_in.shape[1]
    Hg = H // G
    s = state.reshape(Bt, G, Hg, N, P)
    a = jnp.exp(log_a).reshape(Bt, G, Hg)[..., None, None]
    contrib = jnp.einsum("bgn,bghp->bghnp", B_in,
                         x.reshape(Bt, G, Hg, P).astype(jnp.float32))
    s = a * s + contrib
    y = jnp.einsum("bgn,bghnp->bghp", C_out, s).astype(x.dtype)
    return y.reshape(Bt, H, P), s.reshape(Bt, H, N, P)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

_CONV_W = 4  # causal depthwise conv width


def init_mamba2(key, cfg, dtype=None):
    dtype = dtype or cfg.param_dtype
    d, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ks = jax.random.split(key, 8)
    # separate projections per segment (z, x, B, C, dt) so each weight has a
    # clean Megatron column split under tensor parallelism
    return {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[3], d, di, dtype),
        "w_B": dense_init(ks[4], d, G * N, dtype),
        "w_C": dense_init(ks[5], d, G * N, dtype),
        "w_dt": dense_init(ks[6], d, H, dtype),
        "conv_w": (jax.random.normal(ks[1], (_CONV_W, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),           # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _project(params, x, mode):
    z = qlinear(x, params["w_z"], mode)
    xv = qlinear(x, params["w_x"], mode)
    B_in = qlinear(x, params["w_B"], mode)
    C_out = qlinear(x, params["w_C"], mode)
    dt = qlinear(x, params["w_dt"], mode)
    return z, xv, B_in, C_out, dt


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (W, C) depthwise causal conv."""
    W = w.shape[0]
    w = w.astype(x.dtype)
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out + b.astype(x.dtype))


def mamba2_forward(params, x_res, cfg):
    """Training/prefill. x_res: (B, S, d) -> (B, S, d)."""
    B, S, d = x_res.shape
    H, N, G, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    mode = cfg.quant_mode
    z, xv, B_in, C_out, dt = _project(params, x_res, mode)
    xv = _causal_conv(xv, params["conv_w"], params["conv_b"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])                                     # (H,)
    log_a = dt * A[None, None, :]
    xh = xv.reshape(B, S, H, P)
    y, _ = chunked_linear_rnn(log_a,
                              B_in.reshape(B, S, G, N).astype(jnp.float32),
                              C_out.reshape(B, S, G, N).astype(jnp.float32),
                              xh * dt[..., None].astype(xh.dtype),
                              cfg.ssm_chunk)
    y = y + xh * params["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, cfg.d_inner) * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_w"])
    return qlinear(y, params["out_proj"], mode)


def init_mamba2_cache(cfg, batch: int, dtype):
    H, N, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, _CONV_W - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba2_step(params, x_res, cfg, cache):
    """Decode step. x_res: (B, 1, d) -> ((B, 1, d), cache)."""
    B = x_res.shape[0]
    H, N, G, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    mode = cfg.quant_mode
    z, xv, B_in, C_out, dt = _project(params, x_res[:, 0], mode)

    # causal conv over (cached last W-1 inputs, current)
    conv_in = jnp.concatenate([cache["conv"], xv[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"].astype(conv_in.dtype)
    xv = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_in, w)
                     + params["conv_b"].astype(conv_in.dtype))
    new_conv = conv_in[:, 1:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    log_a = dt * A[None, :]
    xh = xv.reshape(B, H, P)
    y, new_ssm = linear_rnn_step(cache["ssm"], log_a,
                                 B_in.reshape(B, G, N).astype(jnp.float32),
                                 C_out.reshape(B, G, N).astype(jnp.float32),
                                 xh * dt[..., None].astype(xh.dtype))
    y = y + xh * params["D"][None, :, None].astype(xh.dtype)
    y = y.reshape(B, cfg.d_inner) * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_w"])
    out = qlinear(y, params["out_proj"], mode)
    return out[:, None, :], {"conv": new_conv, "ssm": new_ssm}
