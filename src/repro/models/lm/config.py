"""LM architecture configuration."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: Optional[int] = None           # default d_model // n_heads
    mlp_kind: str = "swiglu"                 # swiglu | squared_relu | none
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # --- robust attention normalization (paper §III-E, LM analogue) ---
    qk_norm: bool = False                    # l2-normalize q/k per head
    attn_tau: float = 10.0                   # inverse temperature
    rope_theta: float = 500000.0
    # --- block pattern ---
    block_pattern: str = "transformer"       # transformer | zamba2 | xlstm
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 64
    ssm_heads: int = 0                       # default d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1                      # B/C groups (like GQA for SSM)
    zamba_mamba_per_attn: int = 2            # mamba blocks per shared attn
    # --- xLSTM ---
    xlstm_mlstm_per_slstm: int = 7           # the 7:1 ratio
    xlstm_proj_factor: int = 2
    # --- modality frontend ---
    frontend: str = "token"                  # token | audio_frames | image_patches
    # --- quantized execution ---
    quant_mode: str = "none"                 # none | qat_w4a8 | serve_w8a8 | serve_w4a8
    kv_quant: bool = False                   # quantized KV cache at serve time
    kv_bits: int = 8                         # 8 (int8) or 4 (packed int4)
    # replicate each KV head r times at decode so kv_heads*r divides the TP
    # width: attention becomes chip-local (no partial-softmax collectives) at
    # the cost of r x cache bytes (cheap once the cache is int4)
    kv_replicate: int = 1
    # --- numerics / scale ---
    dtype: Any = jnp.bfloat16                # activation dtype
    param_dtype: Any = jnp.float32
    remat: bool = False                      # activation checkpoint per block
    # activation sharding constraints at block boundaries (perf iteration):
    #   none  - let GSPMD propagate freely (baseline)
    #   dp    - pin batch to the data axes between blocks
    #   dp_sp - additionally shard the sequence dim over "model" between
    #           blocks (Megatron-style sequence parallelism)
    act_sharding: str = "none"
    # rmsnorm statistics dtype: f32 (safe default) or bf16. XLA pairs the
    # f32 upcast with the TP partial-sum all-reduce, doubling its bytes;
    # bf16 norms keep the dominant collective in bf16 (perf iteration).
    norm_f32: bool = True
    attn_chunk_q: int = 1024                 # chunked-attention query block
    attn_chunk_kv: int = 1024
    ssm_chunk: int = 256
    # long-context support marker (sub-quadratic path exists)
    sub_quadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6 N D)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * (nh + 2 * nkv) + nh * hd * d
        mlp = {"swiglu": 3 * d * ff, "squared_relu": 2 * d * ff,
               "none": 0}[self.mlp_kind]
        if self.moe:
            mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        if self.block_pattern == "transformer":
            per_layer = attn + mlp
            body = self.n_layers * per_layer
        elif self.block_pattern == "zamba2":
            di, N, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            G = self.ssm_groups
            mamba = (d * (2 * di + 2 * G * N + H) + di * d + 4 * di + 2 * H)
            n_groups = self.n_layers // self.zamba_mamba_per_attn
            body = self.n_layers * mamba + (attn + mlp)  # shared attn counted once
        elif self.block_pattern == "xlstm":
            dk = d // 2
            m_per = d * 2 * d * self.xlstm_proj_factor // 2  # rough
            di = d * self.xlstm_proj_factor
            mlstm = d * di * 2 + di * (3 * (di // 2)) + di * d
            slstm = d * 4 * d * 2  # 4 gates, input+recurrent
            n_s = self.n_layers // (self.xlstm_mlstm_per_slstm + 1)
            body = (self.n_layers - n_s) * mlstm + n_s * slstm
        else:
            raise ValueError(self.block_pattern)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return int(body + emb)

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        moe_p = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff
        return int(total - moe_p + active)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) cell."""
    shape_name: str          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode

SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)
