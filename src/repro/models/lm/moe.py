"""Mixture-of-Experts layer: top-k router + grouped capacity-based dispatch.

GShard/Switch-style: tokens are processed in groups of `moe_group` tokens;
within a group each token is routed to its top-k experts subject to a
per-expert capacity C = ceil(Tg * k / E * capacity_factor); overflow drops
(contributes zero, residual passes through). Expert weights are stacked on a
leading E axis, which shards over the "model" mesh axis (expert
parallelism); the dispatch/combine einsums lower to all-to-alls under GSPMD.

Dispatch-einsum overhead per token is E*C*d = Tg*k*cf*d FLOPs, i.e.
(Tg*cf/(3*ff)) of the expert FLOPs — ~15-30% at Tg=512 for the assigned MoE
configs. (Hillclimb note: a sort-based ragged dispatch removes this, at the
cost of data-dependent layouts.)

The router runs in fp32 regardless of quant_mode — it is the precision-
critical "direction" analogue of the paper's branch separation (a tiny
selector whose rounding errors reorder hard assignments, exactly like the
attention-ordering sensitivity the paper fixes in §III-E).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import unpack_int4
from .layers import dense_init

MOE_GROUP = 512  # tokens per routing group


def _expert_w(w, dt):
    """Expert weights may be serve-quantized (int8/int4-packed, scale)."""
    if isinstance(w, tuple):
        wq, s = w
        if wq.dtype == jnp.uint8:
            wq = unpack_int4(wq)
        return wq.astype(dt) * s.astype(dt)
    return w.astype(dt)


def init_moe(key, cfg, dtype=None):
    dtype = dtype or cfg.param_dtype
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, d, ff)) / jnp.sqrt(d)).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, ff)) / jnp.sqrt(d)).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, ff, d)) / jnp.sqrt(ff)).astype(dtype),
    }


def _route_group(params, xg: jnp.ndarray, cfg, C: int):
    """xg: (ng, Tg, d) -> dispatch/combine (ng, Tg, E, C), aux scalar."""
    ng, Tg, d = xg.shape
    E, k = cfg.n_experts, cfg.top_k

    logits = xg.astype(jnp.float32) @ params["router"]          # (ng, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (ng, Tg, k)

    # position of each (token, choice) within its expert's capacity buffer:
    # exclusive cumsum over the flattened (Tg * k) choice sequence per group
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # (ng,Tg,k,E)
    flat = onehot.reshape(ng, Tg * k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat)                     # exclusive
    pos = (pos * flat).sum(-1).reshape(ng, Tg, k)
    keep = pos < C

    oh_e = onehot.astype(jnp.float32)                            # (ng,Tg,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=jnp.float32)[..., :C]            # (ng,Tg,k,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c,
                         gate_vals * keep.astype(jnp.float32))

    # Switch load-balance loss: E * sum_e fraction_e * router_prob_e
    f = dispatch.sum((1, 3)) / jnp.maximum(dispatch.sum((1, 2, 3)),
                                           1.0)[..., None]      # (ng, E)
    p = probs.mean(1)
    aux = E * jnp.mean(jnp.sum(f * p, axis=-1))
    return dispatch, combine, aux


def moe_forward(params, x, cfg):
    """x: (B, S, d) -> ((B, S, d), aux_loss)."""
    B, S, d = x.shape
    T = B * S
    Tg = min(MOE_GROUP, T)
    assert T % Tg == 0, f"tokens {T} % group {Tg} != 0"
    ng = T // Tg
    C = max(int(Tg * cfg.top_k / cfg.n_experts * cfg.capacity_factor), 1)

    xg = x.reshape(ng, Tg, d)
    dispatch, combine, aux = _route_group(params, xg, cfg, C)

    dt = x.dtype
    xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch.astype(dt))   # (ng,E,C,d)
    g = jnp.einsum("gecd,edf->gecf", xe, _expert_w(params["wg"], dt))
    u = jnp.einsum("gecd,edf->gecf", xe, _expert_w(params["wu"], dt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, _expert_w(params["wd"], dt))
    y = jnp.einsum("gecd,gtec->gtd", ye, combine.astype(dt))
    return y.reshape(B, S, d), aux
