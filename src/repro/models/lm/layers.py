"""Shared LM building blocks: norms, rotary, MLPs, quantized linear."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import fake_quant_ste, unpack_int4


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            f32_stats: bool = True) -> jnp.ndarray:
    dt = x.dtype
    if f32_stats:
        x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(x.dtype)).astype(dt)


def dense_init(key, fan_in, fan_out, dtype=jnp.float32):
    return (jax.random.normal(key, (fan_in, fan_out)) / jnp.sqrt(fan_in)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Quantization-aware linear: the branch-separated policy applied to LMs.
# Weights W4/W8 per-output-channel, activations A8 per-tensor; "none" mode is
# a plain matmul. serve_* modes run the dequant math explicitly so the dry-run
# cost analysis sees int8/int4 weight bytes (on TPU the Pallas kernel fuses
# this; the jnp path is the portable/AOT-analyzable formulation).
# ---------------------------------------------------------------------------

def qlinear(x: jnp.ndarray, w, mode: str = "none",
            bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x: (..., K); w: (K, N) fp or (w_q, w_scale) when pre-quantized."""
    if mode == "none":
        y = x @ w.astype(x.dtype)
    elif mode == "qat_w4a8":
        wq = fake_quant_ste(w, 4, channel_axis=w.ndim - 1)
        xq = fake_quant_ste(x, 8)
        y = xq @ wq.astype(x.dtype)
    elif mode in ("serve_w8a8", "serve_w4a8"):
        w_q, w_scale = w
        if mode == "serve_w4a8" and w_q.dtype == jnp.uint8:
            w_q = unpack_int4(w_q)   # fused in the Pallas kernel on TPU
        # int8/int4 tensors stream from HBM; dequant happens next to compute
        y = (x @ w_q.astype(x.dtype)) * w_scale.astype(x.dtype)
    else:
        raise ValueError(f"unknown quant mode {mode!r}")
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def mlp_swiglu(params, x, mode="none"):
    g = qlinear(x, params["wg"], mode)
    u = qlinear(x, params["wu"], mode)
    return qlinear(jax.nn.silu(g) * u, params["wd"], mode)


def mlp_squared_relu(params, x, mode="none"):
    h = jax.nn.relu(qlinear(x, params["wi"], mode))
    return qlinear(h * h, params["wd"], mode)


def init_mlp(key, cfg, d_ff=None, dtype=None):
    dtype = dtype or cfg.param_dtype
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        return {"wg": dense_init(ks[0], d, ff, dtype),
                "wu": dense_init(ks[1], d, ff, dtype),
                "wd": dense_init(ks[2], ff, d, dtype)}
    if cfg.mlp_kind == "squared_relu":
        return {"wi": dense_init(ks[0], d, ff, dtype),
                "wd": dense_init(ks[1], ff, d, dtype)}
    raise ValueError(cfg.mlp_kind)


def apply_mlp(params, x, cfg, mode=None):
    mode = cfg.quant_mode if mode is None else mode
    if cfg.mlp_kind == "swiglu":
        return mlp_swiglu(params, x, mode)
    if cfg.mlp_kind == "squared_relu":
        return mlp_squared_relu(params, x, mode)
    raise ValueError(cfg.mlp_kind)


# --- rotary ------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]                    # (B, S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)
