"""xLSTM blocks: mLSTM (parallel, matrix memory) + sLSTM (sequential).

mLSTM is a linear RNN with matrix state C_t = f_t C_{t-1} + i_t k_t v_t^T and
normalizer n_t = f_t n_{t-1} + i_t k_t; y_t = (C_t q_t) / max(|n_t . q_t|, 1).
We reuse the chunked SSD scan from ssm.py with N=d_k, P=d_v+1 (the extra
column carries the normalizer: v_aug = [v, 1]).

sLSTM has true recurrence (h feeds the gates) and cannot be parallelized
over time; it runs as a lax.scan over steps with exponential-gating
stabilization (m-state). The published 7:1 mLSTM:sLSTM ratio keeps this
sequential part a small fraction of the depth.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, qlinear, rmsnorm
from .ssm import chunked_linear_rnn, linear_rnn_step


def _heads(cfg):
    di = cfg.d_model * cfg.xlstm_proj_factor
    H = cfg.n_heads
    dk = di // H // 2            # query/key dim per head
    dv = di // H                 # value dim per head
    return di, H, dk, dv


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype=None):
    dtype = dtype or cfg.param_dtype
    d = cfg.d_model
    di, H, dk, dv = _heads(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_gate": dense_init(ks[0], d, di, dtype),        # z gate
        "w_up": dense_init(ks[6], d, di, dtype),          # x path
        "wq": dense_init(ks[1], di, H * dk, dtype),
        "wk": dense_init(ks[2], di, H * dk, dtype),
        "wv": dense_init(ks[3], di, H * dv, dtype),
        "wif": dense_init(ks[4], di, 2 * H, dtype),       # input+forget gates
        "norm_w": jnp.ones((di,), dtype),
        "down": dense_init(ks[5], di, d, dtype),
    }


def _mlstm_qkv(params, xi, cfg, B, S):
    di, H, dk, dv = _heads(cfg)
    mode = cfg.quant_mode
    q = qlinear(xi, params["wq"], mode).reshape(B, S, H, dk) * dk ** -0.5
    k = qlinear(xi, params["wk"], mode).reshape(B, S, H, dk) * dk ** -0.5
    v = qlinear(xi, params["wv"], mode).reshape(B, S, H, dv)
    gates = qlinear(xi, params["wif"], mode).reshape(B, S, H, 2).astype(jnp.float32)
    i_gate = jnp.exp(-jax.nn.softplus(-gates[..., 0]))     # sigmoid, stable
    log_f = -jax.nn.softplus(-gates[..., 1])               # log sigmoid
    return q, k, v, i_gate, log_f


def mlstm_forward(params, x_res, cfg):
    """(B, S, d) -> (B, S, d)."""
    B, S, d = x_res.shape
    di, H, dk, dv = _heads(cfg)
    mode = cfg.quant_mode
    z = qlinear(x_res, params["w_gate"], mode)
    xi = qlinear(x_res, params["w_up"], mode)
    q, k, v, i_gate, log_f = _mlstm_qkv(params, xi, cfg, B, S)

    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], -1)  # (B,S,H,dv+1)
    # per-head keys: groups == heads (G=H) in the generic scan
    y, _ = chunked_linear_rnn(log_f,
                              (k * i_gate[..., None]).astype(jnp.float32),
                              q.astype(jnp.float32),
                              v_aug, cfg.ssm_chunk)
    num, den = y[..., :dv], y[..., dv:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, S, di) * jax.nn.silu(z)
    h = rmsnorm(h, params["norm_w"])
    return qlinear(h, params["down"], mode)


def init_mlstm_cache(cfg, batch: int, dtype):
    """Matrix memory (B, H, dk, dv) + separate normalizer (B, H, dk).

    The normalizer is NOT folded into the value dim (no dv+1 augmentation)
    at decode time: keeping dv a clean power-of-two lets the state shard
    over "model" on dv, aligned with the column-parallel wv/down weights, so
    the per-step read/write are collective-free (EXPERIMENTS.md §Perf)."""
    di, H, dk, dv = _heads(cfg)
    return {"state": jnp.zeros((batch, H, dk, dv), jnp.float32),
            "norm": jnp.zeros((batch, H, dk), jnp.float32)}


def mlstm_step(params, x_res, cfg, cache):
    B = x_res.shape[0]
    di, H, dk, dv = _heads(cfg)
    mode = cfg.quant_mode
    z = qlinear(x_res[:, 0], params["w_gate"], mode)
    xi = qlinear(x_res[:, 0], params["w_up"], mode)
    q, k, v, i_gate, log_f = _mlstm_qkv(params, xi[:, None], cfg, B, 1)
    ki = (k * i_gate[..., None])[:, 0].astype(jnp.float32).reshape(B, H, dk)
    qf = q[:, 0].astype(jnp.float32).reshape(B, H, dk)
    num, state = linear_rnn_step(cache["state"], log_f[:, 0], ki, qf, v[:, 0])
    f = jnp.exp(log_f[:, 0])[..., None]                      # (B, H, 1)
    norm = f * cache["norm"] + ki                            # (B, H, dk)
    den = jnp.sum(norm * qf, axis=-1, keepdims=True)         # (B, H, 1)
    h = (num.astype(jnp.float32)
         / jnp.maximum(jnp.abs(den), 1.0)).astype(x_res.dtype)
    h = h.reshape(B, di) * jax.nn.silu(z)
    h = rmsnorm(h, params["norm_w"])
    return qlinear(h, params["down"], mode)[:, None], \
        {"state": state, "norm": norm}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype=None):
    dtype = dtype or cfg.param_dtype
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        # 4 gates (i, f, z, o) from input and block-diagonal recurrence
        "w_in": dense_init(ks[0], d, 4 * d, dtype),
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh)) / jnp.sqrt(dh)
              ).astype(dtype),
        "b": jnp.zeros((4 * d,), dtype),
        "norm_w": jnp.ones((d,), dtype),
        "down": dense_init(ks[2], d, d, dtype),
    }


def _slstm_cell(params, cfg, x_t, state):
    """x_t: (B, 4d) pre-projected input contribution."""
    h, c, n, m = state
    B = h.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    rec = jnp.einsum("bhd,hde->bhe", h.reshape(B, H, dh),
                     params["r"].astype(h.dtype)).reshape(B, 4 * cfg.d_model)
    gates = (x_t + rec + params["b"].astype(x_t.dtype)).astype(jnp.float32)
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    log_f = -jax.nn.softplus(-gf)                      # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, gi)                 # stabilizer
    i = jnp.exp(gi - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * jnp.tanh(gz)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
    return h_new.astype(x_t.dtype), c_new, n_new, m_new


def slstm_forward(params, x_res, cfg):
    """(B, S, d) -> (B, S, d). Sequential lax.scan over time."""
    B, S, d = x_res.shape
    mode = cfg.quant_mode
    x_in = qlinear(x_res, params["w_in"], mode)        # (B, S, 4d)
    state0 = (jnp.zeros((B, d), x_res.dtype), jnp.zeros((B, d), jnp.float32),
              jnp.zeros((B, d), jnp.float32),
              jnp.full((B, d), -1e30, jnp.float32))

    def step(state, x_t):
        state = _slstm_cell(params, cfg, x_t, state)
        return state, state[0]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(x_in, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)
    h = rmsnorm(h, params["norm_w"])
    return qlinear(h, params["down"], mode)


def init_slstm_cache(cfg, batch: int, dtype):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_step(params, x_res, cfg, cache):
    mode = cfg.quant_mode
    x_in = qlinear(x_res[:, 0], params["w_in"], mode)
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_cell(params, cfg, x_in, state)
    out = qlinear(rmsnorm(h, params["norm_w"]), params["down"], mode)
    return out[:, None], {"h": h, "c": c, "n": n, "m": m}
