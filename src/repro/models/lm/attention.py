"""GQA attention: chunked-causal training path + KV-cache decode path.

Training/prefill use a q-chunked blockwise attention (lax.scan over query
blocks, full-row logits per block) so the S x S score matrix is never
materialized — the pure-JAX analogue of flash attention, required for the
32k prefill shapes. Decode attends one new token against the full cache,
optionally int8-quantized (the paper's memory-wall fix applied to the KV
cache; on TPU the Pallas kernel in repro/kernels/attention_int8kv.py fuses
dequant, this jnp path is the portable formulation with identical math).

Robust attention normalization (paper §III-E): when cfg.qk_norm, q and k are
l2-normalized per head and logits scaled by a learnable tau instead of
1/sqrt(d); bounds logits in [-tau, tau] so A8 rounding cannot reorder the
softmax.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention_norm import l2_normalize
from .layers import apply_rope, dense_init, qlinear


def init_attention(key, cfg, dtype=None):
    dtype = dtype or cfg.param_dtype
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d, nh * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nh * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["tau"] = jnp.asarray(cfg.attn_tau, jnp.float32)
    return p


def _project_qkv(params, x, cfg, positions):
    B, S, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    mode = cfg.quant_mode
    q = qlinear(x, params["wq"], mode, params.get("bq")).reshape(B, S, nh, hd)
    k = qlinear(x, params["wk"], mode, params.get("bk")).reshape(B, S, nkv, hd)
    v = qlinear(x, params["wv"], mode, params.get("bv")).reshape(B, S, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.qk_norm:
        q = l2_normalize(q) * params["tau"].astype(x.dtype)
        k = l2_normalize(k)
        scale = 1.0
    else:
        scale = hd ** -0.5
    return q, k, v, scale


def causal_attention(params, x, cfg, positions=None):
    """Full training/prefill attention. x: (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v, scale = _project_qkv(params, x, cfg, positions)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = nh // nkv
    q = q.reshape(B, S, nkv, g, hd)

    bq = min(cfg.attn_chunk_q, S)
    n_chunks = S // bq
    assert S % bq == 0, f"S={S} % chunk {bq} != 0"

    kT = jnp.moveaxis(k, 1, 3)          # (B, nkv, hd, S) -> used via einsum
    row_ids = jnp.arange(S)

    def chunk(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)  # (B,bq,kv,g,hd)
        # logits over the *full* row: (B, nkv, g, bq, S)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qi, k) * scale
        q_pos = i * bq + jnp.arange(bq)
        mask = row_ids[None, :] <= q_pos[:, None]                # (bq, S)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        oi = jnp.einsum("bkgqs,bskd->bqkgd", w, v)               # (B,bq,kv,g,hd)
        return carry, oi

    _, outs = jax.lax.scan(chunk, None, jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, nh * hd)        # re-stitch
    return qlinear(out, params["wo"], cfg.quant_mode)


# --- decode -------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, seq: int, dtype):
    nkv, hd = cfg.n_kv_heads * cfg.kv_replicate, cfg.hd
    if cfg.kv_quant:
        w = hd if cfg.kv_bits == 8 else hd // 2   # int4: two nibbles/byte
        qdt = jnp.int8 if cfg.kv_bits == 8 else jnp.uint8
        return {
            "k_q": jnp.zeros((batch, nkv, seq, w), qdt),
            "v_q": jnp.zeros((batch, nkv, seq, w), qdt),
            "k_s": jnp.zeros((batch, nkv, seq), jnp.float32),
            "v_s": jnp.zeros((batch, nkv, seq), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, nkv, seq, hd), dtype),
        "v": jnp.zeros((batch, nkv, seq, hd), dtype),
    }


def decode_attention(params, x, cfg, cache, cur_index):
    """One decode step. x: (B, 1, d); cache holds seq_len past KV.

    Returns (out (B, 1, d), new_cache). The new token's K/V are written at
    cur_index (same position for every batch row; standard static-shape
    serving layout).
    """
    B = x.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = jnp.full((B, 1), cur_index)
    q, k_new, v_new, scale = _project_qkv(params, x, cfg, positions)
    k_new = k_new[:, 0]                              # (B, kv, hd)
    v_new = v_new[:, 0]
    if cfg.kv_replicate > 1:
        # contiguous repeat keeps q-group -> kv-head mapping consistent
        k_new = jnp.repeat(k_new, cfg.kv_replicate, axis=1)
        v_new = jnp.repeat(v_new, cfg.kv_replicate, axis=1)
        nkv = nkv * cfg.kv_replicate
    q = q[:, 0].reshape(B, nkv, nh // nkv, hd)      # (B, kv_eff, g, hd)

    if cfg.kv_quant:
        # quantize the incoming token, store int8/int4, attend over the
        # quantized cache (fused dequant in the Pallas decode kernel on TPU)
        from repro.core.quantizers import pack_int4, unpack_int4
        qmax_v = 127.0 if cfg.kv_bits == 8 else 7.0
        k_s = (jnp.maximum(jnp.max(jnp.abs(k_new), -1), 1e-8) / qmax_v
               ).astype(jnp.float32)
        v_s = (jnp.maximum(jnp.max(jnp.abs(v_new), -1), 1e-8) / qmax_v
               ).astype(jnp.float32)
        k_qt = jnp.clip(jnp.round(k_new / k_s[..., None]), -qmax_v, qmax_v
                        ).astype(jnp.int8)
        v_qt = jnp.clip(jnp.round(v_new / v_s[..., None]), -qmax_v, qmax_v
                        ).astype(jnp.int8)
        if cfg.kv_bits == 4:
            k_qt, v_qt = pack_int4(k_qt), pack_int4(v_qt)
        cache = {
            "k_q": jax.lax.dynamic_update_index_in_dim(cache["k_q"], k_qt, cur_index, 2),
            "v_q": jax.lax.dynamic_update_index_in_dim(cache["v_q"], v_qt, cur_index, 2),
            "k_s": jax.lax.dynamic_update_index_in_dim(cache["k_s"], k_s, cur_index, 2),
            "v_s": jax.lax.dynamic_update_index_in_dim(cache["v_s"], v_s, cur_index, 2),
        }
        kq = cache["k_q"] if cfg.kv_bits == 8 else unpack_int4(cache["k_q"])
        vq = cache["v_q"] if cfg.kv_bits == 8 else unpack_int4(cache["v_q"])
        k = kq.astype(x.dtype) * cache["k_s"][..., None].astype(x.dtype)
        v = vq.astype(x.dtype) * cache["v_s"][..., None].astype(x.dtype)
    else:
        cache = {
            "k": jax.lax.dynamic_update_index_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), cur_index, 2),
            "v": jax.lax.dynamic_update_index_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), cur_index, 2),
        }
        k, v = cache["k"], cache["v"]

    seq = k.shape[2]
    logits = jnp.einsum("bkgd,bksd->bkgs", q, k) * scale     # (B,kv,g,S)
    valid = jnp.arange(seq)[None, None, None, :] <= cur_index
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v).reshape(B, 1, nh * hd)
    return qlinear(out, params["wo"], cfg.quant_mode), cache
