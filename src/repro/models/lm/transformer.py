"""LM assembly: block patterns, scan-over-layers, train & decode steps.

Layer stacking uses lax.scan over parameter trees whose leaves carry a
leading `depth` axis (one entry per scan group). This keeps the HLO O(1) in
depth — essential both for MXU utilization analysis and for compiling the
80-layer configs on the CPU host that runs the multi-pod dry-run.

Block patterns
  transformer : n_layers x [attn + mlp/moe]                (scan over layers)
  zamba2      : scan groups of [zamba_mamba_per_attn x mamba2 + shared attn
                + shared mlp] — the transformer block weights are SHARED
                (closed over, not scanned), matching Zamba2's design.
  xlstm       : scan groups of [7 x mLSTM + 1 x sLSTM].
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .config import LMConfig
from .layers import apply_mlp, dense_init, init_mlp, qlinear
from .layers import rmsnorm as _rmsnorm_impl

Params = Dict[str, Any]


def _make_rmsnorm(cfg: LMConfig):
    def rn(x, w):
        return _rmsnorm_impl(x, w, f32_stats=cfg.norm_f32)
    return rn


def _constrain_acts(x: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    """Activation sharding constraint at block boundaries (cfg.act_sharding).

    Reads the ambient physical mesh; no-op outside a mesh context or when
    dims don't divide (e.g. batch=1 long-context decode)."""
    if cfg.act_sharding == "none":
        return x
    from jax._src import mesh as mesh_lib
    from jax.sharding import PartitionSpec as P
    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty:
        return x
    axes = dict(zip(m.axis_names, m.devices.shape))
    dp = tuple(a for a in m.axis_names if a != "model")
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    spec = [None] * x.ndim
    if x.shape[0] % dp_size == 0 and dp_size > 1:
        spec[0] = dp if len(dp) > 1 else dp[0]
    if (cfg.act_sharding == "dp_sp" and x.ndim >= 3
            and x.shape[1] % axes.get("model", 1) == 0 and x.shape[1] > 1):
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def n_groups(cfg: LMConfig) -> int:
    if cfg.block_pattern == "transformer":
        return cfg.n_layers
    if cfg.block_pattern == "zamba2":
        return cfg.n_layers // cfg.zamba_mamba_per_attn
    if cfg.block_pattern == "xlstm":
        return cfg.n_layers // (cfg.xlstm_mlstm_per_slstm + 1)
    raise ValueError(cfg.block_pattern)


def init_lm(key: jax.Array, cfg: LMConfig) -> Params:
    keys = iter(jax.random.split(key, 16 + 4 * cfg.n_layers))
    dt = cfg.param_dtype
    p: Params = {
        "embed": (jax.random.normal(next(keys), (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(next(keys), cfg.d_model, cfg.vocab, dt)

    G = n_groups(cfg)
    if cfg.block_pattern == "transformer":
        def one(k):
            k1, k2 = jax.random.split(k)
            blk = {"ln1": jnp.ones((cfg.d_model,), dt),
                   "ln2": jnp.ones((cfg.d_model,), dt),
                   "attn": attn.init_attention(k1, cfg)}
            if cfg.moe:
                blk["moe"] = moe_lib.init_moe(k2, cfg)
            elif cfg.mlp_kind != "none":
                blk["mlp"] = init_mlp(k2, cfg)
            return blk
        p["blocks"] = _stack([one(next(keys)) for _ in range(G)])
    elif cfg.block_pattern == "zamba2":
        def one(k):
            ks = jax.random.split(k, cfg.zamba_mamba_per_attn)
            return {"mamba": _stack([{"ln": jnp.ones((cfg.d_model,), dt),
                                      **{"m": ssm_lib.init_mamba2(kk, cfg)}}
                                     for kk in ks])}
        p["blocks"] = _stack([one(next(keys)) for _ in range(G)])
        # ONE shared transformer block reused at every group boundary
        p["shared"] = {"ln1": jnp.ones((cfg.d_model,), dt),
                       "ln2": jnp.ones((cfg.d_model,), dt),
                       "attn": attn.init_attention(next(keys), cfg),
                       "mlp": init_mlp(next(keys), cfg)}
    elif cfg.block_pattern == "xlstm":
        M = cfg.xlstm_mlstm_per_slstm
        def one(k):
            ks = jax.random.split(k, M + 1)
            return {
                "mlstm": _stack([{"ln": jnp.ones((cfg.d_model,), dt),
                                  "b": xlstm_lib.init_mlstm(kk, cfg)}
                                 for kk in ks[:M]]),
                "slstm": {"ln": jnp.ones((cfg.d_model,), dt),
                          "b": xlstm_lib.init_slstm(ks[M], cfg)},
            }
        p["blocks"] = _stack([one(next(keys)) for _ in range(G)])
    else:
        raise ValueError(cfg.block_pattern)
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _group_forward(cfg: LMConfig, shared: Optional[Params]):
    """Returns f(carry_x, group_params) -> (x, aux) for one scan group."""
    rmsnorm = _make_rmsnorm(cfg)

    def transformer_group(x, g):
        h = attn.causal_attention(g["attn"], rmsnorm(x, g["ln1"]), cfg)
        x = x + h
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe:
            h, aux = moe_lib.moe_forward(g["moe"], rmsnorm(x, g["ln2"]), cfg)
            x = x + h
        elif cfg.mlp_kind != "none":
            x = x + apply_mlp(g["mlp"], rmsnorm(x, g["ln2"]), cfg)
        return x, aux

    def zamba_group(x, g):
        def mamba_one(xx, mg):
            return xx + ssm_lib.mamba2_forward(
                mg["m"], rmsnorm(xx, mg["ln"]), cfg), None
        x, _ = jax.lax.scan(mamba_one, x, g["mamba"])
        s = shared
        x = x + attn.causal_attention(s["attn"], rmsnorm(x, s["ln1"]), cfg)
        x = x + apply_mlp(s["mlp"], rmsnorm(x, s["ln2"]), cfg)
        return x, jnp.zeros((), jnp.float32)

    def xlstm_group(x, g):
        def mlstm_one(xx, mg):
            return xx + xlstm_lib.mlstm_forward(
                mg["b"], rmsnorm(xx, mg["ln"]), cfg), None
        x, _ = jax.lax.scan(mlstm_one, x, g["mlstm"])
        sg = g["slstm"]
        x = x + xlstm_lib.slstm_forward(sg["b"], rmsnorm(x, sg["ln"]), cfg)
        return x, jnp.zeros((), jnp.float32)

    return {"transformer": transformer_group, "zamba2": zamba_group,
            "xlstm": xlstm_group}[cfg.block_pattern]


def forward(params: Params, cfg: LMConfig, tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V), moe_aux)."""
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
    else:
        x = params["embed"][tokens].astype(cfg.dtype)

    group_fn = _group_forward(cfg, params.get("shared"))
    if cfg.remat:
        group_fn = jax.checkpoint(group_fn)

    def scan_body(carry, g):
        x, aux = carry
        x = _constrain_acts(x, cfg)
        x, a = group_fn(x, g)
        return (x, aux + a), None

    x = _constrain_acts(x, cfg)
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = _make_rmsnorm(cfg)(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(cfg.dtype)).astype(jnp.float32)
    return logits, aux / n_groups(cfg)


def lm_loss(params: Params, cfg: LMConfig, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    """Next-token cross entropy (+ MoE aux)."""
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"))
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, seq: int) -> Params:
    dt = cfg.dtype
    G = n_groups(cfg)

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    if cfg.block_pattern == "transformer":
        return {"blocks": rep(attn.init_kv_cache(cfg, batch, seq, dt), G)}
    if cfg.block_pattern == "zamba2":
        per_group = {
            "mamba": rep(ssm_lib.init_mamba2_cache(cfg, batch, dt),
                         cfg.zamba_mamba_per_attn),
            "attn": attn.init_kv_cache(cfg, batch, seq, dt),
        }
        return {"blocks": rep(per_group, G)}
    if cfg.block_pattern == "xlstm":
        per_group = {
            "mlstm": rep(xlstm_lib.init_mlstm_cache(cfg, batch, dt),
                         cfg.xlstm_mlstm_per_slstm),
            "slstm": xlstm_lib.init_slstm_cache(cfg, batch, dt),
        }
        return {"blocks": rep(per_group, G)}
    raise ValueError(cfg.block_pattern)


def decode_step(params: Params, cfg: LMConfig, cache: Params,
                tokens: jnp.ndarray, cur_index: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step. tokens: (B, 1) int32 (or embeds (B, 1, d) for
    non-token frontends). Returns (logits (B, V), new_cache)."""
    if tokens.ndim == 3:
        x = tokens.astype(cfg.dtype)
    else:
        x = params["embed"][tokens].astype(cfg.dtype)

    rmsnorm = _make_rmsnorm(cfg)
    shared = params.get("shared")

    def transformer_group(x, g, c):
        h, kv = attn.decode_attention(g["attn"], rmsnorm(x, g["ln1"]), cfg,
                                      c, cur_index)
        x = x + h
        if cfg.moe:
            h, _ = moe_lib.moe_forward(g["moe"], rmsnorm(x, g["ln2"]), cfg)
            x = x + h
        elif cfg.mlp_kind != "none":
            x = x + apply_mlp(g["mlp"], rmsnorm(x, g["ln2"]), cfg)
        return x, kv

    def zamba_group(x, g, c):
        def mamba_one(xx, gc):
            mg, mc = gc
            h, mc = ssm_lib.mamba2_step(mg["m"], rmsnorm(xx, mg["ln"]), cfg, mc)
            return xx + h, mc
        x, mcache = jax.lax.scan(mamba_one, x, (g["mamba"], c["mamba"]))
        s = shared
        h, kv = attn.decode_attention(s["attn"], rmsnorm(x, s["ln1"]), cfg,
                                      c["attn"], cur_index)
        x = x + h
        x = x + apply_mlp(s["mlp"], rmsnorm(x, s["ln2"]), cfg)
        return x, {"mamba": mcache, "attn": kv}

    def xlstm_group(x, g, c):
        def mlstm_one(xx, gc):
            mg, mc = gc
            h, mc = xlstm_lib.mlstm_step(mg["b"], rmsnorm(xx, mg["ln"]), cfg, mc)
            return xx + h, mc
        x, mcache = jax.lax.scan(mlstm_one, x, (g["mlstm"], c["mlstm"]))
        sg = g["slstm"]
        h, sc = xlstm_lib.slstm_step(sg["b"], rmsnorm(x, sg["ln"]), cfg,
                                     c["slstm"])
        x = x + h
        return x, {"mlstm": mcache, "slstm": sc}

    group_fn = {"transformer": transformer_group, "zamba2": zamba_group,
                "xlstm": xlstm_group}[cfg.block_pattern]

    def scan_body(x, gc):
        g, c = gc
        x, new_c = group_fn(x, g, c)
        return x, new_c

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache["blocks"]))
    x = _make_rmsnorm(cfg)(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head.astype(cfg.dtype)).astype(jnp.float32)
    return logits, {"blocks": new_cache}
