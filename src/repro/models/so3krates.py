"""So3krates-like SO(3)-equivariant transformer with GAQ quantization.

Faithful to the paper's architecture description (§III-B):
* two parallel per-atom branches — invariant scalars x (n, F) and equivariant
  l=1 vectors v (n, Fv, 3) — interacting only via attention,
* attention computed on invariant features + invariant geometric encodings
  (radial basis of ||r_ij||), optionally with the paper's robust cosine
  normalization (§III-E),
* equivariant message path built from spherical harmonics Y_1(r_hat) = r_hat
  and neighbour vectors, with invariant (attention-modulated) coefficients —
  exactly SO(3)-equivariant in full precision,
* energy readout from invariant features; forces via -grad (conservative).

Quantization modes (cfg.quant):
  "none"         FP32 baseline
  "gaq_w4a8"     the paper's method: MDDQ on vectors (+ geometric STE),
                 linear W4 (per-channel) / A8 on the rest, cosine attention
  "naive_int8"   per-tensor linear INT8 on everything incl. Cartesian vector
                 components — the symmetry-breaking baseline
  "degree_quant" per-node-degree range calibration (graph-aware, geometry-
                 agnostic baseline, after Tailor et al.)
  "svq_kmeans"   hard spherical VQ with *no* STE — gradient-fracture baseline
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    MDDQConfig,
    abs_max_scale,
    fake_quant,
    fake_quant_ste,
    make_codebook,
    mddq_fake_quant,
    nearest_code,
)
from repro.core.attention_norm import l2_normalize

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class So3kratesConfig:
    n_species: int = 20
    feat: int = 64             # F invariant channels
    vec_feat: int = 16         # Fv equivariant (l=1) channels
    n_layers: int = 3
    n_rbf: int = 16
    cutoff: float = 10.0       # Angstrom; azobenzene fits inside
    tau: float = 10.0          # cosine-attention inverse temperature
    quant: str = "none"
    w_bits: int = 4            # equivariant-branch weight bits (paper: W4)
    w_bits_inv: int = 8        # invariant-branch weight bits (paper: 8)
    a_bits: int = 8
    # 16-bit spherical codebook + 8-bit log magnitude = 24 bits/vector --
    # the same storage as naive INT8 (3 x 8-bit components) and 4x less than
    # fp32, but with covering radius ~0.01 rad (vs 0.17 rad at 8 bits).
    # The paper's LEE/F-MAE ratio (~0.7%) implies a comparable effective
    # directional resolution.
    dir_bits: int = 16
    robust_attention: bool = True
    geometric_ste: bool = True
    # Branch-separated staged warm-up (paper §III-D): when True the
    # equivariant-branch quantizer is disabled (scalars still quantized).
    freeze_vec_quant: bool = False

    def mddq(self) -> MDDQConfig:
        return MDDQConfig(direction_bits=self.dir_bits,
                          magnitude_bits=self.a_bits,
                          geometric_ste=self.geometric_ste)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_init(key, fan_in, fan_out):
    return jax.random.normal(key, (fan_in, fan_out)) * (1.0 / jnp.sqrt(fan_in))


def init_params(key: jax.Array, cfg: So3kratesConfig) -> Params:
    keys = iter(jax.random.split(key, 8 + cfg.n_layers * 16))
    F, Fv, K = cfg.feat, cfg.vec_feat, cfg.n_rbf
    p: Params = {"embed": jax.random.normal(next(keys), (cfg.n_species, F)) * 0.5}
    for i in range(cfg.n_layers):
        L = f"layer{i}"
        p[f"{L}/wq"] = _dense_init(next(keys), F, F)
        p[f"{L}/wk"] = _dense_init(next(keys), F, F)
        p[f"{L}/wm"] = _dense_init(next(keys), F, F)       # scalar messages
        p[f"{L}/rbf_m"] = _dense_init(next(keys), K, F)    # rbf gate, scalars
        p[f"{L}/rbf_bias"] = _dense_init(next(keys), K, 1) # attention bias
        p[f"{L}/wa"] = _dense_init(next(keys), F, Fv)      # coeff on Y_1(r_hat)
        p[f"{L}/rbf_a"] = _dense_init(next(keys), K, Fv)
        p[f"{L}/wb"] = _dense_init(next(keys), F, Fv)      # coeff on v_j
        p[f"{L}/rbf_b"] = _dense_init(next(keys), K, Fv)
        p[f"{L}/w_upd1"] = _dense_init(next(keys), F, F)
        p[f"{L}/w_upd2"] = _dense_init(next(keys), F, F)
        p[f"{L}/w_vnorm"] = _dense_init(next(keys), Fv, F)  # invariant feedback
        p[f"{L}/ln_g"] = jnp.ones((F,))
        p[f"{L}/ln_b"] = jnp.zeros((F,))
    p["ro_w1"] = _dense_init(next(keys), F + Fv, F)
    p["ro_w2"] = _dense_init(next(keys), F, 1) * 0.1
    return p


# ---------------------------------------------------------------------------
# quantization helpers (branch-separated, paper §III-D)
# ---------------------------------------------------------------------------

def _qw(w: jnp.ndarray, cfg: So3kratesConfig, branch: str) -> jnp.ndarray:
    """Weight fake-quant: per-output-channel, W4 equivariant / W8 invariant."""
    if cfg.quant == "none":
        return w
    bits = cfg.w_bits if branch == "eqv" else cfg.w_bits_inv
    if cfg.quant in ("naive_int8", "degree_quant", "svq_kmeans"):
        bits = 8  # baselines are W8A8
    return fake_quant_ste(w, bits, channel_axis=w.ndim - 1)


def _qact(x: jnp.ndarray, cfg: So3kratesConfig,
          degrees: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Scalar-activation fake-quant (A8)."""
    if cfg.quant == "none":
        return x
    if cfg.quant == "degree_quant" and degrees is not None:
        # per-node range scaled by sqrt(degree) (Degree-Quant-style protection)
        scale = abs_max_scale(jax.lax.stop_gradient(x), cfg.a_bits)
        scale = scale * jnp.sqrt(degrees / jnp.maximum(degrees.max(), 1.0))[:, None]
        scale = jnp.maximum(scale, 1e-8)
        return fake_quant_ste(x, cfg.a_bits, scale=scale)
    return fake_quant_ste(x, cfg.a_bits)


def _qvec(v: jnp.ndarray, cfg: So3kratesConfig,
          codebook: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Equivariant-feature quantization — where the methods differ."""
    if cfg.quant == "none" or cfg.freeze_vec_quant:
        return v
    if cfg.quant == "gaq_w4a8":
        return mddq_fake_quant(v, cfg.mddq(), codebook)
    if cfg.quant == "svq_kmeans":
        # hard spherical VQ, no gradient approximation: stop_gradient snaps
        m = jnp.linalg.norm(v, axis=-1, keepdims=True)
        u = v / jnp.maximum(m, 1e-12)
        q = codebook[nearest_code(u, codebook)]
        return jax.lax.stop_gradient(q * m)  # gradient fracture (paper §IV-B)
    # naive / degree_quant: per-tensor linear INT8 on Cartesian components
    return fake_quant_ste(v, 8)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * g + b


def _rbf(d: jnp.ndarray, cfg: So3kratesConfig) -> jnp.ndarray:
    centers = jnp.linspace(0.5, cfg.cutoff, cfg.n_rbf)
    gamma = (cfg.n_rbf / cfg.cutoff) ** 2
    phi = jnp.exp(-gamma * (d[..., None] - centers) ** 2)
    # smooth cutoff envelope (cosine)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cfg.cutoff, 0, 1)) + 1.0)
    return phi * env[..., None]


def _vnorm(v: jnp.ndarray) -> jnp.ndarray:
    """Invariant per-channel vector norms. (..., Fv, 3) -> (..., Fv)."""
    return jnp.sqrt(jnp.sum(v ** 2, -1) + 1e-12)


def pair_geometry(coords: jnp.ndarray, cfg: So3kratesConfig,
                  mask: Optional[jnp.ndarray] = None):
    """Dense pairwise geometry, shared by the QAT model and the serving
    oracle. coords: (..., n, 3); mask: (..., n) bool or None (True = real
    atom). Returns (d, u, rbf, pair_mask) with leading dims preserved:
    d (..., n, n), u = (r_j - r_i)/d, rbf masked to zero outside the
    cutoff graph, pair_mask excluding self-pairs and padded atoms.
    """
    n = coords.shape[-2]
    rij = coords[..., None, :, :] - coords[..., :, None, :]  # [i,j]=r_j-r_i
    d = jnp.sqrt(jnp.sum(rij ** 2, -1) + 1e-12)
    pair_mask = (d < cfg.cutoff) & ~jnp.eye(n, dtype=bool)
    if mask is not None:
        pair_mask = pair_mask & mask[..., :, None] & mask[..., None, :]
    u = rij / d[..., None]
    rbf = _rbf(d, cfg) * pair_mask[..., None]
    return d, u, rbf, pair_mask


def cosine_logits(q: jnp.ndarray, k: jnp.ndarray, bias: jnp.ndarray,
                  cfg: So3kratesConfig, robust: bool) -> jnp.ndarray:
    """Dense attention logits (..., n, n): the paper's robust cosine form
    (tau * <q/|q|, k/|k|>) or plain scaled dot product, plus the
    invariant radial-basis bias."""
    if robust:
        return cfg.tau * jnp.einsum("...if,...jf->...ij", l2_normalize(q),
                                    l2_normalize(k)) + bias
    return jnp.einsum("...if,...jf->...ij", q, k) \
        / jnp.sqrt(q.shape[-1]) + bias


def energy(params: Params, cfg: So3kratesConfig, species: jnp.ndarray,
           coords: jnp.ndarray, codebook: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Total energy of one molecule. species: (n,) int, coords: (n, 3)."""
    if codebook is None and cfg.quant != "none":
        codebook = make_codebook(cfg.dir_bits)
    d, u, rbf, mask = pair_geometry(coords, cfg)
    degrees = mask.sum(-1).astype(jnp.float32)

    x = params["embed"][species]                            # (n, F)
    v = jnp.zeros((coords.shape[0], cfg.vec_feat, 3))

    for i in range(cfg.n_layers):
        L = f"layer{i}"
        xn = _layernorm(x, params[f"{L}/ln_g"], params[f"{L}/ln_b"])
        xn = _qact(xn, cfg, degrees)

        q = xn @ _qw(params[f"{L}/wq"], cfg, "inv")
        k = xn @ _qw(params[f"{L}/wk"], cfg, "inv")
        bias = (rbf @ params[f"{L}/rbf_bias"])[..., 0]      # (n, n) invariant
        robust = (cfg.robust_attention and cfg.quant != "naive_int8"
                  and cfg.quant != "degree_quant")
        logits = cosine_logits(q, k, bias, cfg, robust)
        logits = jnp.where(mask, logits, -1e9)
        alpha = jax.nn.softmax(logits, axis=-1)             # (n, n)

        # invariant messages
        msg = xn @ _qw(params[f"{L}/wm"], cfg, "inv")       # (n, F)
        gate = rbf @ params[f"{L}/rbf_m"]                   # (n, n, F)
        x = x + jnp.einsum("ij,ijf->if", alpha, gate * msg[None, :, :])
        h = jax.nn.silu(_qact(x, cfg, degrees) @ _qw(params[f"{L}/w_upd1"], cfg, "inv"))
        x = x + h @ _qw(params[f"{L}/w_upd2"], cfg, "inv")

        # equivariant messages: coefficients are invariant scalars
        ca = (xn @ _qw(params[f"{L}/wa"], cfg, "eqv"))[None, :, :] * (rbf @ params[f"{L}/rbf_a"])
        cb = (xn @ _qw(params[f"{L}/wb"], cfg, "eqv"))[None, :, :] * (rbf @ params[f"{L}/rbf_b"])
        dv = jnp.einsum("ij,ijc,ijd->icd", alpha, ca, u) \
            + jnp.einsum("ij,ijc,jcd->icd", alpha, cb, v)
        v = v + dv
        v = _qvec(v, cfg, codebook)

        # invariant feedback from vector norms (keeps branches coupled)
        vnorm = _vnorm(v)                                   # (n, Fv) invariant
        x = x + jax.nn.silu(_qact(vnorm, cfg, degrees)) @ _qw(params[f"{L}/w_vnorm"], cfg, "inv")

    vnorm = _vnorm(v)
    feats = jnp.concatenate([x, vnorm], axis=-1)
    e_atom = jax.nn.silu(feats @ _qw(params["ro_w1"], cfg, "inv")) @ params["ro_w2"]
    return jnp.sum(e_atom)


def forces(params: Params, cfg: So3kratesConfig, species: jnp.ndarray,
           coords: jnp.ndarray, codebook: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Conservative forces F = -dE/dr. (n, 3)."""
    return -jax.grad(energy, argnums=3)(params, cfg, species, coords, codebook)


def energy_and_forces(params, cfg, species, coords, codebook=None):
    e, neg_f = jax.value_and_grad(energy, argnums=3)(params, cfg, species, coords, codebook)
    return e, -neg_f
