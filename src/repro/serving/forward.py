"""Batched, masked, quantized SO3krates forward passes: dense and sparse.

Two executions of the same architecture (see ``repro.models.so3krates``,
whose geometry/attention helpers both paths share):

* **Dense** (``batched_energy``) — the original O(B * n^2) path: pairwise
  (B, n, n, .) radial-basis and coefficient tensors, masked softmax over
  full rows. Exact and simple; kept as the correctness oracle and as the
  fallback for molecules denser than a bucket's edge capacity.
* **Sparse** (``sparse_energy``) — the O(E) edge-list path: the cutoff
  graph arrives as padded ``(senders, receivers, edge_mask)`` arrays from
  ``bucketing.build_edge_list``; attention, rbf gating, and both
  equivariant message terms are computed on *gathered edge features* and
  reduced with a segment softmax / segment sum — one fused
  ``edge_softmax`` launch per layer carrying the scalar message AND both
  equivariant message terms in a single value matrix. Memory and FLOPs
  scale with the number of edges, not atoms squared, which is what lets
  molecules far beyond the ~64-atom dense regime fit.

Both paths run every per-atom matmul through ``qparams.qmatmul`` (fused
W8A8/W4A8 Pallas kernels; ``use_kernels=False`` swaps in the pure-jnp
integer-accumulation reference) and share identical padding guarantees:
padded atoms never enter any edge or pair, contribute exactly zero
energy, and receive exactly zero force. ``tests/test_serving.py`` and
``tests/test_sparse_serving.py`` pin sparse == dense <= 1e-5 on energies
and forces.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import make_codebook, mddq_fake_quant
from repro.core.attention_norm import l2_normalize
from repro.kernels import ops
from repro.models.so3krates import (So3kratesConfig, _layernorm, _rbf,
                                    _vnorm, cosine_logits, pair_geometry)
from repro.serving.qparams import (QuantizedParams, concat_qtensors, qmatmul,
                                   ref_qmatmul)

__all__ = ["batched_energy", "batched_energy_and_forces",
           "sparse_energy", "sparse_energy_and_forces"]

# the per-layer "trunk": every projection taken from the same layernormed
# activations. The sparse path fuses them into as few matmuls as the
# weight kinds allow (w8a8/fp32: one; w4a8: one w8 + one w4 group) — an
# exact rewrite (see qparams.concat_qtensors), so sparse == dense stays
# pinned at 1e-5 while each layer runs one activation-quantization pass
# and one (kernel or integer-jnp) matmul instead of five. The MD engine
# hits this every step, so the op count is the CPU steps/sec lever.
_TRUNK = ("wq", "wk", "wm", "wa", "wb")


def _trunk_matmul(qparams, layer: str, xn: jnp.ndarray, mm) -> jnp.ndarray:
    """One fused projection pass: returns (N, 3F + 2Fv) columns ordered
    q | k | msg | a-coeff | b-coeff. Consecutive same-kind weights share
    a matmul; output column order is the `_TRUNK` order regardless of
    how the kinds group."""
    qts = [qparams[f"{layer}/{n}"] for n in _TRUNK]
    outs = []
    lo = 0
    for hi in range(1, len(qts) + 1):
        if hi == len(qts) or qts[hi].kind != qts[lo].kind:
            group = qts[lo:hi]
            qt = group[0] if len(group) == 1 else concat_qtensors(group)
            outs.append(mm(xn, qt))
            lo = hi
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)


def _dense(x: jnp.ndarray, qt, use_kernels: bool) -> jnp.ndarray:
    """(B, n, F_in) @ W -> (B, n, F_out) through one flattened matmul."""
    B, n, f = x.shape
    mm = qmatmul if use_kernels else ref_qmatmul
    y = mm(x.reshape(B * n, f), qt)
    return y.reshape(B, n, -1)


def _quant_vectors(v: jnp.ndarray, cfg: So3kratesConfig,
                   codebook: jnp.ndarray, mddq_kernel: bool) -> jnp.ndarray:
    """Serve-time MDDQ on l=1 features: the pure-jnp fake-quant reference,
    or the Pallas encode kernel (``ServeConfig.mddq_kernel``) whose
    backward runs the same Geometric-STE gradients. Padded atoms keep
    v == 0 forever; both implementations map zero vectors to exactly zero
    and are NaN-safe there (core/mddq._split).
    """
    if mddq_kernel:
        return ops.mddq_qdq_kernel(v, cfg.mddq(), codebook)
    return mddq_fake_quant(v, cfg.mddq(), codebook)


def batched_energy(qparams: QuantizedParams, cfg: So3kratesConfig,
                   species: jnp.ndarray, coords: jnp.ndarray,
                   mask: jnp.ndarray,
                   codebook: Optional[jnp.ndarray] = None,
                   *, quant_vectors: bool = True,
                   use_kernels: bool = True,
                   mddq_kernel: bool = False) -> jnp.ndarray:
    """Per-molecule energies for a padded batch — dense O(n^2) path.

    species: (B, n) int32, coords: (B, n, 3) f32, mask: (B, n) bool
    (True = real atom). Returns (B,) f32 — padded rows yield the energy of
    the empty molecule (0 contributions), masked callers should ignore
    them via the plan's graph indices.
    """
    B, n = species.shape
    if codebook is None and quant_vectors:
        codebook = make_codebook(cfg.dir_bits)

    _, u, rbf, pair_mask = pair_geometry(coords, cfg, mask)  # (B, n, n, .)

    x = qparams["embed"][species] * mask[..., None]          # (B, n, F)
    v = jnp.zeros((B, n, cfg.vec_feat, 3))

    for i in range(cfg.n_layers):
        L = f"layer{i}"
        xn = _layernorm(x, qparams[f"{L}/ln_g"], qparams[f"{L}/ln_b"])

        q = _dense(xn, qparams[f"{L}/wq"], use_kernels)
        k = _dense(xn, qparams[f"{L}/wk"], use_kernels)
        bias = (rbf @ qparams[f"{L}/rbf_bias"])[..., 0]      # (B, n, n)
        logits = cosine_logits(q, k, bias, cfg, cfg.robust_attention)
        logits = jnp.where(pair_mask, logits, -1e9)
        alpha = jax.nn.softmax(logits, axis=-1)              # (B, n, n)

        # invariant messages (gate is rbf-masked -> padded pairs drop out)
        msg = _dense(xn, qparams[f"{L}/wm"], use_kernels)
        gate = rbf @ qparams[f"{L}/rbf_m"]                   # (B, n, n, F)
        x = x + jnp.einsum("bij,bijf->bif", alpha,
                           gate * msg[:, None, :, :])
        h = jax.nn.silu(_dense(x, qparams[f"{L}/w_upd1"], use_kernels))
        x = x + _dense(h, qparams[f"{L}/w_upd2"], use_kernels)

        # equivariant messages: invariant coefficients x geometric directions
        ca = _dense(xn, qparams[f"{L}/wa"], use_kernels)[:, None] \
            * (rbf @ qparams[f"{L}/rbf_a"])                  # (B, n, n, Fv)
        cb = _dense(xn, qparams[f"{L}/wb"], use_kernels)[:, None] \
            * (rbf @ qparams[f"{L}/rbf_b"])
        dv = jnp.einsum("bij,bijc,bijd->bicd", alpha, ca, u) \
            + jnp.einsum("bij,bijc,bjcd->bicd", alpha, cb, v)
        v = v + dv
        if quant_vectors:
            v = _quant_vectors(v, cfg, codebook, mddq_kernel)

        x = x + _dense(jax.nn.silu(_vnorm(v)), qparams[f"{L}/w_vnorm"],
                       use_kernels)

    feats = jnp.concatenate([x, _vnorm(v)], axis=-1)
    e_hid = jax.nn.silu(_dense(feats, qparams["ro_w1"], use_kernels))
    e_atom = _dense(e_hid, qparams["ro_w2"], use_kernels)[..., 0]  # (B, n)
    return jnp.sum(e_atom * mask, axis=-1)                   # (B,)


def batched_energy_and_forces(qparams, cfg, species, coords, mask,
                              codebook=None, *, quant_vectors=True,
                              use_kernels=True, mddq_kernel=False):
    """Energies (B,) and conservative forces (B, n, 3) = -dE/dr.

    Differentiates through the quantized kernels via the straight-through
    VJP in ``qparams.qmatmul``; padded atoms receive exactly zero force.
    """
    def total_energy(c):
        e = batched_energy(qparams, cfg, species, c, mask, codebook,
                           quant_vectors=quant_vectors,
                           use_kernels=use_kernels, mddq_kernel=mddq_kernel)
        return jnp.sum(e), e

    (_, energies), neg_f = jax.value_and_grad(total_energy,
                                              has_aux=True)(coords)
    return energies, -neg_f


# ---------------------------------------------------------------------------
# sparse edge-list path
# ---------------------------------------------------------------------------

def sparse_energy(qparams: QuantizedParams, cfg: So3kratesConfig,
                  species: jnp.ndarray, coords: jnp.ndarray,
                  mask: jnp.ndarray, senders: jnp.ndarray,
                  receivers: jnp.ndarray, edge_mask: jnp.ndarray,
                  codebook: Optional[jnp.ndarray] = None,
                  *, quant_vectors: bool = True, use_kernels: bool = True,
                  edge_kernel: Optional[bool] = None,
                  mddq_kernel: bool = False,
                  refine_cutoff: bool = False) -> jnp.ndarray:
    """Per-molecule energies over a padded edge list — the O(E) path.

    species/coords/mask as in ``batched_energy``; senders/receivers are
    flat int32 indices into the ``(B * n,)`` node axis and edge_mask the
    per-slot validity bit, all laid out per the ``bucketing.EdgeList``
    contract (per-molecule slot ranges, receiver-sorted). ``edge_kernel``
    selects the fused Pallas segment-softmax (None = auto: kernel on TPU,
    the blocked XLA path elsewhere). ``refine_cutoff=True`` treats
    ``edge_mask`` as a Verlet-skin list built at an enlarged radius and
    tightens it to ``d < cfg.cutoff`` at the current coordinates using
    the internally computed distances (the MD engine's per-step
    refinement, fused here so it shares the geometry pass — same
    predicate as ``kernels.ops.refine_edge_mask``). Returns (B,) f32.
    """
    B, n = species.shape
    N = B * n
    F, Fv = cfg.feat, cfg.vec_feat
    if codebook is None and quant_vectors:
        codebook = make_codebook(cfg.dir_bits)
    mm = qmatmul if use_kernels else ref_qmatmul

    # edge geometry from gathered coordinates: the energy stays a function
    # of coords, so forces flow through the gathers; masked slots are
    # self-loops -> d ~ 0, and every use below is edge_mask-gated
    coords_f = coords.reshape(N, 3)
    rij = ops.edge_gather(coords_f, senders, n) \
        - ops.edge_gather(coords_f, receivers, n)            # (E, 3) r_j-r_i
    d2 = jnp.sum(rij ** 2, -1)
    if refine_cutoff:
        edge_mask = edge_mask & (d2 < cfg.cutoff * cfg.cutoff)
    d = jnp.sqrt(d2 + 1e-12)
    u = rij / d[..., None]                                   # (E, 3)
    rbf_e = _rbf(d, cfg) * edge_mask[..., None]              # (E, K)

    mask_f = mask.reshape(N)
    x = qparams["embed"][species.reshape(N)] * mask_f[:, None]   # (N, F)
    v = jnp.zeros((N, Fv, 3))

    for i in range(cfg.n_layers):
        L = f"layer{i}"
        xn = _layernorm(x, qparams[f"{L}/ln_g"], qparams[f"{L}/ln_b"])

        # fused trunk projection (q | k | msg | a | b, see _trunk_matmul)
        trunk = _trunk_matmul(qparams, L, xn, mm)            # (N, 3F+2Fv)
        q, k = trunk[:, :F], trunk[:, F:2 * F]
        if cfg.robust_attention:
            q_s = cfg.tau * l2_normalize(q)
            k_s = l2_normalize(k)
        else:
            q_s = q / jnp.sqrt(q.shape[-1])
            k_s = k

        # fused radial gemm: bias | scalar gate | a-gate | b-gate ride
        # one (E, K) @ (K, 1+F+2Fv) product (exact column split)
        rg = rbf_e @ jnp.concatenate(
            [qparams[f"{L}/rbf_bias"], qparams[f"{L}/rbf_m"],
             qparams[f"{L}/rbf_a"], qparams[f"{L}/rbf_b"]], axis=1)
        bias_e = rg[:, 0]                                    # (E,)
        gate_e = rg[:, 1:1 + F]                              # (E, F)

        # fused sender gather: scalar messages, both coefficient
        # projections, and the vector features come off one (E, .) gather
        # (ops.edge_gather: its VJP is a blocked matmul, not a scatter)
        sf = ops.edge_gather(
            jnp.concatenate([trunk[:, 2 * F:], v.reshape(N, Fv * 3)],
                            axis=1), senders, n)
        msg_e = sf[:, :F]                                    # (E, F)
        ca_e = sf[:, F:F + Fv] * rg[:, 1 + F:1 + F + Fv]     # (E, Fv)
        cb_e = sf[:, F + Fv:F + 2 * Fv] * rg[:, 1 + F + Fv:]
        # per-edge values for ONE fused softmax-scatter: scalar messages
        # and both equivariant message terms share the same alpha
        vec_e = ca_e[..., None] * u[:, None, :] \
            + cb_e[..., None] * sf[:, F + 2 * Fv:].reshape(-1, Fv, 3)
        vals = jnp.concatenate(
            [gate_e * msg_e, vec_e.reshape(-1, Fv * 3)], axis=1)

        out = ops.edge_softmax(q_s, k_s, bias_e, vals, senders, receivers,
                               edge_mask, cap=n, use_kernel=edge_kernel)
        x = x + out[:, :F]
        h = jax.nn.silu(mm(x, qparams[f"{L}/w_upd1"]))
        x = x + mm(h, qparams[f"{L}/w_upd2"])

        v = v + out[:, F:].reshape(N, Fv, 3)
        if quant_vectors:
            v = _quant_vectors(v, cfg, codebook, mddq_kernel)

        x = x + mm(jax.nn.silu(_vnorm(v)), qparams[f"{L}/w_vnorm"])

    feats = jnp.concatenate([x, _vnorm(v)], axis=-1)
    e_hid = jax.nn.silu(mm(feats, qparams["ro_w1"]))
    e_atom = mm(e_hid, qparams["ro_w2"])[:, 0]               # (N,)
    return jnp.sum(e_atom.reshape(B, n) * mask, axis=-1)     # (B,)


def sparse_energy_and_forces(qparams, cfg, species, coords, mask,
                             senders, receivers, edge_mask, codebook=None,
                             *, quant_vectors=True, use_kernels=True,
                             edge_kernel=None, mddq_kernel=False,
                             refine_cutoff=False):
    """Sparse-path energies (B,) and conservative forces (B, n, 3).

    The edge list is treated as data (indices carry no gradient); the
    energy differentiates through the gathered coordinates, so padded
    atoms — which appear in no real edge — get exactly zero force.
    """
    def total_energy(c):
        e = sparse_energy(qparams, cfg, species, c, mask, senders,
                          receivers, edge_mask, codebook,
                          quant_vectors=quant_vectors,
                          use_kernels=use_kernels, edge_kernel=edge_kernel,
                          mddq_kernel=mddq_kernel,
                          refine_cutoff=refine_cutoff)
        return jnp.sum(e), e

    (_, energies), neg_f = jax.value_and_grad(total_energy,
                                              has_aux=True)(coords)
    return energies, -neg_f
