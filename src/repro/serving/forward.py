"""Batched, masked, quantized SO3krates forward pass.

This is the serving counterpart of ``repro.models.so3krates.energy``: the
same architecture (two-branch equivariant transformer, robust cosine
attention, MDDQ on l=1 features) generalized to a *batch* of padded
molecules and rewired so every per-atom matmul runs through the fused
W8A8/W4A8 Pallas kernels via ``qparams.qmatmul``.

Batching strategy: activations of shape (B, n_pad, F) are flattened to a
single (B * n_pad, F) matrix per matmul — one kernel launch amortized over
the whole batch, with B * n_pad a multiple of 128 by the bucketing
contract (see ``repro.serving.bucketing``). Everything pairwise
(attention, radial basis, vector messages) keeps the batch dimension and
is masked so that

* padded atoms never appear in any neighbour pair (``pair_mask`` carries
  the per-atom validity mask on both sides),
* padded atoms contribute exactly zero energy (masked readout sum), and
* forces on padded atoms are exactly zero (the energy is independent of
  their coordinates, so ``jax.grad`` returns 0 there).

The same function body serves as its own oracle: ``use_kernels=False``
swaps ``qmatmul`` for a pure-jnp integer-accumulation reference with
identical quantization semantics, which is what ``tests/test_serving.py``
compares against (batched kernels vs per-molecule reference, <= 1e-5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import make_codebook, mddq_fake_quant
from repro.core.attention_norm import l2_normalize
from repro.models.so3krates import So3kratesConfig, _layernorm, _rbf
from repro.serving.qparams import QuantizedParams, qmatmul, ref_qmatmul

__all__ = ["batched_energy", "batched_energy_and_forces"]


def _dense(x: jnp.ndarray, qt, use_kernels: bool) -> jnp.ndarray:
    """(B, n, F_in) @ W -> (B, n, F_out) through one flattened matmul."""
    B, n, f = x.shape
    mm = qmatmul if use_kernels else ref_qmatmul
    y = mm(x.reshape(B * n, f), qt)
    return y.reshape(B, n, -1)


def batched_energy(qparams: QuantizedParams, cfg: So3kratesConfig,
                   species: jnp.ndarray, coords: jnp.ndarray,
                   mask: jnp.ndarray,
                   codebook: Optional[jnp.ndarray] = None,
                   *, quant_vectors: bool = True,
                   use_kernels: bool = True) -> jnp.ndarray:
    """Per-molecule energies for a padded batch.

    species: (B, n) int32, coords: (B, n, 3) f32, mask: (B, n) bool
    (True = real atom). Returns (B,) f32 — padded rows yield the energy of
    the empty molecule (0 contributions), masked callers should ignore
    them via the plan's graph indices.
    """
    B, n = species.shape
    if codebook is None and quant_vectors:
        codebook = make_codebook(cfg.dir_bits)

    rij = coords[:, None, :, :] - coords[:, :, None, :]      # [b,i,j]=r_j-r_i
    d = jnp.sqrt(jnp.sum(rij ** 2, -1) + 1e-12)
    eye = jnp.eye(n, dtype=bool)[None]
    pair_mask = ((d < cfg.cutoff) & ~eye
                 & mask[:, :, None] & mask[:, None, :])      # (B, n, n)
    u = rij / d[..., None]
    rbf = _rbf(d, cfg) * pair_mask[..., None]                # (B, n, n, K)

    x = qparams["embed"][species] * mask[..., None]          # (B, n, F)
    v = jnp.zeros((B, n, cfg.vec_feat, 3))

    for i in range(cfg.n_layers):
        L = f"layer{i}"
        xn = _layernorm(x, qparams[f"{L}/ln_g"], qparams[f"{L}/ln_b"])

        q = _dense(xn, qparams[f"{L}/wq"], use_kernels)
        k = _dense(xn, qparams[f"{L}/wk"], use_kernels)
        bias = (rbf @ qparams[f"{L}/rbf_bias"])[..., 0]      # (B, n, n)
        if cfg.robust_attention:
            logits = cfg.tau * jnp.einsum(
                "bif,bjf->bij", l2_normalize(q), l2_normalize(k)) + bias
        else:
            logits = jnp.einsum("bif,bjf->bij", q, k) \
                / jnp.sqrt(q.shape[-1]) + bias
        logits = jnp.where(pair_mask, logits, -1e9)
        alpha = jax.nn.softmax(logits, axis=-1)              # (B, n, n)

        # invariant messages (gate is rbf-masked -> padded pairs drop out)
        msg = _dense(xn, qparams[f"{L}/wm"], use_kernels)
        gate = rbf @ qparams[f"{L}/rbf_m"]                   # (B, n, n, F)
        x = x + jnp.einsum("bij,bijf->bif", alpha,
                           gate * msg[:, None, :, :])
        h = jax.nn.silu(_dense(x, qparams[f"{L}/w_upd1"], use_kernels))
        x = x + _dense(h, qparams[f"{L}/w_upd2"], use_kernels)

        # equivariant messages: invariant coefficients x geometric directions
        ca = _dense(xn, qparams[f"{L}/wa"], use_kernels)[:, None] \
            * (rbf @ qparams[f"{L}/rbf_a"])                  # (B, n, n, Fv)
        cb = _dense(xn, qparams[f"{L}/wb"], use_kernels)[:, None] \
            * (rbf @ qparams[f"{L}/rbf_b"])
        dv = jnp.einsum("bij,bijc,bijd->bicd", alpha, ca, u) \
            + jnp.einsum("bij,bijc,bjcd->bicd", alpha, cb, v)
        v = v + dv
        if quant_vectors:
            # padded atoms keep v == 0 forever; MDDQ maps zero vectors to
            # zero and its norm gradient is NaN-safe there (core/mddq._split)
            v = mddq_fake_quant(v, cfg.mddq(), codebook)

        vnorm = jnp.sqrt(jnp.sum(v ** 2, -1) + 1e-12)        # (B, n, Fv)
        x = x + _dense(jax.nn.silu(vnorm), qparams[f"{L}/w_vnorm"],
                       use_kernels)

    vnorm = jnp.sqrt(jnp.sum(v ** 2, -1) + 1e-12)
    feats = jnp.concatenate([x, vnorm], axis=-1)
    e_hid = jax.nn.silu(_dense(feats, qparams["ro_w1"], use_kernels))
    e_atom = _dense(e_hid, qparams["ro_w2"], use_kernels)[..., 0]  # (B, n)
    return jnp.sum(e_atom * mask, axis=-1)                   # (B,)


def batched_energy_and_forces(qparams, cfg, species, coords, mask,
                              codebook=None, *, quant_vectors=True,
                              use_kernels=True):
    """Energies (B,) and conservative forces (B, n, 3) = -dE/dr.

    Differentiates through the quantized kernels via the straight-through
    VJP in ``qparams.qmatmul``; padded atoms receive exactly zero force.
    """
    def total_energy(c):
        e = batched_energy(qparams, cfg, species, c, mask, codebook,
                           quant_vectors=quant_vectors,
                           use_kernels=use_kernels)
        return jnp.sum(e), e

    (_, energies), neg_f = jax.value_and_grad(total_energy,
                                              has_aux=True)(coords)
    return energies, -neg_f
