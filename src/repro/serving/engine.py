"""`QuantizedEngine` — batched, bucketed, quantized inference.

The deployment entry point this repo's ROADMAP builds toward: variable-size
molecular graphs in, per-molecule energies/forces out, with

* **bucketing** (``repro.serving.bucketing``) bounding the number of
  compiled shapes regardless of traffic mix,
* **two execution paths** (``repro.serving.forward``): the dense O(n^2)
  oracle and the sparse O(E) edge-list path with its fused
  segment-softmax kernel; ``ServeConfig.path`` selects, and ``"auto"``
  dispatches each batch sparse whenever its cutoff graph fits the
  bucket's edge capacity (falling back to dense when it doesn't),
* **real quantized weights** (``repro.serving.qparams``) streamed through
  the fused W8A8/W4A8 Pallas kernels — ``interpret=True`` is selected
  automatically when no TPU is present so the identical code path runs on
  CPU,
* **masked batching**: padded atoms are excluded from results and
  diagnostics exactly, not approximately.

Quickstart (see docs/serving.md):

    from repro.models import so3krates as so3
    from repro.serving import Graph, QuantizedEngine, ServeConfig

    engine = QuantizedEngine.from_config(
        so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2),
        params=trained_params,                 # or None -> random init
        serve=ServeConfig(mode="w8a8", bucket_sizes=(16, 32), max_batch=8))
    engine.warmup()            # pre-compile every admissible shape class
    results = engine.infer_batch([Graph(species, coords), ...])
    results[0].energy, results[0].forces       # padding already stripped
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_codebook
from repro.core.lee import random_rotation, random_rotations
from repro.guardrails import (Flag, GuardrailConfig, GuardrailViolation,
                              check_result)
from repro.models import so3krates as so3
from repro.obs.metrics import REGISTRY
from repro.serving.bucketing import (BucketSpec, Graph, build_edge_list,
                                     count_edges, pad_graphs, plan_batches)
from repro.serving.forward import (batched_energy_and_forces,
                                   sparse_energy_and_forces)
from repro.serving.qparams import (fp32_bytes, quantize_so3_params,
                                   serving_bytes, serving_fp32_equiv)

__all__ = ["ServeConfig", "MoleculeResult", "QuantizedEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-side knobs, orthogonal to the model architecture config."""
    mode: str = "w8a8"                       # "fp32" | "w8a8" | "w4a8"
    bucket_sizes: tuple = (16, 32, 64, 128)  # atom-capacity ladder
    max_batch: int = 64                      # molecules per compiled batch
    # MDDQ on l=1 features at serve time; None = follow the mode
    # (on for quantized modes, off for fp32 so fp32 is a true reference)
    quant_vectors: Optional[bool] = None
    pad_species: int = 0
    # execution path: "dense" (O(n^2) oracle), "sparse" (always prefer the
    # O(E) edge list), or "auto" (edge list only for buckets where it is
    # profitable — see QuantizedEngine._sparse_profitable — so
    # small-molecule traffic keeps the faster dense path). Both
    # sparse-preferring modes run a batch dense when its cutoff graph
    # overflows the bucket's edge capacity — counted in
    # engine.dispatch_stats["sparse_fallback"] — so warmup() compiles
    # dense shapes for every path.
    path: str = "auto"
    # per-molecule edge slots; None = bucketing.default_edge_capacity(cap)
    edge_capacity: Optional[int] = None
    # fused segment-softmax Pallas kernel; None = auto (kernel on TPU,
    # XLA segment ops on CPU — see kernels.ops.edge_softmax)
    edge_kernel: Optional[bool] = None
    # route serve-time vector quantization through the MDDQ Pallas encode
    # kernel (kernels.ops.mddq_qdq_kernel) instead of the pure-jnp
    # fake-quant reference
    mddq_kernel: bool = False

    def __post_init__(self):
        if self.path not in ("dense", "sparse", "auto"):
            raise ValueError(f"unknown path {self.path!r}")

    @property
    def vectors_quantized(self) -> bool:
        if self.quant_vectors is None:
            return self.mode != "fp32"
        return self.quant_vectors

    def buckets(self) -> List[BucketSpec]:
        return [BucketSpec(capacity=c, max_batch=self.max_batch,
                           edge_capacity=self.edge_capacity)
                for c in self.bucket_sizes]


@dataclasses.dataclass(frozen=True)
class MoleculeResult:
    """Per-molecule inference output with padding stripped."""
    energy: float
    forces: np.ndarray       # (n_atoms, 3)
    n_atoms: int
    bucket_capacity: int     # shape class the molecule rode in
    batch_size: int          # compiled batch rows (incl. alignment dummies)
    path: str = "dense"      # execution path the molecule's batch took
    # which cluster replica served the batch (0 outside a cluster; set
    # by repro.cluster's replica worker, not by the engine itself)
    replica_id: int = 0
    # content tag of the packed artifact the serving weights came from
    # ("" for engines built straight from fp32 params) — lets a client
    # verify which weights answered during a rolling hot swap
    artifact_version: str = ""
    # guardrail flags that fired on this molecule (repro.guardrails
    # Flag tuples). Empty for clean results; fatal flags never reach a
    # caller as a result — suspect flags annotate results that were
    # delivered because no higher precision tier remained
    flags: tuple = ()
    # precision-escalation audit trail (EscalationRecord tuples): each
    # entry is one re-run up the w4a8 -> w8a8 -> fp32 ladder a cluster
    # performed before this result was produced
    escalations: tuple = ()
    # obs linkage: the request trace this result answers ("" when tracing
    # is disabled or the result came from a direct infer_batch call)
    trace_id: str = ""


class QuantizedEngine:
    """Batched quantized-inference engine for the SO3krates force field."""

    def __init__(self, model_cfg: so3.So3kratesConfig,
                 params: Optional[Dict[str, jnp.ndarray]], serve: ServeConfig,
                 *, qparams=None, fp32_nbytes: Optional[int] = None,
                 device: Optional[jax.Device] = None,
                 artifact_version: str = "",
                 guardrails: Optional[GuardrailConfig] = None):
        """Build from fp32 ``params`` (quantized here, the training->serving
        hand-off) or directly from serving-format ``qparams`` (the packed-
        artifact cold-start path, ``repro.server.artifact`` — no fp32 tree
        is ever materialized). Exactly one of the two must be given;
        ``fp32_nbytes`` carries the fp32 footprint for ``memory_report``
        when no fp32 tree exists.

        ``device`` pins the engine to one JAX device: weights, codebook,
        and every batch are committed there, so the jitted forwards
        compile and execute on it — this is how ``repro.cluster`` stands
        up one engine per device (simulated on CPU via
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``). None
        keeps the default-device behavior. ``artifact_version`` is the
        content tag of the packed artifact the weights came from, echoed
        into every :class:`MoleculeResult`.

        ``guardrails`` configures the runtime result detectors
        (``repro.guardrails``; None = the default config, non-finite
        check on). It is an engine argument, not part of ``ServeConfig``,
        so artifacts and the cluster's shared-config invariant stay
        unchanged — detectors are a property of the serving process,
        not of the weights."""
        if (params is None) == (qparams is None):
            raise ValueError("pass exactly one of params / qparams")
        self.model_cfg = model_cfg
        self.serve = serve
        self.device = device
        self.artifact_version = artifact_version
        self.guardrails = (guardrails if guardrails is not None
                           else GuardrailConfig())
        if qparams is None:
            self._fp32_bytes = fp32_bytes(params)  # fp32 tree is not retained
            self.qparams = quantize_so3_params(params, serve.mode)
        else:
            self._fp32_bytes = (fp32_nbytes if fp32_nbytes is not None
                                else serving_fp32_equiv(qparams))
            self.qparams = qparams
        # committed placement: with a device given, weights/codebook move
        # there once and inputs follow per batch (_put), so jit compiles
        # for exactly that device
        self._put = ((lambda x: jax.device_put(x, device))
                     if device is not None else jnp.asarray)
        if device is not None:
            self.qparams = jax.device_put(self.qparams, device)
        quant_vec = serve.vectors_quantized
        self._codebook = (self._put(make_codebook(model_cfg.dir_bits))
                          if quant_vec else None)
        self._buckets = serve.buckets()
        use_kernels = serve.mode != "fp32"

        def _fwd_dense(species, coords, mask):
            return batched_energy_and_forces(
                self.qparams, self.model_cfg, species, coords, mask,
                self._codebook, quant_vectors=quant_vec,
                use_kernels=use_kernels, mddq_kernel=serve.mddq_kernel)

        def _fwd_sparse(species, coords, mask, senders, receivers,
                        edge_mask):
            return sparse_energy_and_forces(
                self.qparams, self.model_cfg, species, coords, mask,
                senders, receivers, edge_mask, self._codebook,
                quant_vectors=quant_vec, use_kernels=use_kernels,
                edge_kernel=serve.edge_kernel,
                mddq_kernel=serve.mddq_kernel)

        self._forward_dense = jax.jit(_fwd_dense)
        self._forward_sparse = jax.jit(_fwd_sparse)
        self.compiled_shapes = set()
        # batches dispatched per path; "sparse_fallback" counts batches a
        # sparse-preferring config had to run dense (edge-capacity overflow)
        self.dispatch_stats = {"dense": 0, "sparse": 0, "sparse_fallback": 0}
        # guardrail telemetry: molecules checked / flagged per detector,
        # LEE probes run (all counts only advance when guardrails.active)
        self.guard_stats = {"checked": 0, "flagged_nonfinite": 0,
                            "flagged_outlier": 0, "flagged_lee": 0,
                            "lee_probes": 0}
        self._n_infer_calls = 0         # LEE probe sampling counter
        # dual-write handles into the process-wide metrics plane
        # (repro.obs.metrics): the plain dicts above stay the exact
        # per-engine view (tests/benches subtract snapshots and expect
        # reset_stats to zero them); the registry instruments are keyed
        # by (name, labels) so the same counters keep accumulating across
        # engine exchanges — ClusterPool.swap_artifact and quarantine
        # cold-restarts no longer lose fleet-lifetime totals
        self._m_dispatch = {
            k: REGISTRY.counter("engine_dispatch_total",
                                mode=serve.mode, path=k)
            for k in self.dispatch_stats}
        self._m_guard = {
            k: REGISTRY.counter("engine_guard_total",
                                mode=serve.mode, event=k)
            for k in self.guard_stats}
        # per-(bucket, batch_size, path) warmup/compile accounting and
        # the last _infer_raw stage breakdown (obs profiling hooks)
        self.warmup_report: List[Dict] = []
        self.last_infer_breakdown: Dict[str, float] = {}

    # -- construction -------------------------------------------------------

    @classmethod
    def from_config(cls, model_cfg: so3.So3kratesConfig,
                    params: Optional[Dict[str, jnp.ndarray]] = None,
                    serve: ServeConfig = ServeConfig(),
                    seed: int = 0,
                    device: Optional[jax.Device] = None,
                    guardrails: Optional[GuardrailConfig] = None
                    ) -> "QuantizedEngine":
        """Build an engine from a model config and (optionally) trained
        fp32 params; random init when params is None (benchmarks, smoke)."""
        if params is None:
            params = so3.init_params(jax.random.PRNGKey(seed), model_cfg)
        return cls(model_cfg, params, serve, device=device,
                   guardrails=guardrails)

    @classmethod
    def from_quantized(cls, model_cfg: so3.So3kratesConfig, qparams,
                       serve: ServeConfig,
                       fp32_nbytes: Optional[int] = None,
                       device: Optional[jax.Device] = None,
                       artifact_version: str = "",
                       guardrails: Optional[GuardrailConfig] = None
                       ) -> "QuantizedEngine":
        """Build an engine from already-serving-format parameters — the
        packed-artifact cold-start path (``repro.server.artifact``) and
        the per-replica construction path of ``repro.cluster``: no fp32
        materialization, no quantization pass. ``qparams`` must have
        been produced by ``quantize_so3_params(params, serve.mode)`` (or
        loaded from an artifact saved from such an engine)."""
        return cls(model_cfg, None, serve, qparams=qparams,
                   fp32_nbytes=fp32_nbytes, device=device,
                   artifact_version=artifact_version, guardrails=guardrails)

    # -- introspection ------------------------------------------------------

    @property
    def interpret(self) -> bool:
        """True when the Pallas kernels run in CPU interpret mode (no TPU)."""
        return jax.default_backend() == "cpu"

    @property
    def backend(self) -> str:
        return jax.default_backend()

    def memory_report(self) -> Dict[str, int]:
        served = serving_bytes(self.qparams)
        return {"fp32_bytes": self._fp32_bytes, "served_bytes": served,
                "compression_x": round(self._fp32_bytes / max(served, 1), 2)}

    def stats_snapshot(self) -> Dict[str, int]:
        """Immutable copy of the dispatch counters — take one before and
        one after a phase and subtract to attribute batches to it."""
        return dict(self.dispatch_stats)

    def guard_snapshot(self) -> Dict[str, int]:
        """Immutable copy of the guardrail counters (checked/flagged per
        detector, LEE probes run)."""
        return dict(self.guard_stats)

    def reset_stats(self) -> Dict[str, int]:
        """Zero the dispatch + guardrail counters, returning the
        pre-reset dispatch snapshot. Both otherwise accumulate for the
        engine's lifetime, so benches/servers reset after warmup to keep
        steady-state phases unpolluted."""
        snap = self.stats_snapshot()
        for k in self.dispatch_stats:
            self.dispatch_stats[k] = 0
        for k in self.guard_stats:
            self.guard_stats[k] = 0
        return snap

    # -- serving ------------------------------------------------------------

    def warmup(self, buckets: Optional[Sequence[int]] = None,
               batch_sizes: Optional[Sequence[int]] = None) -> float:
        """Pre-compile the forward pass for the given shape classes.

        By default every admissible batch class of every bucket is
        compiled, for every path this config can dispatch — sparse paths
        also warm their dense shapes, because edge-capacity overflow
        falls back to dense at dispatch time. That is the complete
        (finite) set of shapes ``infer_batch`` can ever hit, so a warmed
        engine never compiles under traffic. Pass ``buckets`` and/or
        ``batch_sizes`` to restrict. Returns monotonic seconds spent
        compiling; ``warmup_report`` holds the per-(bucket, batch_size,
        path) breakdown — the measurement substrate for ROADMAP item 2's
        scale-from-zero accounting.
        """
        t0 = time.monotonic()
        self.warmup_report = []

        def _timed(path: str, cap: int, bsz: int, fn) -> None:
            s0 = time.monotonic()
            fn()
            dt = time.monotonic() - s0
            # t0 places the compile on the fleet timeline
            # (repro.obs.timeline renders one slice per compile)
            self.warmup_report.append(
                {"bucket": cap, "batch_size": bsz, "path": path,
                 "mode": self.serve.mode, "seconds": dt, "t0": s0})
            REGISTRY.histogram("engine_warmup_compile_seconds",
                               mode=self.serve.mode, path=path).observe(dt)

        caps = list(buckets) if buckets else [b.capacity
                                              for b in self._buckets]
        for cap in caps:
            spec = next(b for b in self._buckets if b.capacity == cap)
            if batch_sizes:
                sizes = list(batch_sizes)
            else:
                # distinct batch classes for 1..max_batch graphs
                sizes = sorted({spec.batch_class(n)
                                for n in range(1, spec.max_batch + 1)})
            for bsz in sizes:
                species = np.zeros((bsz, cap), np.int32)
                coords = np.zeros((bsz, cap, 3), np.float32)
                mask = np.zeros((bsz, cap), bool)
                # dense is always warmed: it is the overflow fallback of
                # every sparse-preferring config, so even path="sparse"
                # can dispatch it under traffic
                _timed("dense", cap, bsz,
                       lambda: self._run_dense(species, coords, mask))
                if self._wants_sparse(spec):
                    el = build_edge_list(coords, mask, self.model_cfg.cutoff,
                                         spec.edges)
                    _timed("sparse", cap, bsz,
                           lambda: self._run_sparse(species, coords,
                                                    mask, el))
        total = time.monotonic() - t0
        REGISTRY.counter("engine_warmup_seconds_total",
                         mode=self.serve.mode).inc(total)
        return total

    def _run_dense(self, species, coords, mask):
        self.compiled_shapes.add(species.shape)
        return self._forward_dense(self._put(species), self._put(coords),
                                   self._put(mask))

    def _run_sparse(self, species, coords, mask, el):
        self.compiled_shapes.add(("sparse",) + species.shape
                                 + (el.edge_capacity,))
        return self._forward_sparse(
            self._put(species), self._put(coords), self._put(mask),
            self._put(el.senders), self._put(el.receivers),
            self._put(el.edge_mask))

    # "auto" dispatches sparse only when the dense pairwise work is at
    # least this many times the padded edge-slot count — the gather /
    # segment-reduction overhead means break-even needs headroom, and 4x
    # matches the measured CPU crossover (dense wins at 16/32 atoms,
    # sparse from 64 up; see BENCH_serving.json)
    _SPARSE_PROFIT_FACTOR = 4

    def _sparse_profitable(self, spec: BucketSpec) -> bool:
        """Whether the edge-list path is expected to beat dense for this
        bucket: n^2 pairwise work >= 4x the padded edge slots."""
        return spec.capacity ** 2 >= self._SPARSE_PROFIT_FACTOR * spec.edges

    def _wants_sparse(self, spec: BucketSpec) -> bool:
        if self.serve.path == "sparse":
            return True              # explicit override, even if slower
        return self.serve.path == "auto" and self._sparse_profitable(spec)

    def _dispatch(self, species, coords, mask, spec: BucketSpec):
        """Run one padded batch down the configured path. Returns
        (energies, forces, path_taken)."""
        if self._wants_sparse(spec):
            el = build_edge_list(coords, mask, self.model_cfg.cutoff,
                                 spec.edges)
            if el is not None:
                self.dispatch_stats["sparse"] += 1
                self._m_dispatch["sparse"].inc()
                e, f = self._run_sparse(species, coords, mask, el)
                return e, f, "sparse"
            # cutoff graph denser than the bucket's edge capacity
            self.dispatch_stats["sparse_fallback"] += 1
            self._m_dispatch["sparse_fallback"].inc()
        self.dispatch_stats["dense"] += 1
        self._m_dispatch["dense"].inc()
        e, f = self._run_dense(species, coords, mask)
        return e, f, "dense"

    def infer_batch(self, graphs: Sequence[Graph],
                    on_flag: Optional[str] = None) -> List[MoleculeResult]:
        """Energies and forces for a heterogeneous list of molecules.

        Graphs are bucketed, padded, batched, and dispatched through the
        quantized forward (sparse edge-list path when configured and the
        batch's cutoff graph fits the edge capacity); results come back
        in input order with padding (and dummy alignment molecules)
        stripped.

        Results then pass the configured runtime guardrails
        (``repro.guardrails``): non-finite energy/forces, force-norm
        outliers vs the calibrated envelope, and the sampled LEE probe.
        ``on_flag`` overrides ``GuardrailConfig.on_flag`` for this call:
        ``"raise"`` (the direct-call default — a typed
        :class:`~repro.guardrails.GuardrailViolation` instead of a bad
        result) or ``"mark"`` (scheduler/cluster surfaces — flagged
        results come back with ``MoleculeResult.flags`` set and the
        caller triages: typed error, annotated delivery, or a precision
        escalation).
        """
        results = self._infer_raw(graphs)
        g = self.guardrails
        if not g.active:
            return results
        self._n_infer_calls += 1
        self.guard_stats["checked"] += len(results)
        self._m_guard["checked"].inc(len(results))
        flagged: Dict[int, tuple] = {}
        for i, r in enumerate(results):
            flags = check_result(r.energy, r.forces, r.bucket_capacity, g)
            if flags:
                flagged[i] = flags
        if g.lee_probe_every > 0 \
                and self._n_infer_calls % g.lee_probe_every == 0:
            for i, flag in self._lee_probe(graphs, results):
                flagged[i] = flagged.get(i, ()) + (flag,)
        if not flagged:
            return results
        for flags in flagged.values():
            for f in flags:
                key = {"nonfinite": "flagged_nonfinite",
                       "force_outlier": "flagged_outlier",
                       "lee": "flagged_lee"}.get(f.reason)
                if key is not None:
                    self.guard_stats[key] += 1
                    self._m_guard[key].inc()
        mode = on_flag if on_flag is not None else g.on_flag
        if mode == "raise":
            worst = max((f for flags in flagged.values() for f in flags),
                        key=lambda f: f.fatal)
            raise GuardrailViolation(
                f"guardrail {worst.reason} on {len(flagged)}/{len(results)} "
                f"molecule(s) (mode={self.serve.mode})", reason=worst.reason,
                severity=worst.severity,
                detail={"value": worst.value, "limit": worst.limit,
                        "mode": self.serve.mode})
        return [dataclasses.replace(r, flags=flagged[i]) if i in flagged
                else r for i, r in enumerate(results)]

    def _infer_raw(self, graphs: Sequence[Graph]) -> List[MoleculeResult]:
        """The bucket/pad/dispatch pipeline with no guardrail pass —
        also the re-run path of the LEE probe and ``lee_diagnostic``
        (probing the probe would recurse)."""
        t_start = time.monotonic()
        plans = plan_batches(graphs, self._buckets)
        prep_s = dispatch_s = sync_s = 0.0
        results: List[Optional[MoleculeResult]] = [None] * len(graphs)
        for plan in plans:
            t0 = time.monotonic()
            species, coords, mask = pad_graphs(
                graphs, plan, pad_species=self.serve.pad_species)
            t1 = time.monotonic()
            e, f, path = self._dispatch(species, coords, mask, plan.bucket)
            t2 = time.monotonic()
            # np.asarray forces device->host transfer: the sync point
            e = np.asarray(e)
            f = np.asarray(f)
            t3 = time.monotonic()
            prep_s += t1 - t0
            dispatch_s += t2 - t1
            sync_s += t3 - t2
            for row, gi in enumerate(plan.graph_indices):
                n = graphs[gi].n_atoms
                results[gi] = MoleculeResult(
                    energy=float(e[row]), forces=f[row, :n],
                    n_atoms=n, bucket_capacity=plan.bucket.capacity,
                    batch_size=plan.batch_size, path=path,
                    artifact_version=self.artifact_version)
        # per-flush serve-time breakdown (read by the scheduler/replica
        # worker right after infer_batch returns, same thread)
        self.last_infer_breakdown = {
            "prep_s": prep_s, "dispatch_s": dispatch_s, "sync_s": sync_s,
            "n_plans": len(plans), "total_s": time.monotonic() - t_start}
        return results  # type: ignore[return-value]

    def _lee_probe(self, graphs: Sequence[Graph],
                   results: Sequence[MoleculeResult]):
        """Sampled equivariance check: re-run the batch under one
        seeded rotation and compare rotated vs counter-rotated forces
        (paper Eq. 1, online). Returns ``(index, Flag)`` pairs for
        molecules whose LEE exceeds the limit."""
        g = self.guardrails
        self.guard_stats["lee_probes"] += 1
        self._m_guard["lee_probes"].inc()
        key = jax.random.PRNGKey(g.lee_seed + self._n_infer_calls)
        R = np.asarray(random_rotation(key))
        rotated = [Graph(gr.species, np.asarray(gr.coords) @ R.T)
                   for gr in graphs]
        out = []
        level = 0.0
        for i, (r0, r1) in enumerate(zip(results,
                                         self._infer_raw(rotated))):
            if not np.isfinite(r0.forces).all():
                continue            # nonfinite already flagged as fatal
            err = float(np.linalg.norm(r1.forces - r0.forces @ R.T))
            if np.isfinite(err):
                level = max(level, err / max(g.lee_limit, 1e-12))
            if not np.isfinite(err) or err > g.lee_limit:
                out.append((i, Flag("lee", "suspect", value=err,
                                    limit=g.lee_limit)))
        # SLO feed: worst probed LEE as a fraction of the limit
        # (> 1.0 breaches the lee_probe_level objective)
        REGISTRY.gauge("engine_lee_probe_level",
                       mode=self.serve.mode).set(level)
        return out

    # -- MD bridge ----------------------------------------------------------

    def md_engine(self, md=None):
        """A device-resident :class:`repro.md.engine.MDEngine` sharing
        this engine's quantized weights and codebook — serve traffic and
        run MD off one set of serving-format parameters. ``md`` is an
        ``MDConfig`` whose ``mode`` must match (default: one is built
        from this engine's mode). See docs/md.md.
        """
        from repro.md.engine import MDConfig, MDEngine
        if md is None:
            md = MDConfig(mode=self.serve.mode)
        if md.mode != self.serve.mode:
            raise ValueError(
                f"MDConfig.mode {md.mode!r} != ServeConfig.mode "
                f"{self.serve.mode!r}: the quantized weights are shared")
        return MDEngine(self.model_cfg, md=md, qparams=self.qparams,
                        codebook=self._codebook)

    # -- diagnostics --------------------------------------------------------

    def edge_occupancy(self, graphs: Sequence[Graph]) -> Dict[str, float]:
        """How full the sparse path's edge slots would be for this traffic:
        per-plan real-edge counts vs capacity. Useful for sizing
        ``ServeConfig.edge_capacity``."""
        plans = plan_batches(graphs, self._buckets)
        occ, overflow = [], 0
        for plan in plans:
            _, coords, mask = pad_graphs(graphs, plan,
                                         pad_species=self.serve.pad_species)
            counts = count_edges(coords, mask, self.model_cfg.cutoff)
            cap_e = plan.bucket.edges
            occ.append(float(counts.max()) / cap_e)
            overflow += int((counts > cap_e).sum())
        return {"max_occupancy": max(occ) if occ else 0.0,
                "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
                "molecules_overflowing": overflow}

    def lee_diagnostic(self, graphs: Sequence[Graph], key: jax.Array,
                       n_rotations: int = 4) -> Dict[str, float]:
        """Local Equivariance Error of the *served* (quantized, batched)
        model: || F(R.G) - R F(G) || per molecule, averaged over random
        rotations, with padded atoms excluded by construction (forces on
        them are exactly zero on both sides).
        """
        rots = np.asarray(random_rotations(key, n_rotations))
        base = self._infer_raw(graphs)
        errs = []
        for R in rots:
            rotated = [Graph(g.species, np.asarray(g.coords) @ R.T)
                       for g in graphs]
            rot_res = self._infer_raw(rotated)
            for r0, r1 in zip(base, rot_res):
                errs.append(float(np.linalg.norm(
                    r1.forces - r0.forces @ R.T)))
        return {"lee_mean": float(np.mean(errs)),
                "lee_max": float(np.max(errs)),
                "n_rotations": n_rotations, "n_graphs": len(graphs)}
