"""`QuantizedEngine` — batched, bucketed, quantized inference.

The deployment entry point this repo's ROADMAP builds toward: variable-size
molecular graphs in, per-molecule energies/forces out, with

* **bucketing** (``repro.serving.bucketing``) bounding the number of
  compiled shapes regardless of traffic mix,
* **real quantized weights** (``repro.serving.qparams``) streamed through
  the fused W8A8/W4A8 Pallas kernels — ``interpret=True`` is selected
  automatically when no TPU is present so the identical code path runs on
  CPU,
* **masked batching** (``repro.serving.forward``): padded atoms are
  excluded from results and diagnostics exactly, not approximately.

Quickstart (see docs/serving.md):

    from repro.models import so3krates as so3
    from repro.serving import Graph, QuantizedEngine, ServeConfig

    engine = QuantizedEngine.from_config(
        so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2),
        params=trained_params,                 # or None -> random init
        serve=ServeConfig(mode="w8a8", bucket_sizes=(16, 32), max_batch=8))
    engine.warmup()            # pre-compile every admissible shape class
    results = engine.infer_batch([Graph(species, coords), ...])
    results[0].energy, results[0].forces       # padding already stripped
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_codebook
from repro.core.lee import random_rotations
from repro.models import so3krates as so3
from repro.serving.bucketing import (BucketSpec, Graph, pad_graphs,
                                     plan_batches)
from repro.serving.forward import batched_energy_and_forces
from repro.serving.qparams import (fp32_bytes, quantize_so3_params,
                                   serving_bytes)

__all__ = ["ServeConfig", "MoleculeResult", "QuantizedEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-side knobs, orthogonal to the model architecture config."""
    mode: str = "w8a8"                       # "fp32" | "w8a8" | "w4a8"
    bucket_sizes: tuple = (16, 32, 64, 128)  # atom-capacity ladder
    max_batch: int = 64                      # molecules per compiled batch
    # MDDQ on l=1 features at serve time; None = follow the mode
    # (on for quantized modes, off for fp32 so fp32 is a true reference)
    quant_vectors: Optional[bool] = None
    pad_species: int = 0

    @property
    def vectors_quantized(self) -> bool:
        if self.quant_vectors is None:
            return self.mode != "fp32"
        return self.quant_vectors

    def buckets(self) -> List[BucketSpec]:
        return [BucketSpec(capacity=c, max_batch=self.max_batch)
                for c in self.bucket_sizes]


@dataclasses.dataclass(frozen=True)
class MoleculeResult:
    """Per-molecule inference output with padding stripped."""
    energy: float
    forces: np.ndarray       # (n_atoms, 3)
    n_atoms: int
    bucket_capacity: int     # shape class the molecule rode in
    batch_size: int


class QuantizedEngine:
    """Batched quantized-inference engine for the SO3krates force field."""

    def __init__(self, model_cfg: so3.So3kratesConfig,
                 params: Dict[str, jnp.ndarray], serve: ServeConfig):
        self.model_cfg = model_cfg
        self.serve = serve
        self._fp32_bytes = fp32_bytes(params)   # fp32 tree is not retained
        self.qparams = quantize_so3_params(params, serve.mode)
        quant_vec = serve.vectors_quantized
        self._codebook = (make_codebook(model_cfg.dir_bits)
                          if quant_vec else None)
        self._buckets = serve.buckets()
        use_kernels = serve.mode != "fp32"

        def _fwd(species, coords, mask):
            return batched_energy_and_forces(
                self.qparams, self.model_cfg, species, coords, mask,
                self._codebook, quant_vectors=quant_vec,
                use_kernels=use_kernels)

        self._forward = jax.jit(_fwd)
        self.compiled_shapes = set()

    # -- construction -------------------------------------------------------

    @classmethod
    def from_config(cls, model_cfg: so3.So3kratesConfig,
                    params: Optional[Dict[str, jnp.ndarray]] = None,
                    serve: ServeConfig = ServeConfig(),
                    seed: int = 0) -> "QuantizedEngine":
        """Build an engine from a model config and (optionally) trained
        fp32 params; random init when params is None (benchmarks, smoke)."""
        if params is None:
            params = so3.init_params(jax.random.PRNGKey(seed), model_cfg)
        return cls(model_cfg, params, serve)

    # -- introspection ------------------------------------------------------

    @property
    def interpret(self) -> bool:
        """True when the Pallas kernels run in CPU interpret mode (no TPU)."""
        return jax.default_backend() == "cpu"

    @property
    def backend(self) -> str:
        return jax.default_backend()

    def memory_report(self) -> Dict[str, int]:
        served = serving_bytes(self.qparams)
        return {"fp32_bytes": self._fp32_bytes, "served_bytes": served,
                "compression_x": round(self._fp32_bytes / max(served, 1), 2)}

    # -- serving ------------------------------------------------------------

    def warmup(self, buckets: Optional[Sequence[int]] = None,
               batch_sizes: Optional[Sequence[int]] = None) -> float:
        """Pre-compile the forward pass for the given shape classes.

        By default every admissible batch class of every bucket is
        compiled — the complete (finite) set of shapes ``infer_batch``
        can ever dispatch, so a warmed engine never compiles under
        traffic. Pass ``buckets`` and/or ``batch_sizes`` to restrict.
        Returns wall-clock seconds spent compiling.
        """
        t0 = time.time()
        caps = list(buckets) if buckets else [b.capacity
                                              for b in self._buckets]
        for cap in caps:
            spec = next(b for b in self._buckets if b.capacity == cap)
            if batch_sizes:
                sizes = list(batch_sizes)
            else:
                # distinct batch classes for 1..max_batch graphs
                sizes = sorted({spec.batch_class(n)
                                for n in range(1, spec.max_batch + 1)})
            for bsz in sizes:
                self._run_padded(
                    np.zeros((bsz, cap), np.int32),
                    np.zeros((bsz, cap, 3), np.float32),
                    np.zeros((bsz, cap), bool))
        return time.time() - t0

    def _run_padded(self, species, coords, mask):
        self.compiled_shapes.add(species.shape)
        e, f = self._forward(jnp.asarray(species), jnp.asarray(coords),
                             jnp.asarray(mask))
        return e, f

    def infer_batch(self, graphs: Sequence[Graph]) -> List[MoleculeResult]:
        """Energies and forces for a heterogeneous list of molecules.

        Graphs are bucketed, padded, batched, and dispatched through the
        quantized forward; results come back in input order with padding
        (and dummy alignment molecules) stripped.
        """
        plans = plan_batches(graphs, self._buckets)
        results: List[Optional[MoleculeResult]] = [None] * len(graphs)
        for plan in plans:
            species, coords, mask = pad_graphs(
                graphs, plan, pad_species=self.serve.pad_species)
            e, f = self._run_padded(species, coords, mask)
            e = np.asarray(e)
            f = np.asarray(f)
            for row, gi in enumerate(plan.graph_indices):
                n = graphs[gi].n_atoms
                results[gi] = MoleculeResult(
                    energy=float(e[row]), forces=f[row, :n],
                    n_atoms=n, bucket_capacity=plan.bucket.capacity,
                    batch_size=plan.batch_size)
        return results  # type: ignore[return-value]

    # -- diagnostics --------------------------------------------------------

    def lee_diagnostic(self, graphs: Sequence[Graph], key: jax.Array,
                       n_rotations: int = 4) -> Dict[str, float]:
        """Local Equivariance Error of the *served* (quantized, batched)
        model: || F(R.G) - R F(G) || per molecule, averaged over random
        rotations, with padded atoms excluded by construction (forces on
        them are exactly zero on both sides).
        """
        rots = np.asarray(random_rotations(key, n_rotations))
        base = self.infer_batch(graphs)
        errs = []
        for R in rots:
            rotated = [Graph(g.species, np.asarray(g.coords) @ R.T)
                       for g in graphs]
            rot_res = self.infer_batch(rotated)
            for r0, r1 in zip(base, rot_res):
                errs.append(float(np.linalg.norm(
                    r1.forces - r0.forces @ R.T)))
        return {"lee_mean": float(np.mean(errs)),
                "lee_max": float(np.max(errs)),
                "n_rotations": n_rotations, "n_graphs": len(graphs)}
