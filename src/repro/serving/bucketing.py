"""Shape-class bucketing for variable-size molecular graphs.

XLA (and especially the Pallas kernels) compile one program per input shape.
Serving a stream of molecules whose atom counts vary freely would trigger a
recompile per distinct ``n_atoms`` — fatal for latency. Instead every graph
is assigned to a **bucket**: a fixed atom capacity drawn from a small ladder
(default 16/32/64/128). Graphs are zero-padded up to their bucket capacity
and stacked; batch sizes are likewise rounded up to a power-of-two **batch
class** so the total number of distinct compiled shapes is
``len(buckets) * len(batch classes)`` — a constant, independent of traffic.

MXU alignment contract: the fused matmul kernels consume activations as a
flattened ``(batch * capacity, features)`` matrix whose row count must be a
multiple of 128 (one MXU tile side). ``plan_batches`` therefore rounds each
batch so ``batch_class * capacity % 128 == 0``; the surplus rows are dummy
all-padding molecules that are masked out of every result.

Edge capacity (the sparse serving path): every bucket also carries an
**edge capacity** — a fixed, 128-aligned number of directed-edge slots per
molecule. ``build_edge_list`` fills each molecule's slots with its real
cutoff-graph edges (sorted by receiver) and pads the rest with masked
self-loops, so the sparse forward and the ``edge_softmax`` kernel see one
static shape per (bucket, batch class) — same recompilation bound as the
dense path, but O(E) instead of O(n^2) memory and FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["Graph", "BucketSpec", "BatchPlan", "EdgeList", "assign_bucket",
           "plan_batches", "pad_graphs", "build_edge_list",
           "device_edge_list", "count_edges", "default_edge_capacity",
           "random_graph", "random_graphs", "MXU_LANE", "EDGE_LANE"]

MXU_LANE = 128  # minor-dim tile side of the TPU MXU; the 128-alignment contract
EDGE_LANE = 128  # edge slots are padded to a multiple of this (kernel block)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def default_edge_capacity(capacity: int) -> int:
    """Default per-molecule edge-slot count for a bucket.

    Small buckets get the complete graph (n*(n-1) directed pairs — no graph
    can overflow); from ~32 atoms up the capacity is clamped to an average
    degree of 16 neighbours, the regime where the sparse path wins. Always
    a multiple of EDGE_LANE. Molecules denser than the capacity fall back
    to the dense path at plan time (see ``QuantizedEngine``).
    """
    full = capacity * (capacity - 1)
    return _round_up(max(1, min(full, capacity * 16)), EDGE_LANE)


@dataclasses.dataclass(frozen=True)
class Graph:
    """One molecule: integer species codes (n,) and coordinates (n, 3)."""
    species: np.ndarray
    coords: np.ndarray

    @property
    def n_atoms(self) -> int:
        return int(self.species.shape[0])


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """A shape class: molecules padded to ``capacity`` atoms, batched in
    groups rounded up to a batch class with ``rows % 128 == 0``.

    ``edge_capacity`` is the per-molecule edge-slot count for the sparse
    path (None -> ``default_edge_capacity(capacity)``); it must be a
    multiple of EDGE_LANE so the segment-softmax kernel's edge blocks
    tile exactly.
    """
    capacity: int          # padded atom count per molecule
    max_batch: int = 64    # upper bound on molecules per compiled batch
    edge_capacity: Optional[int] = None  # per-molecule edge slots (sparse)

    @property
    def edges(self) -> int:
        ec = (default_edge_capacity(self.capacity)
              if self.edge_capacity is None else self.edge_capacity)
        if ec % EDGE_LANE != 0:
            raise ValueError(
                f"edge_capacity {ec} is not a multiple of {EDGE_LANE}")
        return ec

    def batch_class(self, n_graphs: int) -> int:
        """Smallest admissible batch size >= n_graphs: a power of two,
        clamped to max_batch, then rounded up so batch*capacity is a
        multiple of MXU_LANE (128)."""
        b = 1
        while b < min(n_graphs, self.max_batch):
            b *= 2
        b = min(b, self.max_batch)
        # enforce the row-alignment contract: batch * capacity % 128 == 0
        while (b * self.capacity) % MXU_LANE != 0:
            b *= 2
        return b


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One compiled dispatch: which input graphs ride in which rows."""
    bucket: BucketSpec
    batch_size: int                 # rows in the stacked batch (incl. dummies)
    graph_indices: Tuple[int, ...]  # positions in the caller's graph list


def assign_bucket(n_atoms: int, buckets: Sequence[BucketSpec]) -> BucketSpec:
    """Smallest bucket whose capacity holds the graph. Raises if none fits."""
    for b in sorted(buckets, key=lambda b: b.capacity):
        if n_atoms <= b.capacity:
            return b
    raise ValueError(
        f"graph with {n_atoms} atoms exceeds the largest bucket "
        f"({max(b.capacity for b in buckets)}); extend the bucket ladder")


def random_graph(rng: np.random.Generator, n_atoms: int, n_species: int,
                 density: Optional[float] = None) -> Graph:
    """One random molecule — the single generation recipe shared by
    :func:`random_graphs`, the server traffic harness
    (``repro.server.traffic``), and bench calibration, so every layer
    measures the same molecule distribution.

    ``density`` (atoms per cubic Angstrom) places atoms uniformly in a
    cube whose volume grows with n, so the cutoff graph has a
    size-independent average degree — the physical regime where the
    sparse path's O(E) beats the dense O(n^2). The default (None) is
    the legacy normal(0, 2) cloud, nearly fully connected under typical
    cutoffs.
    """
    if density is None:
        coords = rng.normal(size=(n_atoms, 3)) * 2.0
    else:
        side = (n_atoms / density) ** (1.0 / 3.0)
        coords = rng.uniform(0.0, side, size=(n_atoms, 3))
    return Graph(
        species=rng.integers(0, n_species, n_atoms).astype(np.int32),
        coords=coords.astype(np.float32))


def random_graphs(n_graphs: int, min_atoms: int, max_atoms: int,
                  n_species: int, seed: int = 0,
                  density: Optional[float] = None) -> List[Graph]:
    """Uniform random molecules for benchmarks and smoke runs (sizes
    uniform in [min_atoms, max_atoms]; see :func:`random_graph` for the
    per-molecule recipe and the meaning of ``density``)."""
    rng = np.random.default_rng(seed)
    return [random_graph(rng, int(rng.integers(min_atoms, max_atoms + 1)),
                         n_species, density)
            for _ in range(n_graphs)]


def plan_batches(graphs: Sequence[Graph],
                 buckets: Sequence[BucketSpec]) -> List[BatchPlan]:
    """Group graphs into per-bucket batches of bounded shape classes."""
    by_bucket: Dict[int, List[int]] = {}
    spec_of: Dict[int, BucketSpec] = {}
    for gi, g in enumerate(graphs):
        spec = assign_bucket(g.n_atoms, buckets)
        by_bucket.setdefault(spec.capacity, []).append(gi)
        spec_of[spec.capacity] = spec
    plans: List[BatchPlan] = []
    for cap in sorted(by_bucket):
        spec, idxs = spec_of[cap], by_bucket[cap]
        for lo in range(0, len(idxs), spec.max_batch):
            chunk = idxs[lo:lo + spec.max_batch]
            plans.append(BatchPlan(bucket=spec,
                                   batch_size=spec.batch_class(len(chunk)),
                                   graph_indices=tuple(chunk)))
    return plans


def pad_graphs(graphs: Sequence[Graph], plan: BatchPlan,
               pad_species: int = 0):
    """Stack a plan's graphs into dense arrays with a validity mask.

    Returns (species (B, cap) int32, coords (B, cap, 3) f32,
    mask (B, cap) bool). Rows beyond ``len(plan.graph_indices)`` are dummy
    all-padding molecules added only to satisfy the 128-row alignment.
    Padded atoms get ``pad_species`` and coordinates far outside any cutoff
    sphere would be wrong — they get zeros, and the forward pass masks them
    out of the neighbour graph explicitly, so their values never matter.
    """
    cap, B = plan.bucket.capacity, plan.batch_size
    species = np.full((B, cap), pad_species, dtype=np.int32)
    coords = np.zeros((B, cap, 3), dtype=np.float32)
    mask = np.zeros((B, cap), dtype=bool)
    for row, gi in enumerate(plan.graph_indices):
        g = graphs[gi]
        n = g.n_atoms
        species[row, :n] = np.asarray(g.species, dtype=np.int32)
        coords[row, :n] = np.asarray(g.coords, dtype=np.float32)
        mask[row, :n] = True
    return species, coords, mask


# ---------------------------------------------------------------------------
# neighbour lists (the sparse serving path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EdgeList:
    """Padded edge list for one batch, flat-indexed into ``(B * cap,)``.

    Layout contract (what ``repro.kernels.edge_softmax`` assumes):

    * molecule ``b`` owns edge slots ``[b * edge_capacity, (b+1) * ec)``
      exclusively — edges never cross molecule slot ranges;
    * within a molecule's range, real edges come first, **sorted by
      receiver**, followed by masked padding edges;
    * padding edges are self-loops on the molecule's first atom slot
      (sender == receiver == b * cap) with ``edge_mask == False``;
    * ``receivers[e] // cap == senders[e] // cap == e // edge_capacity``
      for every slot, masked or not.
    """
    senders: np.ndarray        # (B * ec,) int32, flat node index of atom j
    receivers: np.ndarray      # (B * ec,) int32, flat node index of atom i
    edge_mask: np.ndarray      # (B * ec,) bool, True = real cutoff edge
    edge_capacity: int         # ec: slots per molecule
    n_real: int                # total real edges across the batch


def _pair_adjacency(coords: np.ndarray, mask: np.ndarray,
                    cutoff: float) -> np.ndarray:
    """Host-side cutoff-graph adjacency (B, cap, cap): d < cutoff, no
    self-pairs, both atoms real — the single numpy mirror of the dense
    forward's ``pair_geometry`` predicate (keep the two in sync)."""
    d = np.linalg.norm(coords[:, :, None, :] - coords[:, None, :, :], axis=-1)
    cap = coords.shape[1]
    return ((d < cutoff) & ~np.eye(cap, dtype=bool)[None]
            & mask[:, :, None] & mask[:, None, :])


def count_edges(coords: np.ndarray, mask: np.ndarray,
                cutoff: float) -> np.ndarray:
    """Directed cutoff-graph edge count per molecule. coords: (B, cap, 3),
    mask: (B, cap) -> (B,) int. Used at plan time to decide whether a
    batch fits a bucket's edge capacity."""
    return _pair_adjacency(coords, mask, cutoff).sum(axis=(1, 2))


def build_edge_list(coords: np.ndarray, mask: np.ndarray, cutoff: float,
                    edge_capacity: int) -> Optional[EdgeList]:
    """Host-side neighbour-list construction for a padded batch.

    coords: (B, cap, 3) f32, mask: (B, cap) bool. Emits the exact edge set
    of the dense forward's ``pair_mask`` (d < cutoff, no self-pairs, both
    atoms real), receiver-sorted, padded to ``edge_capacity`` slots per
    molecule. Returns None when any molecule's edge count exceeds the
    capacity — the caller falls back to the dense path for this batch.

    Fully vectorized over the batch (no per-molecule Python loop — this
    runs per dispatch on the serving hot path): a stable argsort over each
    molecule's flattened adjacency moves edge positions to the front in
    row-major (= receiver-sorted) order, mirroring ``np.nonzero``.
    """
    B, cap = mask.shape
    ec = edge_capacity
    pair = _pair_adjacency(coords, mask, cutoff)             # (B, cap, cap)
    counts = pair.sum(axis=(1, 2))
    if (counts > ec).any():
        return None

    flat = pair.reshape(B, cap * cap)
    k = min(ec, cap * cap)
    # stable sort: edge positions (True) first, original order preserved
    order = np.argsort(~flat, axis=1, kind="stable")[:, :k]  # (B, k)
    valid = np.take_along_axis(flat, order, axis=1)          # (B, k)
    # padding slots: masked self-loops on the molecule's first atom,
    # so every index stays inside molecule b's node range
    i = np.where(valid, order // cap, 0)
    j = np.where(valid, order % cap, 0)
    base = (np.arange(B) * cap)[:, None]
    receivers = np.zeros((B, ec), dtype=np.int32)
    senders = np.zeros((B, ec), dtype=np.int32)
    edge_mask = np.zeros((B, ec), dtype=bool)
    receivers[:, :k] = base + i
    senders[:, :k] = base + j
    edge_mask[:, :k] = valid
    receivers[:, k:] = base
    senders[:, k:] = base
    return EdgeList(senders=senders.reshape(-1),
                    receivers=receivers.reshape(-1),
                    edge_mask=edge_mask.reshape(-1), edge_capacity=ec,
                    n_real=int(counts.sum()))


def device_edge_list(coords: jnp.ndarray, mask: jnp.ndarray, cutoff: float,
                     edge_capacity: int):
    """Jittable device-side neighbour-list builder for a padded batch.

    The static-shape twin of ``build_edge_list``: same inputs (as jnp
    arrays), same layout contract (per-molecule slot ranges,
    receiver-sorted real edges, masked self-loop padding on the
    molecule's first atom slot), but built entirely on device so it can
    live inside ``jax.jit`` / ``lax.scan`` — the MD engine rebuilds its
    Verlet skin lists through this under ``lax.cond`` with zero host
    sync. Instead of the host path's ``None`` fallback it returns an
    **overflow flag**: ``(senders, receivers, edge_mask, counts)`` with
    ``counts`` the per-molecule real-edge count; the list is only valid
    where ``counts <= edge_capacity`` and callers must check
    ``jnp.any(counts > edge_capacity)`` at a convenient sync point.

    The cutoff predicate is ``d^2 < cutoff^2`` (no sqrt) — identical
    real-edge sets to the host builder away from the measure-zero
    boundary, and the same predicate ``kernels.ops.refine_edge_mask``
    applies per step.
    """
    B, cap = mask.shape
    ec = edge_capacity
    rij = coords[:, :, None, :] - coords[:, None, :, :]      # [b,i,j]
    d2 = jnp.sum(rij * rij, axis=-1)
    adj = ((d2 < cutoff * cutoff) & ~jnp.eye(cap, dtype=bool)[None]
           & mask[:, :, None] & mask[:, None, :])            # (B, cap, cap)
    flat = adj.reshape(B, cap * cap)
    counts = flat.sum(axis=1)

    k = min(ec, cap * cap)
    order = jnp.argsort(~flat, axis=1)[:, :k]       # stable: edges first
    valid = jnp.take_along_axis(flat, order, axis=1)         # (B, k)
    i = jnp.where(valid, order // cap, 0)
    j = jnp.where(valid, order % cap, 0)
    base = (jnp.arange(B, dtype=jnp.int32) * cap)[:, None]
    if k < ec:
        pad = ((0, 0), (0, ec - k))
        i = jnp.pad(i, pad)
        j = jnp.pad(j, pad)
        valid = jnp.pad(valid, pad)
    receivers = (base + i).astype(jnp.int32).reshape(-1)
    senders = (base + j).astype(jnp.int32).reshape(-1)
    return senders, receivers, valid.reshape(-1), counts
