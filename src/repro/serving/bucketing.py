"""Shape-class bucketing for variable-size molecular graphs.

XLA (and especially the Pallas kernels) compile one program per input shape.
Serving a stream of molecules whose atom counts vary freely would trigger a
recompile per distinct ``n_atoms`` — fatal for latency. Instead every graph
is assigned to a **bucket**: a fixed atom capacity drawn from a small ladder
(default 16/32/64/128). Graphs are zero-padded up to their bucket capacity
and stacked; batch sizes are likewise rounded up to a power-of-two **batch
class** so the total number of distinct compiled shapes is
``len(buckets) * len(batch classes)`` — a constant, independent of traffic.

MXU alignment contract: the fused matmul kernels consume activations as a
flattened ``(batch * capacity, features)`` matrix whose row count must be a
multiple of 128 (one MXU tile side). ``plan_batches`` therefore rounds each
batch so ``batch_class * capacity % 128 == 0``; the surplus rows are dummy
all-padding molecules that are masked out of every result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["Graph", "BucketSpec", "BatchPlan", "assign_bucket",
           "plan_batches", "pad_graphs", "random_graphs", "MXU_LANE"]

MXU_LANE = 128  # minor-dim tile side of the TPU MXU; the 128-alignment contract


@dataclasses.dataclass(frozen=True)
class Graph:
    """One molecule: integer species codes (n,) and coordinates (n, 3)."""
    species: np.ndarray
    coords: np.ndarray

    @property
    def n_atoms(self) -> int:
        return int(self.species.shape[0])


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """A shape class: molecules padded to ``capacity`` atoms, batched in
    groups rounded up to a batch class with ``rows % 128 == 0``."""
    capacity: int          # padded atom count per molecule
    max_batch: int = 64    # upper bound on molecules per compiled batch

    def batch_class(self, n_graphs: int) -> int:
        """Smallest admissible batch size >= n_graphs: a power of two,
        clamped to max_batch, then rounded up so batch*capacity is a
        multiple of MXU_LANE (128)."""
        b = 1
        while b < min(n_graphs, self.max_batch):
            b *= 2
        b = min(b, self.max_batch)
        # enforce the row-alignment contract: batch * capacity % 128 == 0
        while (b * self.capacity) % MXU_LANE != 0:
            b *= 2
        return b


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One compiled dispatch: which input graphs ride in which rows."""
    bucket: BucketSpec
    batch_size: int                 # rows in the stacked batch (incl. dummies)
    graph_indices: Tuple[int, ...]  # positions in the caller's graph list


def assign_bucket(n_atoms: int, buckets: Sequence[BucketSpec]) -> BucketSpec:
    """Smallest bucket whose capacity holds the graph. Raises if none fits."""
    for b in sorted(buckets, key=lambda b: b.capacity):
        if n_atoms <= b.capacity:
            return b
    raise ValueError(
        f"graph with {n_atoms} atoms exceeds the largest bucket "
        f"({max(b.capacity for b in buckets)}); extend the bucket ladder")


def random_graphs(n_graphs: int, min_atoms: int, max_atoms: int,
                  n_species: int, seed: int = 0) -> List[Graph]:
    """Uniform random molecules for benchmarks and smoke runs."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(min_atoms, max_atoms + 1))
        out.append(Graph(
            species=rng.integers(0, n_species, n).astype(np.int32),
            coords=(rng.normal(size=(n, 3)) * 2.0).astype(np.float32)))
    return out


def plan_batches(graphs: Sequence[Graph],
                 buckets: Sequence[BucketSpec]) -> List[BatchPlan]:
    """Group graphs into per-bucket batches of bounded shape classes."""
    by_bucket: Dict[int, List[int]] = {}
    spec_of: Dict[int, BucketSpec] = {}
    for gi, g in enumerate(graphs):
        spec = assign_bucket(g.n_atoms, buckets)
        by_bucket.setdefault(spec.capacity, []).append(gi)
        spec_of[spec.capacity] = spec
    plans: List[BatchPlan] = []
    for cap in sorted(by_bucket):
        spec, idxs = spec_of[cap], by_bucket[cap]
        for lo in range(0, len(idxs), spec.max_batch):
            chunk = idxs[lo:lo + spec.max_batch]
            plans.append(BatchPlan(bucket=spec,
                                   batch_size=spec.batch_class(len(chunk)),
                                   graph_indices=tuple(chunk)))
    return plans


def pad_graphs(graphs: Sequence[Graph], plan: BatchPlan,
               pad_species: int = 0):
    """Stack a plan's graphs into dense arrays with a validity mask.

    Returns (species (B, cap) int32, coords (B, cap, 3) f32,
    mask (B, cap) bool). Rows beyond ``len(plan.graph_indices)`` are dummy
    all-padding molecules added only to satisfy the 128-row alignment.
    Padded atoms get ``pad_species`` and coordinates far outside any cutoff
    sphere would be wrong — they get zeros, and the forward pass masks them
    out of the neighbour graph explicitly, so their values never matter.
    """
    cap, B = plan.bucket.capacity, plan.batch_size
    species = np.full((B, cap), pad_species, dtype=np.int32)
    coords = np.zeros((B, cap, 3), dtype=np.float32)
    mask = np.zeros((B, cap), dtype=bool)
    for row, gi in enumerate(plan.graph_indices):
        g = graphs[gi]
        n = g.n_atoms
        species[row, :n] = np.asarray(g.species, dtype=np.int32)
        coords[row, :n] = np.asarray(g.coords, dtype=np.float32)
        mask[row, :n] = True
    return species, coords, mask
