"""Serve-time quantized parameters for the SO3krates force field.

QAT (``repro.models.so3krates``) trains with *fake* quantization: fp32
weights passed through quantize-dequantize so the network adapts to the
grid. Serving flips to the *real* representation: each matmul weight is
stored as int8 (W8) or nibble-packed int4 (W4) plus a per-output-channel
fp32 scale, and consumed directly by the fused Pallas kernels in
``repro.kernels.quant_matmul`` — weights stream from HBM at 1/4 (W8) or
1/8 (W4) of the fp32 byte count, which is the paper's Table-IV speedup
mechanism.

Quantization policy (mirrors ``repro.quant.apply`` for LMs, paper §III-D):

* per-atom-feature matmul weights -> quantized. In ``w4a8`` mode the
  equivariant-branch coefficient matrices (``wa``/``wb``) take W4, the
  invariant branch W8 (the paper's W4A8 operating point); ``w8a8`` puts
  W8 everywhere.
* precision-critical / tiny leaves stay fp32: the species embedding,
  layernorm gains/biases, the radial-basis gates (K=16 minor dim — no
  bandwidth to win), and the final energy head ``ro_w2`` (N=1: odd minor
  dim cannot nibble-pack, and the scalar energy readout is the
  error-amplifying leaf).

``qmatmul`` is the single entry point the serving forward pass uses: it
dispatches on the stored kind, runs the Pallas kernel (interpret=True
automatically on CPU), and carries a straight-through custom VJP so
conservative forces ``F = -dE/dr`` can still be taken through the integer
kernels — the backward pass multiplies by the *dequantized* weight matrix.
"""
from __future__ import annotations

import functools
from typing import Dict, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import unpack_int4
from repro.kernels import ops

__all__ = ["QTensor", "QuantPolicy", "qmatmul", "concat_qtensors",
           "quantize_so3_params", "serving_bytes", "fp32_bytes",
           "serving_fp32_equiv"]

# names of the equivariant-branch coefficient matrices (paper: W4 in w4a8)
_EQV_SUFFIXES = ("/wa", "/wb")
# matmul weights consumed by qmatmul; everything else stays fp32
_MATMUL_SUFFIXES = ("/wq", "/wk", "/wm", "/w_upd1", "/w_upd2", "/w_vnorm",
                    "/wa", "/wb")
_MATMUL_GLOBALS = ("ro_w1",)


@jax.tree_util.register_pytree_node_class
class QTensor:
    """A weight in its serving representation.

    kind: "fp"  -> data = fp32 (K, N), scale unused
          "w8"  -> data = int8 (K, N), scale = fp32 (1, N) per column
          "w4"  -> data = uint8 (K, N//2) nibble-packed, scale = fp32 (1, N)
    """

    def __init__(self, kind: str, data: jnp.ndarray, scale=None):
        self.kind = kind
        self.data = data
        self.scale = scale

    def tree_flatten(self):
        return (self.data, self.scale), self.kind

    @classmethod
    def tree_unflatten(cls, kind, children):
        return cls(kind, *children)

    @property
    def out_features(self) -> int:
        if self.kind == "w4":
            return self.data.shape[1] * 2
        return self.data.shape[1]

    @property
    def nbytes(self) -> int:
        n = int(self.data.size)
        itemsize = {"fp": 4, "w8": 1, "w4": 1}[self.kind]
        scale_bytes = 0 if self.scale is None else int(self.scale.size) * 4
        return n * itemsize + scale_bytes

    def dequantize(self) -> jnp.ndarray:
        """fp32 view of the stored weight — used by the force backward pass
        and by the pure-jnp reference forward."""
        if self.kind == "fp":
            return self.data
        if self.kind == "w8":
            return self.data.astype(jnp.float32) * self.scale
        if self.kind == "w4":
            return unpack_int4(self.data).astype(jnp.float32) * self.scale
        raise ValueError(self.kind)


QuantizedParams = Dict[str, Union[QTensor, jnp.ndarray]]


# ---------------------------------------------------------------------------
# qmatmul: Pallas forward, straight-through backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _qmm(kind: str, x, data, scale):
    return _qmm_impl(kind, x, data, scale)


def _qmm_impl(kind, x, data, scale):
    if kind == "fp":
        return x @ data
    if kind == "w8":
        return ops.matmul_w8a8(x, data, scale)
    if kind == "w4":
        return ops.matmul_w4a8(x, data, scale)
    raise ValueError(kind)


def _qmm_fwd(kind, x, data, scale):
    return _qmm_impl(kind, x, data, scale), (data, scale)


def _qmm_bwd(kind, res, g):
    data, scale = res
    w_dq = QTensor(kind, data, scale).dequantize()
    gx = g @ w_dq.T
    # weights are frozen at serve time: zero/float0 cotangents
    ct_data = (jnp.zeros_like(data) if jnp.issubdtype(data.dtype, jnp.floating)
               else np.zeros(data.shape, jax.dtypes.float0))
    ct_scale = None if scale is None else jnp.zeros_like(scale)
    return (gx, ct_data, ct_scale)


_qmm.defvjp(_qmm_fwd, _qmm_bwd)


def qmatmul(x: jnp.ndarray, qt: QTensor) -> jnp.ndarray:
    """y = x @ W for a serving-format weight. x: (M, K) fp32 -> (M, N) fp32.

    W8/W4 kinds run the fused dequantize-matmul Pallas kernel (per-row
    dynamic A8 activation quantization inside ``repro.kernels.ops``); the
    backward pass is straight-through against the dequantized weights, so
    ``jax.grad`` through an engine forward (forces) works.
    """
    return _qmm(qt.kind, x, qt.data, qt.scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ref_qmm(kind, x, data, scale):
    return _ref_qmm_impl(kind, x, data, scale)


def _ref_qmm_impl(kind, x, data, scale):
    if kind == "fp":
        return x @ data
    a_q, a_s = ops.quantize_activations(x)
    w_q = data if kind == "w8" else unpack_int4(data)
    acc = jnp.matmul(a_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return acc.astype(jnp.float32) * a_s * scale


def _ref_qmm_fwd(kind, x, data, scale):
    return _ref_qmm_impl(kind, x, data, scale), (data, scale)


_ref_qmm.defvjp(_ref_qmm_fwd, _qmm_bwd)  # same STE backward as the kernels


def ref_qmatmul(x: jnp.ndarray, qt: QTensor) -> jnp.ndarray:
    """Pure-jnp oracle with the same semantics as ``qmatmul`` — identical
    forward value (per-row A8 activations, integer accumulation) and the
    identical straight-through backward (gradients flow as if the matmul
    were against the dequantized weights — a custom VJP, so the forward
    runs the integer path alone with no surrogate fp matmul riding
    along). Used by the per-molecule reference path in tests (both
    energies AND forces must match the kernel-batched engine) and as the
    CPU serving/MD matmul where the Pallas interpreter has nothing to
    fuse for."""
    return _ref_qmm(qt.kind, x, qt.data, qt.scale)


def concat_qtensors(qts) -> QTensor:
    """Fuse weights along the output axis: ``x @ [W1|W2|...]`` equals the
    per-weight matmuls column-for-column, because activation scales are
    per-row (independent of the weight) and weight scales per-column
    (independent of the split) — for fp, w8, and nibble-packed w4 alike
    (each packed width is a whole number of bytes). The serving forward
    fuses each layer's trunk projections through this: one quantized
    matmul (and one activation-quantization pass) instead of five.

    All inputs must share kind and input dimension; w4 widths must be
    even. Output columns are ordered as the inputs are given.
    """
    kind = qts[0].kind
    if any(q.kind != kind for q in qts):
        raise ValueError(f"mixed kinds {[q.kind for q in qts]}")
    if any(q.data.shape[0] != qts[0].data.shape[0] for q in qts):
        raise ValueError("mismatched input dims")
    data = jnp.concatenate([q.data for q in qts], axis=1)
    if kind == "fp":
        return QTensor("fp", data)
    return QTensor(kind, data,
                   jnp.concatenate([q.scale for q in qts], axis=1))


# ---------------------------------------------------------------------------
# parameter-tree conversion
# ---------------------------------------------------------------------------

class QuantPolicy:
    """Maps a SO3krates param name to its serving kind for a given mode."""

    def __init__(self, mode: str):
        assert mode in ("fp32", "w8a8", "w4a8"), mode
        self.mode = mode

    def kind_of(self, name: str, w) -> str:
        is_matmul = (name.endswith(_MATMUL_SUFFIXES)
                     or name in _MATMUL_GLOBALS)
        if self.mode == "fp32" or not is_matmul or w.ndim != 2:
            return "fp"
        if (self.mode == "w4a8" and name.endswith(_EQV_SUFFIXES)
                and w.shape[1] % 2 == 0):
            return "w4"
        return "w8"


def quantize_so3_params(params: Dict[str, jnp.ndarray],
                        mode: str) -> QuantizedParams:
    """Convert a trained fp32 SO3krates param dict to serving format.

    Matmul weights become ``QTensor``s (int8 / packed-int4 + per-column
    scales via ``repro.kernels.ops.prepare_w8/prepare_w4``); everything
    else passes through as fp32 arrays.
    """
    policy = QuantPolicy(mode)
    out: QuantizedParams = {}
    for name, w in params.items():
        kind = policy.kind_of(name, w)
        if kind == "w8":
            q, s = ops.prepare_w8(w)
            out[name] = QTensor("w8", q, s)
        elif kind == "w4":
            q, s = ops.prepare_w4(w)
            out[name] = QTensor("w4", q, s)
        elif name.endswith(_MATMUL_SUFFIXES) or name in _MATMUL_GLOBALS \
                or name == "ro_w2":
            out[name] = QTensor("fp", w)
        else:
            out[name] = w
    return out


def serving_bytes(qparams: QuantizedParams) -> int:
    """Total parameter bytes in the serving representation."""
    total = 0
    for v in qparams.values():
        if isinstance(v, QTensor):
            total += v.nbytes
        else:
            total += int(np.asarray(v).nbytes)
    return total


def fp32_bytes(params: Dict[str, jnp.ndarray]) -> int:
    return int(sum(np.asarray(v).size * 4 for v in params.values()))


def serving_fp32_equiv(qparams: QuantizedParams) -> int:
    """fp32 byte count the qparams tree *would* occupy: the logical
    (unpacked, unscaled) element count at 4 bytes/element. Used when an
    engine is built straight from a packed artifact and no fp32 tree ever
    existed to measure."""
    total = 0
    for v in qparams.values():
        if isinstance(v, QTensor):
            total += int(v.data.shape[0]) * v.out_features * 4 \
                if v.data.ndim == 2 else int(np.asarray(v.data).size) * 4
        else:
            total += int(np.asarray(v).size) * 4
    return total
