"""repro.serving — batched quantized-inference engine.

The deployment layer of the GAQ reproduction: takes variable-size
molecular graphs, buckets and pads them into MXU-aligned (multiple-of-128)
shape classes to bound recompilation, runs the quantized SO3krates forward
pass — dense O(n^2) oracle or sparse O(E) edge-list path with its fused
segment-softmax kernel, selected per batch by ``ServeConfig.path`` —
through the fused W8A8/W4A8 Pallas kernels (CPU ``interpret=True``
fallback selected automatically when no TPU is present), and returns
per-molecule energies and conservative forces with padding masked out of
both results and LEE diagnostics.

Public API:

* :class:`QuantizedEngine` — ``from_config(...)``, ``infer_batch(graphs)``,
  ``warmup(buckets)``, ``lee_diagnostic(...)``, ``memory_report()``
* :class:`ServeConfig` — serving mode (fp32/w8a8/w4a8), bucket ladder,
  max batch
* :class:`Graph` / :class:`MoleculeResult` — input/output records
* :class:`BucketSpec`, :func:`plan_batches`, :func:`pad_graphs` — the
  bucketing layer, usable standalone
* :func:`quantize_so3_params`, :func:`qmatmul` — serve-time weight
  conversion and the kernel-backed matmul with straight-through VJP

See docs/serving.md for the full semantics and docs/architecture.md for
where this layer sits in the module map.
"""
from repro.serving.bucketing import (BatchPlan, BucketSpec, EDGE_LANE,
                                     EdgeList, Graph, MXU_LANE,
                                     assign_bucket, build_edge_list,
                                     count_edges, default_edge_capacity,
                                     device_edge_list, pad_graphs,
                                     plan_batches, random_graph,
                                     random_graphs)
from repro.serving.engine import MoleculeResult, QuantizedEngine, ServeConfig
from repro.serving.forward import (batched_energy, batched_energy_and_forces,
                                   sparse_energy, sparse_energy_and_forces)
from repro.serving.qparams import (QTensor, qmatmul, quantize_so3_params,
                                   ref_qmatmul, serving_bytes)

__all__ = [
    "BatchPlan", "BucketSpec", "EDGE_LANE", "EdgeList", "Graph", "MXU_LANE",
    "assign_bucket", "build_edge_list", "count_edges",
    "default_edge_capacity", "device_edge_list", "pad_graphs",
    "plan_batches", "random_graph", "random_graphs",
    "MoleculeResult", "QuantizedEngine", "ServeConfig",
    "batched_energy", "batched_energy_and_forces",
    "sparse_energy", "sparse_energy_and_forces",
    "QTensor", "qmatmul", "quantize_so3_params", "ref_qmatmul",
    "serving_bytes",
]
