"""Process-wide metrics registry for the quantized serving stack.

Dependency-free (stdlib only): the engine, scheduler, cluster pool,
sessions manager, and guardrail detectors all dual-write into this
registry at their existing increment sites, so the nine scattered
snapshot surfaces (``stats_snapshot``/``guard_snapshot``/``stats()``/
``flush_summary``/...) become thin per-component views over numbers
that also exist in one labelled, process-lifetime plane.

Three instrument kinds:

- :class:`Counter` — monotonic float total (Prometheus counter
  semantics). Because instruments are keyed by ``(name, labels)`` in a
  *process-wide* registry, counters naturally survive engine exchanges
  (``ClusterPool.swap_artifact``, quarantine cold-restarts): a fresh
  ``QuantizedEngine`` binds to the same instrument and keeps adding.
- :class:`Gauge` — last-write-wins level (queue depth, live replicas).
- :class:`Histogram` — log-bucketed (base ``2**0.25``, ~19% bucket
  resolution) with count/sum/min/max and p50/p95/p99 readout. Built for
  durations spanning microseconds (counter bumps) to minutes (warmup
  compiles) without preconfigured bounds.

All instruments are thread-safe. ``REGISTRY.set_enabled(False)`` turns
every write into a no-op (the A/B arm of the obs overhead bench);
reads still work. ``snapshot()`` returns one JSON-able labelled
document; :func:`repro.obs.export.prometheus_text` renders it in
Prometheus text exposition format.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Tuple

# log-bucket base: 4 buckets per octave (~19% relative resolution)
_BASE = 2.0 ** 0.25
_LOG_BASE = math.log(_BASE)


def label_suffix(labels: Dict[str, str]) -> str:
    """Prometheus-style ``{k="v",...}`` suffix, keys sorted, '' if none."""
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


class _Instrument:
    __slots__ = ("name", "labels", "_lock", "_registry")

    def __init__(self, registry: "MetricsRegistry", name: str,
                 labels: Dict[str, str]):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._registry = registry

    @property
    def key(self) -> str:
        return self.name + label_suffix(self.labels)


class Counter(_Instrument):
    """Monotonic total. ``inc`` with a negative amount raises."""
    __slots__ = ("_value",)

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        if amount < 0:
            raise ValueError(f"counter {self.key} cannot decrease")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Instrument):
    """Last-write-wins level; ``add`` for deltas (queue depth +-1)."""
    __slots__ = ("_value",)

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._value = 0.0

    def set(self, value: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value = float(value)

    def add(self, delta: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += delta

    @property
    def value(self) -> float:
        return self._value


class Histogram(_Instrument):
    """Log-bucketed histogram with quantile readout.

    Buckets are ``(_BASE**(i-1), _BASE**i]``; values <= 0 land in a
    dedicated underflow bucket reported as 0.0. Quantiles return the
    upper edge of the bucket where the cumulative count crosses ``q`` —
    i.e. an over-estimate by at most one bucket width (~19%), which is
    the right bias for latency gates.
    """
    __slots__ = ("_buckets", "_count", "_sum", "_min", "_max")

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._buckets: Dict[Optional[int], int] = {}
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    @staticmethod
    def _bucket_index(value: float) -> Optional[int]:
        if value <= 0.0:
            return None  # underflow bucket
        return int(math.ceil(math.log(value) / _LOG_BASE - 1e-12))

    @staticmethod
    def _bucket_edge(index: Optional[int]) -> float:
        return 0.0 if index is None else _BASE ** index

    def observe(self, value: float) -> None:
        if not self._registry.enabled:
            return
        idx = self._bucket_index(value)
        with self._lock:
            self._buckets[idx] = self._buckets.get(idx, 0) + 1
            self._count += 1
            self._sum += value
            self._min = min(self._min, value)
            self._max = max(self._max, value)

    def percentile(self, q: float) -> float:
        """Upper bucket edge at quantile ``q`` in [0, 1]; 0.0 if empty."""
        with self._lock:
            if self._count == 0:
                return 0.0
            target = q * self._count
            # None (underflow) sorts first
            items = sorted(self._buckets.items(),
                           key=lambda kv: -math.inf if kv[0] is None
                           else kv[0])
            cum = 0
            for idx, n in items:
                cum += n
                if cum >= target:
                    return min(self._bucket_edge(idx), self._max)
            return self._max

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def snapshot(self) -> Dict:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "p50": 0.0, "p95": 0.0, "p99": 0.0,
                        "buckets": {}}
            base = {"count": self._count, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    # JSON-able bucket dict ("u" = underflow) so the
                    # health plane (repro.obs.slo) can compute windowed
                    # quantiles from snapshot deltas
                    "buckets": {("u" if k is None else str(k)): n
                                for k, n in self._buckets.items()}}
        base["p50"] = self.percentile(0.50)
        base["p95"] = self.percentile(0.95)
        base["p99"] = self.percentile(0.99)
        return base


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

#: Catch-all label set a name's instruments fold into once it exceeds
#: the registry's per-name cardinality cap.
OVERFLOW_LABELS = {"overflow": "true"}
_OVERFLOW_COUNTER = "repro_obs_label_overflow_total"


class MetricsRegistry:
    """Get-or-create instrument registry keyed by ``(name, labels)``.

    One process-wide instance (:data:`REGISTRY`) backs the whole stack;
    separate instances exist only for tests. Re-registering a name with
    a different instrument kind raises — a name means one thing.

    Label cardinality is bounded: once a name has ``max_label_sets``
    distinct label sets, further *new* label sets fold into one
    ``{overflow="true"}`` catch-all instrument (per name) and each
    folded lookup bumps ``repro_obs_label_overflow_total`` — a
    per-``session_id``-style label can no longer leak instruments
    forever, and the leak is visible instead of silent. Existing label
    sets keep resolving normally.
    """

    def __init__(self, max_label_sets: int = 1024):
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple[str, str], _Instrument] = {}
        self._kinds: Dict[str, str] = {}
        self._label_counts: Dict[str, int] = {}
        self.max_label_sets = int(max_label_sets)
        self.enabled = True

    def _overflow_counter_locked(self) -> Counter:
        key = (_OVERFLOW_COUNTER, "")
        inst = self._instruments.get(key)
        if inst is None:
            inst = Counter(self, _OVERFLOW_COUNTER, {})
            self._instruments[key] = inst
            self._kinds[_OVERFLOW_COUNTER] = "counter"
            self._label_counts[_OVERFLOW_COUNTER] = 1
        return inst

    def _get(self, kind: str, name: str, labels: Dict[str, str]):
        key = (name, label_suffix(labels))
        with self._lock:
            seen = self._kinds.get(name)
            if seen is not None and seen != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {seen}, "
                    f"cannot re-register as {kind}")
            inst = self._instruments.get(key)
            if inst is None:
                if (labels != OVERFLOW_LABELS
                        and self._label_counts.get(name, 0)
                        >= self.max_label_sets):
                    overflow = self._overflow_counter_locked()
                    labels = dict(OVERFLOW_LABELS)
                    key = (name, label_suffix(labels))
                    inst = self._instruments.get(key)
                    # instrument locks differ from the registry lock,
                    # so bumping under it cannot deadlock
                    overflow.inc()
                    if inst is not None:
                        return inst
                inst = _KINDS[kind](self, name, labels)
                self._instruments[key] = inst
                self._kinds[name] = kind
                self._label_counts[name] = (
                    self._label_counts.get(name, 0) + 1)
            return inst

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._get("histogram", name, labels)

    def set_enabled(self, enabled: bool) -> None:
        self.enabled = bool(enabled)

    def reset(self) -> None:
        """Drop every instrument (tests / bench arms)."""
        with self._lock:
            self._instruments.clear()
            self._kinds.clear()
            self._label_counts.clear()

    def snapshot(self) -> Dict:
        """One labelled JSON-able document over every instrument."""
        with self._lock:
            instruments = list(self._instruments.values())
        out: Dict[str, List[Dict]] = {"counters": [], "gauges": [],
                                      "histograms": []}
        for inst in sorted(instruments, key=lambda i: i.key):
            entry = {"name": inst.name, "labels": dict(inst.labels)}
            if isinstance(inst, Counter):
                entry["value"] = inst.value
                out["counters"].append(entry)
            elif isinstance(inst, Gauge):
                entry["value"] = inst.value
                out["gauges"].append(entry)
            else:
                entry.update(inst.snapshot())
                out["histograms"].append(entry)
        return out

    def flat(self) -> Dict[str, float]:
        """``{"name{labels}": value}`` convenience view (histograms
        expand to ``name_count`` / ``name_sum`` keys)."""
        snap = self.snapshot()
        out: Dict[str, float] = {}
        for e in snap["counters"] + snap["gauges"]:
            out[e["name"] + label_suffix(e["labels"])] = e["value"]
        for e in snap["histograms"]:
            sfx = label_suffix(e["labels"])
            out[e["name"] + "_count" + sfx] = e["count"]
            out[e["name"] + "_sum" + sfx] = e["sum"]
        return out


#: The process-wide registry every component dual-writes into.
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY


def snapshot() -> Dict:
    """Module-level shorthand: the unified labelled snapshot."""
    return REGISTRY.snapshot()
