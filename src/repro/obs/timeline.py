"""Chrome-trace / Perfetto export for the fleet timeline.

Renders three sources onto one loadable timeline
(``chrome://tracing`` or https://ui.perfetto.dev):

- **Request/chunk span trees** (:class:`~repro.obs.trace.RequestTrace`
  JSONL docs): each trace becomes one *async* event tree (``ph``
  ``b``/``e`` with ``id`` = trace id) on the router process — async
  tracks may overlap freely, which concurrent requests do. The span
  model's exact-tiling invariant (children partition the root with
  shared endpoints) survives the export because the µs conversion is
  one linear map applied to identical floats;
  :func:`validate_chrome_trace` re-checks it on the exported doc.
- **Per-flush breakdowns** (:class:`~repro.server.stats.FlushRecord`
  with ``t_start``): complete (``ph`` ``X``) slices on one pid per
  replica, tid per worker thread. A replica's worker serializes its
  flushes, so ``X`` slices never overlap; ``prep``/``dispatch``/
  ``sync`` render as contained child slices.
- **Warmup compile records** (``QuantizedEngine.warmup_report`` with
  ``t0``): ``X`` slices on the owning replica's worker lane, so a
  compile storm is visibly a wall of slices.

Timestamps are monotonic seconds rebased to the earliest event and
scaled to µs (floats; Chrome's format takes fractional µs).
Wall-clock never enters the timeline — only the exported doc's
``otherData`` stamp.
"""
from __future__ import annotations

import json
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["chrome_trace", "write_chrome_trace", "validate_chrome_trace",
           "ROUTER_PID", "replica_pid"]

#: pid hosting the async request/chunk trees (queues live router-side).
ROUTER_PID = 1
_REPLICA_PID0 = 100
_TID_WORKER = 1
_TID_CHUNKS = 2


def replica_pid(replica_id) -> int:
    try:
        return _REPLICA_PID0 + int(replica_id)
    except (TypeError, ValueError):
        return _REPLICA_PID0


def _get(rec, field: str, default=None):
    """Field access for dataclass records and plain dicts alike."""
    if isinstance(rec, dict):
        return rec.get(field, default)
    return getattr(rec, field, default)


def _t_base(traces: Sequence[Dict], flushes: Sequence,
            warmup: Sequence) -> float:
    t0s = [t.get("t0") for t in traces if t.get("t0") is not None]
    t0s += [_get(f, "t_start", 0.0) for f in flushes
            if _get(f, "t_start", 0.0) > 0.0]
    t0s += [_get(w, "t0", 0.0) for w in warmup
            if _get(w, "t0", 0.0) > 0.0]
    return min(t0s) if t0s else 0.0


def chrome_trace(traces: Sequence[Dict] = (),
                 flushes: Sequence = (),
                 warmup: Sequence = ()) -> Dict:
    """Build a Chrome-trace JSON object (``{"traceEvents": [...]}``).

    ``traces`` are JSONL trace docs (``RequestTrace.to_json`` /
    ``load_traces``); ``flushes`` are :class:`FlushRecord` objects or
    dicts (records without ``t_start`` predate the timeline plane and
    are skipped); ``warmup`` entries are ``warmup_report`` dicts, with
    an optional ``replica`` key (``ClusterPool.warmup_records`` adds
    it)."""
    traces = list(traces)
    flushes = list(flushes)
    warmup = list(warmup)
    base = _t_base(traces, flushes, warmup)

    def us(t: float) -> float:
        return (t - base) * 1e6

    events: List[Dict] = []
    pids: Dict[int, str] = {ROUTER_PID: "router/queues"}
    tids: Dict[Tuple[int, int], str] = {(ROUTER_PID, _TID_WORKER):
                                        "requests"}
    n_skipped_flushes = 0

    # ---- request/chunk span trees as async b/e trees ----------------
    for doc in traces:
        tid_ = doc.get("trace_id", "?")
        kind = doc.get("kind", "request")
        root_args = {"status": doc.get("status", ""),
                     "hops": doc.get("hops", 0)}
        root_args.update(doc.get("attrs") or {})
        common = {"cat": kind, "id": tid_, "pid": ROUTER_PID,
                  "tid": _TID_WORKER}
        t0, t1 = doc.get("t0"), doc.get("t1")
        if t0 is None or t1 is None:
            continue
        events.append({"ph": "b", "name": kind, "ts": us(t0),
                       "args": root_args, **common})
        for span in doc.get("spans", ()):
            if span.get("parent_id") is None:
                continue  # the root span IS the b/e envelope above
            events.append({"ph": "b", "name": span["name"],
                           "ts": us(span["t0"]),
                           "args": dict(span.get("attrs") or {}),
                           **common})
            events.append({"ph": "e", "name": span["name"],
                           "ts": us(span["t1"]), **common})
        events.append({"ph": "e", "name": kind, "ts": us(t1), **common})
        for ev in doc.get("events", ()):
            attrs = dict(ev.get("attrs") or {})
            rep = attrs.get("replica")
            pid = replica_pid(rep) if rep is not None else ROUTER_PID
            if rep is not None:
                pids.setdefault(pid, f"replica {rep}")
                tids.setdefault((pid, _TID_WORKER), "worker")
            events.append({"ph": "i", "s": "p", "name": ev.get("name", ""),
                           "ts": us(ev.get("t", t0)), "pid": pid,
                           "tid": _TID_WORKER,
                           "args": {"trace_id": tid_, **attrs}})

    # ---- flush slices on replica worker lanes -----------------------
    for rec in flushes:
        t_start = float(_get(rec, "t_start", 0.0) or 0.0)
        if t_start <= 0.0:
            n_skipped_flushes += 1
            continue
        rep = _get(rec, "replica_id", 0)
        pid = replica_pid(rep)
        pids.setdefault(pid, f"replica {rep}")
        tids.setdefault((pid, _TID_WORKER), "worker")
        service = float(_get(rec, "service_s", 0.0) or 0.0)
        reason = _get(rec, "reason", "")
        events.append({
            "ph": "X", "name": f"flush[{reason}]", "pid": pid,
            "tid": _TID_WORKER, "ts": us(t_start), "dur": service * 1e6,
            "args": {"capacity": _get(rec, "capacity", 0),
                     "n_requests": _get(rec, "n_requests", 0),
                     "batch_size": _get(rec, "batch_size", 0),
                     "queue_depth": _get(rec, "queue_depth", 0),
                     "wait_ms": float(_get(rec, "wait_s", 0.0) or 0.0)
                     * 1e3,
                     "path": _get(rec, "path", "")}})
        cursor = t_start
        for seg in ("prep", "dispatch", "sync"):
            dur = float(_get(rec, f"{seg}_s", 0.0) or 0.0)
            if dur <= 0.0:
                continue
            events.append({"ph": "X", "name": seg, "pid": pid,
                           "tid": _TID_WORKER, "ts": us(cursor),
                           "dur": dur * 1e6, "args": {}})
            cursor += dur

    # ---- warmup compile slices --------------------------------------
    for rec in warmup:
        t0 = float(_get(rec, "t0", 0.0) or 0.0)
        if t0 <= 0.0:
            continue
        rep = _get(rec, "replica", 0)
        pid = replica_pid(rep)
        pids.setdefault(pid, f"replica {rep}")
        tids.setdefault((pid, _TID_WORKER), "worker")
        events.append({
            "ph": "X",
            "name": f"compile {_get(rec, 'path', '')} "
                    f"b{_get(rec, 'bucket', 0)}"
                    f"x{_get(rec, 'batch_size', 0)}",
            "pid": pid, "tid": _TID_WORKER, "ts": us(t0),
            "dur": float(_get(rec, "seconds", 0.0) or 0.0) * 1e6,
            "args": {"mode": _get(rec, "mode", ""),
                     "bucket": _get(rec, "bucket", 0)}})

    # ---- metadata ---------------------------------------------------
    meta: List[Dict] = []
    for pid, name in sorted(pids.items()):
        meta.append({"ph": "M", "name": "process_name", "pid": pid,
                     "tid": 0, "args": {"name": name}})
        meta.append({"ph": "M", "name": "process_sort_index", "pid": pid,
                     "tid": 0, "args": {"sort_index": pid}})
    for (pid, tid), name in sorted(tids.items()):
        meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tid, "args": {"name": name}})

    return {"traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs.timeline",
                          "t_base_monotonic": base,
                          "exported_at": time.time(),
                          "n_traces": len(traces),
                          "n_flushes": len(flushes) - n_skipped_flushes,
                          "n_flushes_skipped": n_skipped_flushes,
                          "n_warmup": len(warmup)}}


def write_chrome_trace(path: str, traces: Sequence[Dict] = (),
                       flushes: Sequence = (),
                       warmup: Sequence = ()) -> Dict:
    """Build and write the Chrome-trace doc; returns it."""
    doc = chrome_trace(traces, flushes=flushes, warmup=warmup)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


# --------------------------------------------------------------------------
# validation


_PH_REQUIRED = {
    "X": ("name", "pid", "tid", "ts", "dur"),
    "b": ("name", "pid", "tid", "ts", "cat", "id"),
    "e": ("name", "pid", "tid", "ts", "cat", "id"),
    "i": ("name", "pid", "tid", "ts"),
    "M": ("name", "pid", "args"),
}


def validate_chrome_trace(doc: Dict, tol_us: float = 0.5) -> Dict:
    """Schema + invariant check on an exported Chrome-trace doc.

    Verifies (1) every event carries the fields its phase requires and
    ``X`` durations are non-negative; (2) for every async tree, the
    depth-1 child intervals tile the root *exactly* — shared endpoints
    as identical floats — and (3) the child durations sum to the root
    duration within ``tol_us`` (the span-sum == e2e-latency invariant,
    re-checked after export). Returns a verdict dict with violation
    counts; ``ok`` is True only when everything passes."""
    errors: List[str] = []
    n_events = 0
    trees: Dict[Tuple[str, str], List[Dict]] = {}
    for i, ev in enumerate(doc.get("traceEvents", ())):
        n_events += 1
        ph = ev.get("ph")
        req = _PH_REQUIRED.get(ph)
        if req is None:
            errors.append(f"event {i}: unknown ph {ph!r}")
            continue
        missing = [k for k in req if k not in ev]
        if missing:
            errors.append(f"event {i} (ph={ph}): missing {missing}")
            continue
        if ph == "X" and ev["dur"] < 0:
            errors.append(f"event {i}: negative dur {ev['dur']}")
        if ph in ("b", "e"):
            trees.setdefault((ev["cat"], ev["id"]), []).append(ev)

    tiling_violations = 0
    sum_violations = 0
    max_sum_err = 0.0
    n_trees = 0
    for (cat, tid_), evs in trees.items():
        # events were emitted in document order: b(root) [b/e children] e(root)
        stack: List[Dict] = []
        root: Optional[Tuple[float, float]] = None
        children: List[Tuple[float, float]] = []
        bad = False
        for ev in evs:
            if ev["ph"] == "b":
                stack.append(ev)
            else:
                if not stack:
                    errors.append(f"tree {cat}/{tid_}: unbalanced 'e'")
                    bad = True
                    break
                b = stack.pop()
                pair = (b["ts"], ev["ts"])
                if len(stack) == 0:
                    root = pair
                elif len(stack) == 1:
                    children.append(pair)
        if bad or stack or root is None:
            if stack:
                errors.append(f"tree {cat}/{tid_}: unbalanced 'b'")
            continue
        n_trees += 1
        if not children:
            continue
        children.sort()
        edges = [root[0]] + [c[1] for c in children]
        starts = [c[0] for c in children] + [root[1]]
        # exact tiling: each child starts where the previous ended,
        # first at the root start, last ends at the root end
        if any(a != b for a, b in zip(edges, starts)):
            tiling_violations += 1
        span_sum = sum(c[1] - c[0] for c in children)
        err = abs(span_sum - (root[1] - root[0]))
        max_sum_err = max(max_sum_err, err)
        if err > tol_us:
            sum_violations += 1

    return {"ok": (not errors and tiling_violations == 0
                   and sum_violations == 0),
            "n_events": n_events,
            "n_async_trees": n_trees,
            "schema_errors": errors[:20],
            "n_schema_errors": len(errors),
            "tiling_violations": tiling_violations,
            "sum_violations": sum_violations,
            "max_sum_err_us": max_sum_err}
