"""Declarative SLOs with multi-window burn-rate alerting.

PR 9 gave the stack a passive collection plane; this module is the
half that *judges* it. An :class:`SLO` declares an objective over the
:class:`~repro.obs.metrics.MetricsRegistry` — a bad/total ratio, a
windowed latency quantile, a physics level gauge, or a discrete event
counter — and an :class:`SLOEvaluator` samples the registry on a
cadence, evaluates every SLO against the sampled history, and emits
typed :class:`Alert` objects (with the metric evidence attached) into
an :class:`AlertBus` on each ok->breached edge.

Burn-rate semantics (ratio SLOs) follow the Prometheus / SRE-workbook
multi-window pattern: the bad fraction is computed over a *fast* and a
*slow* trailing window from counter deltas between registry snapshots,
normalised by the objective into a burn rate, and the SLO breaches
only when **both** windows burn above ``burn_threshold`` — the slow
window keeps one bad blip from paging, the fast window ends the alert
quickly once the system recovers. Windowed quantile SLOs subtract
log-bucket histograms at the two window edges, so an old latency storm
ages out of the readout instead of polluting the cumulative p99
forever.

Everything here is stdlib-only and side-effect free against the
serving hot path: evaluation *reads* snapshots; the only writes are
the ``slo_breached{slo=...}`` status gauges and the
``repro_obs_alerts_total`` counter bumped by the bus.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.obs import metrics as _metrics
from repro.obs.metrics import REGISTRY, MetricsRegistry

__all__ = [
    "Alert", "AlertBus", "SLO", "SLOEvaluator", "HealthMonitor",
    "SampleWindow", "default_slos",
]


# --------------------------------------------------------------------------
# alerts


@dataclasses.dataclass(frozen=True)
class Alert:
    """One typed, attributed health event.

    ``source`` is ``"slo"`` or ``"anomaly"``; ``evidence`` carries the
    metric readouts that justified the alert (window deltas, burn
    rates, per-label values) so a subscriber — or a human reading the
    ``--alerts-out`` JSONL — can attribute it without re-deriving."""
    name: str
    severity: str              # "page" | "warn" | "info"
    source: str                # "slo" | "anomaly"
    message: str
    value: float = 0.0
    threshold: float = 0.0
    t: float = 0.0             # monotonic evaluation time
    wall_time: float = 0.0     # time.time() at emission
    labels: Mapping[str, str] = dataclasses.field(default_factory=dict)
    evidence: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return {
            "name": self.name, "severity": self.severity,
            "source": self.source, "message": self.message,
            "value": self.value, "threshold": self.threshold,
            "t": self.t, "wall_time": self.wall_time,
            "labels": dict(self.labels),
            "evidence": dict(self.evidence),
        }


class AlertBus:
    """Fan-out hub for alerts: bounded history + subscriber callbacks.

    Subscribers must not raise — if one does, the exception is swallowed
    and counted, because an alert consumer must never take down the
    evaluation loop (let alone serving). ``subscribe`` returns an
    unsubscribe callable. Every published alert also bumps
    ``repro_obs_alerts_total{name=,severity=}``."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 history: int = 256):
        self._lock = threading.Lock()
        self._subs: List[Callable[[Alert], None]] = []
        self._history: deque = deque(maxlen=history)
        self._counts: Dict[str, int] = {}
        self.registry = registry if registry is not None else REGISTRY
        self.n_published = 0
        self.n_subscriber_errors = 0

    def subscribe(self, fn: Callable[[Alert], None]) -> Callable[[], None]:
        with self._lock:
            self._subs.append(fn)

        def _unsubscribe() -> None:
            with self._lock:
                if fn in self._subs:
                    self._subs.remove(fn)
        return _unsubscribe

    def publish(self, alert: Alert) -> None:
        with self._lock:
            self._history.append(alert)
            self._counts[alert.name] = self._counts.get(alert.name, 0) + 1
            self.n_published += 1
            subs = list(self._subs)
        self.registry.counter("repro_obs_alerts_total",
                              alert=alert.name,
                              severity=alert.severity).inc()
        for fn in subs:
            try:
                fn(alert)
            except Exception:
                with self._lock:
                    self.n_subscriber_errors += 1

    def history(self) -> List[Alert]:
        with self._lock:
            return list(self._history)

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)


# --------------------------------------------------------------------------
# snapshot sampling


def _match(labels: Mapping[str, str], where: Mapping[str, str]) -> bool:
    return all(labels.get(k) == v for k, v in where.items())


class _Sample:
    """One timestamped, indexed registry snapshot."""
    __slots__ = ("t", "counters", "gauges", "hists")

    def __init__(self, t: float, snapshot: Dict):
        self.t = t
        self.counters: Dict[str, List[Tuple[Dict, float]]] = {}
        self.gauges: Dict[str, List[Tuple[Dict, float]]] = {}
        self.hists: Dict[str, List[Tuple[Dict, Dict]]] = {}
        for e in snapshot.get("counters", ()):
            self.counters.setdefault(e["name"], []).append(
                (e["labels"], e["value"]))
        for e in snapshot.get("gauges", ()):
            self.gauges.setdefault(e["name"], []).append(
                (e["labels"], e["value"]))
        for e in snapshot.get("histograms", ()):
            self.hists.setdefault(e["name"], []).append((e["labels"], e))

    def counter_sum(self, name: str, where: Mapping[str, str]) -> float:
        return sum(v for lb, v in self.counters.get(name, ())
                   if _match(lb, where))

    def gauge_values(self, name: str, where: Mapping[str, str]
                     ) -> List[Tuple[Dict, float]]:
        return [(lb, v) for lb, v in self.gauges.get(name, ())
                if _match(lb, where)]

    def hist_agg(self, name: str, where: Mapping[str, str]
                 ) -> Tuple[int, float, Dict[str, int]]:
        """Summed ``(count, sum, buckets)`` over matching label sets."""
        count, total = 0, 0.0
        buckets: Dict[str, int] = {}
        for lb, e in self.hists.get(name, ()):
            if not _match(lb, where):
                continue
            count += int(e.get("count", 0))
            total += float(e.get("sum", 0.0))
            for k, n in (e.get("buckets") or {}).items():
                buckets[k] = buckets.get(k, 0) + int(n)
        return count, total, buckets


class SampleWindow:
    """Bounded deque of timestamped registry samples with windowed
    delta readouts. Shared by the SLO evaluator and the anomaly
    monitor (:mod:`repro.obs.anomaly`)."""

    def __init__(self, maxlen: int = 512):
        self.samples: deque = deque(maxlen=maxlen)

    def sample(self, registry: MetricsRegistry,
               now: Optional[float] = None) -> _Sample:
        s = _Sample(time.monotonic() if now is None else now,
                    registry.snapshot())
        self.samples.append(s)
        return s

    @property
    def latest(self) -> Optional[_Sample]:
        return self.samples[-1] if self.samples else None

    @property
    def previous(self) -> Optional[_Sample]:
        return self.samples[-2] if len(self.samples) >= 2 else None

    def at_or_before(self, t: float,
                     allow_partial: bool = False) -> Optional[_Sample]:
        """Newest sample with ``sample.t <= t`` — the far edge of a
        trailing window ending at the latest sample. ``allow_partial``
        falls back to the oldest sample when the history does not yet
        span the window (rates are then over the available history —
        still sound, just a shorter window)."""
        best = None
        for s in self.samples:
            if s.t <= t + 1e-9:
                best = s
            else:
                break
        if best is None and allow_partial and self.samples:
            best = self.samples[0]
        return best

    def counter_delta(self, name: str, where: Mapping[str, str],
                      window_s: float, allow_partial: bool = False
                      ) -> Optional[float]:
        """Counter increase over the trailing window; None when the
        history does not cover the window (unless ``allow_partial``)."""
        now = self.latest
        if now is None:
            return None
        then = self.at_or_before(now.t - window_s, allow_partial)
        if then is None or then is now:
            return None
        return max(0.0, now.counter_sum(name, where)
                   - then.counter_sum(name, where))

    def hist_delta(self, name: str, where: Mapping[str, str],
                   window_s: float, allow_partial: bool = False
                   ) -> Optional[Tuple[int, float, Dict[str, int]]]:
        """Windowed ``(count, sum, buckets)`` histogram increase."""
        now = self.latest
        if now is None:
            return None
        then = self.at_or_before(now.t - window_s, allow_partial)
        if then is None or then is now:
            return None
        c1, s1, b1 = now.hist_agg(name, where)
        c0, s0, b0 = then.hist_agg(name, where)
        buckets = {k: n - b0.get(k, 0) for k, n in b1.items()
                   if n - b0.get(k, 0) > 0}
        return max(0, c1 - c0), max(0.0, s1 - s0), buckets


def quantile_from_buckets(buckets: Mapping[str, int], q: float) -> float:
    """Upper-edge quantile over a (possibly windowed-delta) log-bucket
    dict as emitted by ``Histogram.snapshot()["buckets"]`` — keys are
    stringified bucket indices, ``"u"`` for the underflow bucket."""
    total = sum(buckets.values())
    if total <= 0:
        return 0.0
    # None (underflow) sorts first, as in Histogram.percentile
    items = sorted((((None if k == "u" else int(k)), n)
                    for k, n in buckets.items()),
                   key=lambda kv: -math.inf if kv[0] is None else kv[0])
    target = q * total
    cum = 0
    for idx, n in items:
        cum += n
        if cum >= target:
            return _metrics.Histogram._bucket_edge(idx)
    return _metrics.Histogram._bucket_edge(items[-1][0])


# --------------------------------------------------------------------------
# SLO declaration + evaluation


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative service-level objective.

    ``kind`` selects the indicator:

    - ``"ratio"`` — bad/total counter fraction vs ``objective`` (the
      max acceptable bad fraction), multi-window burn-rate gated:
      breached only when ``(frac / objective) >= burn_threshold`` in
      BOTH the fast and the slow trailing window.
    - ``"quantile"`` — windowed histogram quantile ``q`` of ``metric``
      vs ``objective`` (an absolute bound, e.g. seconds), again gated
      on both windows.
    - ``"level"`` — latest value of gauge ``metric`` (max over matching
      label sets) vs ``objective``; no windows (a level is already a
      state, not a rate).
    - ``"event"`` — increase of counter ``metric`` since the previous
      evaluation step vs ``objective`` (default 0: any new event
      breaches). The first step arms the baseline, so events that
      pre-date the evaluator never fire.

    ``where`` / ``bad_where`` / ``total_where`` are label-subset
    filters; matching label sets are summed. ``allow_partial`` lets the
    windowed kinds evaluate before the history spans the slow window
    (short replays, startup) — strict coverage is the default."""
    name: str
    kind: str                                   # ratio|quantile|level|event
    description: str = ""
    severity: str = "page"
    metric: str = ""                            # quantile/level/event
    where: Mapping[str, str] = dataclasses.field(default_factory=dict)
    bad: str = ""                               # ratio: bad counter
    bad_where: Mapping[str, str] = dataclasses.field(default_factory=dict)
    total: str = ""                             # ratio: total counter
    total_where: Mapping[str, str] = dataclasses.field(default_factory=dict)
    objective: float = 0.0
    q: float = 0.99
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    burn_threshold: float = 1.0
    min_events: int = 1
    allow_partial: bool = False
    runbook: str = ""

    def __post_init__(self):
        if self.kind not in ("ratio", "quantile", "level", "event"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if self.kind == "ratio" and not (self.bad and self.total):
            raise ValueError(f"ratio SLO {self.name!r} needs bad+total")
        if self.kind in ("quantile", "level", "event") and not self.metric:
            raise ValueError(f"{self.kind} SLO {self.name!r} needs metric")


class SLOEvaluator:
    """Samples a registry and evaluates a catalogue of SLOs.

    ``step()`` takes one snapshot, re-evaluates every SLO, publishes an
    :class:`Alert` per ok->breached edge (edge-triggered: a breach that
    persists does not re-page; it re-arms once the SLO clears), writes
    ``slo_breached{slo=...}`` status gauges, and returns the alerts it
    published this step. Pass ``now`` explicitly for deterministic
    tests."""

    def __init__(self, slos: Iterable[SLO],
                 registry: Optional[MetricsRegistry] = None,
                 bus: Optional[AlertBus] = None,
                 max_samples: int = 512):
        self.slos = list(slos)
        names = [s.name for s in self.slos]
        if len(set(names)) != len(names):
            raise ValueError("duplicate SLO names in catalogue")
        self.registry = registry if registry is not None else REGISTRY
        self.bus = bus
        self.window = SampleWindow(maxlen=max_samples)
        self._breached: Dict[str, bool] = {}
        self._event_base: Dict[str, Optional[float]] = {}
        self._status: Dict[str, Dict] = {}

    # -- per-kind evaluation ------------------------------------------

    def _eval_ratio(self, slo: SLO) -> Dict:
        out = {"breached": False, "value": 0.0, "evaluable": False,
               "evidence": {}}
        burns = {}
        for tag, w in (("fast", slo.fast_window_s),
                       ("slow", slo.slow_window_s)):
            bad = self.window.counter_delta(
                slo.bad, slo.bad_where, w, slo.allow_partial)
            tot = self.window.counter_delta(
                slo.total, slo.total_where, w, slo.allow_partial)
            if bad is None or tot is None:
                return out  # history does not cover the slow window yet
            frac = (bad / tot) if (tot >= slo.min_events
                                   and tot > 0) else 0.0
            burn = frac / max(slo.objective, 1e-12)
            burns[tag] = burn
            out["evidence"][f"{tag}_window_s"] = w
            out["evidence"][f"{tag}_bad"] = bad
            out["evidence"][f"{tag}_total"] = tot
            out["evidence"][f"{tag}_burn"] = burn
        out["evaluable"] = True
        out["value"] = burns["fast"]
        out["breached"] = (burns["fast"] >= slo.burn_threshold
                           and burns["slow"] >= slo.burn_threshold)
        return out

    def _eval_quantile(self, slo: SLO) -> Dict:
        out = {"breached": False, "value": 0.0, "evaluable": False,
               "evidence": {}}
        qs = {}
        for tag, w in (("fast", slo.fast_window_s),
                       ("slow", slo.slow_window_s)):
            d = self.window.hist_delta(
                slo.metric, slo.where, w, slo.allow_partial)
            if d is None:
                return out
            count, _, buckets = d
            if count < slo.min_events:
                qs[tag] = 0.0
            else:
                qs[tag] = quantile_from_buckets(buckets, slo.q)
            out["evidence"][f"{tag}_window_s"] = w
            out["evidence"][f"{tag}_count"] = count
            out["evidence"][f"{tag}_q{slo.q:g}"] = qs[tag]
        out["evaluable"] = True
        out["value"] = qs["fast"]
        out["breached"] = (qs["fast"] > slo.objective
                          and qs["slow"] > slo.objective)
        return out

    def _eval_level(self, slo: SLO) -> Dict:
        out = {"breached": False, "value": 0.0, "evaluable": False,
               "evidence": {}}
        now = self.window.latest
        if now is None:
            return out
        vals = now.gauge_values(slo.metric, slo.where)
        if not vals:
            return out  # gauge never written: objective not armed
        level = max(v for _, v in vals)
        out["evaluable"] = True
        out["value"] = level
        out["breached"] = level > slo.objective
        out["evidence"]["levels"] = {
            _metrics.label_suffix(lb) or "{}": v for lb, v in vals}
        return out

    def _eval_event(self, slo: SLO) -> Dict:
        out = {"breached": False, "value": 0.0, "evaluable": False,
               "evidence": {}}
        now = self.window.latest
        if now is None:
            return out
        cur = now.counter_sum(slo.metric, slo.where)
        base = self._event_base.get(slo.name)
        self._event_base[slo.name] = cur
        if base is None:
            return out  # first step arms the baseline
        delta = max(0.0, cur - base)
        out["evaluable"] = True
        out["value"] = delta
        out["breached"] = delta > slo.objective
        out["evidence"]["delta"] = delta
        out["evidence"]["cumulative"] = cur
        return out

    _EVAL = {"ratio": _eval_ratio, "quantile": _eval_quantile,
             "level": _eval_level, "event": _eval_event}

    # -- stepping ------------------------------------------------------

    def step(self, now: Optional[float] = None) -> List[Alert]:
        self.window.sample(self.registry, now)
        t = self.window.latest.t
        alerts: List[Alert] = []
        for slo in self.slos:
            # one misconfigured SLO must not kill the rest of the
            # catalogue — isolate, surface, keep evaluating
            try:
                res = self._EVAL[slo.kind](self, slo)
            except Exception as e:
                self.registry.counter("repro_obs_health_eval_errors_total",
                                      stepper="slo", slo=slo.name).inc()
                self._status[slo.name] = {
                    "kind": slo.kind, "severity": slo.severity,
                    "breached": False, "evaluable": False, "errored": True,
                    "error": f"{type(e).__name__}: {e}",
                    "value": 0.0, "objective": slo.objective, "t": t,
                }
                continue
            breached = bool(res["breached"])
            was = self._breached.get(slo.name, False)
            self._breached[slo.name] = breached
            self._status[slo.name] = {
                "kind": slo.kind, "severity": slo.severity,
                "breached": breached, "evaluable": res["evaluable"],
                "value": res["value"], "objective": slo.objective,
                "t": t,
            }
            self.registry.gauge("slo_breached", slo=slo.name).set(
                1.0 if breached else 0.0)
            if breached and not was:
                evidence = dict(res["evidence"])
                evidence["slo_kind"] = slo.kind
                alerts.append(Alert(
                    name=slo.name, severity=slo.severity, source="slo",
                    message=(slo.description or slo.name)
                    + f": value {res['value']:.6g} vs objective "
                      f"{slo.objective:.6g}",
                    value=float(res["value"]), threshold=slo.objective,
                    t=t, wall_time=time.time(),
                    labels={"slo": slo.name}, evidence=evidence))
        if self.bus is not None:
            for a in alerts:
                self.bus.publish(a)
        return alerts

    def status(self) -> Dict[str, Dict]:
        """Latest per-SLO readout (breached / value / evaluable)."""
        return {k: dict(v) for k, v in self._status.items()}


# --------------------------------------------------------------------------
# background monitor


class HealthMonitor:
    """Drives one or more steppers (:class:`SLOEvaluator`,
    :class:`~repro.obs.anomaly.AnomalyMonitor`) on a background
    interval thread. ``step_all(now)`` is the synchronous path for
    deterministic tests and final flushes."""

    def __init__(self, steppers: Iterable, interval_s: float = 1.0):
        self.steppers = list(steppers)
        self.interval_s = max(0.02, float(interval_s))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.n_steps = 0

    def step_all(self, now: Optional[float] = None) -> List[Alert]:
        alerts: List[Alert] = []
        for s in self.steppers:
            try:
                alerts.extend(s.step(now))
            except Exception:
                # health evaluation must never take down serving, but a
                # dead stepper must still be visible to the operator
                reg = getattr(s, "registry", None) or REGISTRY
                reg.counter("repro_obs_health_eval_errors_total",
                            stepper=type(s).__name__).inc()
        self.n_steps += 1
        return alerts

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.step_all()

    def start(self) -> "HealthMonitor":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="obs-health", daemon=True)
            self._thread.start()
        return self

    def stop(self, final_step: bool = True) -> None:
        self._stop.set()
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=5.0)
        if final_step:
            self.step_all()


# --------------------------------------------------------------------------
# catalogue


def default_slos(fast_window_s: float = 60.0,
                 slow_window_s: float = 300.0,
                 latency_p99_s: float = 0.5,
                 shed_objective: float = 0.01,
                 escalation_objective: float = 0.02,
                 frame_loss_objective: float = 1e-3,
                 allow_partial: bool = False) -> List[SLO]:
    """The stack's stock SLO catalogue (docs/observability.md has the
    table + runbooks). Thresholds are constructor knobs so short chaos
    replays can shrink the windows without redefining the catalogue."""
    w = dict(fast_window_s=fast_window_s, slow_window_s=slow_window_s,
             allow_partial=allow_partial)
    return [
        SLO(name="latency_p99", kind="quantile",
            metric="serve_request_latency_seconds",
            where={"kind": "request"}, q=0.99, objective=latency_p99_s,
            min_events=20, severity="page",
            description="windowed request p99 latency",
            runbook="check replica skew + compile storms in obs_top; "
                    "trace_report --chrome-trace for the flush timeline",
            **w),
        SLO(name="shed_rate", kind="ratio",
            bad="serve_requests_total", bad_where={"event": "shed"},
            total="serve_requests_total",
            total_where={"event": "submitted"},
            objective=shed_objective, severity="page",
            description="admission shed fraction",
            runbook="queue depths in obs_top; raise max_queue or "
                    "add replicas",
            **w),
        SLO(name="escalation_rate", kind="ratio",
            bad="pool_events_total", bad_where={"event": "escalated"},
            total="serve_requests_total",
            total_where={"event": "submitted"},
            objective=escalation_objective, severity="warn",
            description="guardrail escalation fraction",
            runbook="guard_snapshot per-detector counts; check input "
                    "distribution vs calibration (docs/guardrails.md)",
            **w),
        SLO(name="session_frame_loss", kind="ratio",
            bad="session_frames_total", bad_where={"event": "lost"},
            total="session_frames_total", total_where={},
            objective=frame_loss_objective, severity="page",
            description="MD session frame loss fraction",
            runbook="sessions stats + checkpoint lag; resume from "
                    "last checkpoint (docs/sessions.md)",
            **w),
        SLO(name="md_energy_drift", kind="level",
            metric="md_energy_drift_ratio", objective=1.0,
            severity="page",
            description="MD energy drift vs configured limit",
            runbook="session escalates the chunk a tier up; if w8a8 "
                    "still drifts, shrink dt or check the artifact",
            ),
        SLO(name="lee_probe_level", kind="level",
            metric="engine_lee_probe_level", objective=1.0,
            severity="warn",
            description="local equivariance error probe vs limit",
            runbook="LEE above limit means quantization broke "
                    "SO(3) consistency: recalibrate / raise bits",
            ),
        SLO(name="replica_failure", kind="event",
            metric="pool_events_total",
            where={"event": "replica_failure"}, objective=0.0,
            severity="page", description="replica worker died",
            runbook="pool respawns + requeues automatically; check "
                    "the replica's last flush in the timeline",
            ),
        SLO(name="replica_stall", kind="event",
            metric="pool_events_total",
            where={"event": "stall_detected"}, objective=0.0,
            severity="page", description="replica stalled past "
            "stall_timeout_s (watchdog quarantined it)",
            runbook="usually a wedged device dispatch; inspect the "
                    "quarantined replica's flush breakdown",
            ),
    ]
