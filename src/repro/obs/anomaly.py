"""Rolling-window anomaly detectors for things SLOs can't pre-declare.

An SLO needs a known objective; these detectors instead learn a
baseline online and flag *change*: queue-depth runaway, compile storms
(warmup/compile histogram spikes mid-serving), per-replica latency
skew, and escalation-rate trend breaks. Two statistics back them:

- :class:`EwmaZScore` — exponentially-weighted mean/variance with a
  z-score readout against the pre-update baseline.
- :func:`robust_zscore` — median/MAD z-score over a bounded history;
  with a constant baseline (MAD 0) any departure scores ``inf``, which
  is exactly the semantics a compile-storm detector wants ("steady
  state is zero compiles; any compile is a spike").

Detectors read the same :class:`~repro.obs.slo.SampleWindow` snapshot
history the SLO evaluator uses, operate on *deltas* between samples
(so pre-existing counter totals never fire), are edge-triggered, and
carry explicit floors (``min_depth``, ``min_events``) so a quiet
system cannot alert on noise — the chaos bench's clean arm gates that
property at zero false positives.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from typing import Dict, List, Mapping, Optional

from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.slo import Alert, AlertBus, SampleWindow

__all__ = [
    "EwmaZScore", "robust_zscore", "Detector", "QueueDepthRunaway",
    "CompileStorm", "ReplicaLatencySkew", "EscalationTrend",
    "AnomalyMonitor", "default_detectors",
]


class EwmaZScore:
    """Online EWMA mean/variance with z-score against the baseline.

    ``score(x)`` is evaluated BEFORE ``update(x)`` folds the point in,
    so a spike is judged against the pre-spike baseline. Needs
    ``min_points`` updates before it scores (returns 0.0 until then)."""

    def __init__(self, alpha: float = 0.3, min_points: int = 3,
                 eps: float = 1e-9):
        self.alpha = float(alpha)
        self.min_points = int(min_points)
        self.eps = float(eps)
        self.mean = 0.0
        self.var = 0.0
        self.n = 0

    def score(self, x: float) -> float:
        if self.n < self.min_points:
            return 0.0
        return (x - self.mean) / math.sqrt(self.var + self.eps)

    def update(self, x: float) -> None:
        if self.n == 0:
            self.mean = float(x)
            self.var = 0.0
        else:
            d = x - self.mean
            incr = self.alpha * d
            self.mean += incr
            self.var = (1.0 - self.alpha) * (self.var + d * incr)
        self.n += 1


def robust_zscore(history, x: float, eps: float = 1e-12) -> float:
    """Median/MAD z-score of ``x`` against ``history`` (MAD scaled by
    1.4826 to estimate sigma). A constant history (MAD 0) scores any
    departure as ``+/-inf`` and an exact match as 0.0."""
    xs = sorted(history)
    if not xs:
        return 0.0

    def _median(vals):
        m = len(vals) // 2
        return (vals[m] if len(vals) % 2
                else 0.5 * (vals[m - 1] + vals[m]))
    med = _median(xs)
    mad = _median(sorted(abs(v - med) for v in xs))
    if mad < eps:
        if abs(x - med) < eps:
            return 0.0
        return math.inf if x > med else -math.inf
    return (x - med) / (1.4826 * mad)


class Detector:
    """Base class: ``check(window)`` returns a breach dict (message,
    value, threshold, evidence) or None. Subclasses keep their own
    online state; the monitor handles edge-triggering + publishing."""
    name = "detector"
    severity = "warn"

    def check(self, window: SampleWindow) -> Optional[Dict]:
        raise NotImplementedError


class QueueDepthRunaway(Detector):
    """Total queue depth growing without bound: depth above an
    absolute floor AND strictly increasing for ``consecutive`` samples
    AND a robust z-score break vs the trailing depth history. The
    floor keeps an idle/low-rate system from ever firing."""
    name = "queue_depth_runaway"
    severity = "page"

    def __init__(self, gauge: str = "cluster_queue_depth",
                 min_depth: float = 8.0, consecutive: int = 3,
                 z_threshold: float = 4.0, history: int = 64):
        self.gauge = gauge
        self.min_depth = float(min_depth)
        self.consecutive = int(consecutive)
        self.z_threshold = float(z_threshold)
        self._depths: deque = deque(maxlen=history)

    def check(self, window: SampleWindow) -> Optional[Dict]:
        now = window.latest
        if now is None:
            return None
        depth = sum(v for _, v in now.gauge_values(self.gauge, {}))
        baseline = list(self._depths)
        self._depths.append(depth)
        if depth < self.min_depth:
            return None
        k = self.consecutive
        if len(baseline) < k + 2:
            return None
        recent = baseline[-k:] + [depth]
        if not all(b < a for b, a in zip(recent, recent[1:])):
            return None
        z = robust_zscore(baseline[:-k] or baseline, depth)
        if z <= self.z_threshold:
            return None
        return {"message": f"queue depth runaway: {depth:.0f} and "
                           f"rising for {k} samples (z={z:.2f})",
                "value": depth, "threshold": self.min_depth,
                "evidence": {"depth": depth, "z": z,
                             "recent": recent}}


class CompileStorm(Detector):
    """New XLA compiles observed mid-serving. Steady-state serving on a
    warmed bucket ladder performs zero compiles, so the baseline of
    per-sample compile-count deltas is 0 and any burst of
    ``min_compiles`` or more in one sampling interval fires."""
    name = "compile_storm"
    severity = "warn"

    def __init__(self, hist: str = "engine_warmup_compile_seconds",
                 min_compiles: int = 1, warm_samples: int = 2):
        self.hist = hist
        self.min_compiles = int(min_compiles)
        self.warm_samples = int(warm_samples)
        self._seen = 0

    def check(self, window: SampleWindow) -> Optional[Dict]:
        now, prev = window.latest, window.previous
        self._seen += 1
        if now is None or prev is None:
            return None
        c1, s1, _ = now.hist_agg(self.hist, {})
        c0, s0, _ = prev.hist_agg(self.hist, {})
        delta = c1 - c0
        # startup warmup lands between the first samples; don't page on it
        if self._seen <= self.warm_samples:
            return None
        if delta < self.min_compiles:
            return None
        return {"message": f"compile storm: {delta} new compile(s) "
                           f"({s1 - s0:.2f}s) in one interval",
                "value": float(delta),
                "threshold": float(self.min_compiles),
                "evidence": {"new_compiles": delta,
                             "compile_seconds": s1 - s0}}


class ReplicaLatencySkew(Detector):
    """One replica serving far slower than its peers: per-replica mean
    flush service time over a trailing window (from
    ``replica_flush_seconds{replica=...}`` deltas); fires when the
    slowest qualifying replica's mean exceeds ``ratio`` times the
    median of the qualifying means. Needs at least two replicas with
    ``min_events`` flushes in the window."""
    name = "replica_latency_skew"
    severity = "warn"

    def __init__(self, hist: str = "replica_flush_seconds",
                 ratio: float = 4.0, min_events: int = 8,
                 window_s: float = 10.0):
        self.hist = hist
        self.ratio = float(ratio)
        self.min_events = int(min_events)
        self.window_s = float(window_s)

    def check(self, window: SampleWindow) -> Optional[Dict]:
        now = window.latest
        if now is None:
            return None
        then = window.at_or_before(now.t - self.window_s,
                                   allow_partial=True)
        if then is None or then is now:
            return None
        means: Dict[str, float] = {}
        for lb, e in now.hists.get(self.hist, ()):
            rep = lb.get("replica", "?")
            c0, s0, _ = then.hist_agg(self.hist, {"replica": rep})
            dc = int(e.get("count", 0)) - c0
            ds = float(e.get("sum", 0.0)) - s0
            if dc >= self.min_events:
                means[rep] = ds / dc
        if len(means) < 2:
            return None
        vals = sorted(means.values())
        med = vals[len(vals) // 2] if len(vals) % 2 else 0.5 * (
            vals[len(vals) // 2 - 1] + vals[len(vals) // 2])
        worst_rep = max(means, key=means.get)
        worst = means[worst_rep]
        if med <= 0 or worst < self.ratio * med:
            return None
        return {"message": f"replica {worst_rep} mean flush "
                           f"{worst * 1e3:.2f}ms vs fleet median "
                           f"{med * 1e3:.2f}ms",
                "value": worst / med, "threshold": self.ratio,
                "evidence": {"means_ms":
                             {r: m * 1e3 for r, m in means.items()},
                             "worst_replica": worst_rep}}


class EscalationTrend(Detector):
    """Escalation-rate trend break: robust z-score of the current
    per-sample escalation delta against the trailing delta history.
    A quiet fleet has an all-zero baseline, so the first escalation
    burst scores ``inf`` and fires; a persistently-escalating fleet
    folds the rate into the baseline and the alert clears (this is a
    change detector — the sustained level is ``escalation_rate``'s
    SLO to judge)."""
    name = "escalation_trend"
    severity = "warn"

    def __init__(self, counter: str = "pool_events_total",
                 where: Optional[Mapping[str, str]] = None,
                 z_threshold: float = 3.0, min_delta: float = 1.0,
                 history: int = 64):
        self.counter = counter
        self.where = dict(where) if where else {"event": "escalated"}
        self.z_threshold = float(z_threshold)
        self.min_delta = float(min_delta)
        self._deltas: deque = deque(maxlen=history)
        self._prev: Optional[float] = None

    def check(self, window: SampleWindow) -> Optional[Dict]:
        now = window.latest
        if now is None:
            return None
        cur = now.counter_sum(self.counter, self.where)
        prev, self._prev = self._prev, cur
        if prev is None:
            return None  # first sample arms the baseline
        delta = max(0.0, cur - prev)
        baseline = list(self._deltas)
        self._deltas.append(delta)
        if delta < self.min_delta or len(baseline) < 3:
            return None
        z = robust_zscore(baseline, delta)
        if z <= self.z_threshold:
            return None
        return {"message": f"escalation trend break: {delta:.0f} "
                           f"escalation(s) this interval (z={z:.2f})",
                "value": delta, "threshold": self.min_delta,
                "evidence": {"delta": delta, "z": z,
                             "cumulative": cur}}


def default_detectors() -> List[Detector]:
    return [QueueDepthRunaway(), CompileStorm(), ReplicaLatencySkew(),
            EscalationTrend()]


class AnomalyMonitor:
    """Steps a set of detectors over fresh registry samples; same
    ``step(now)`` contract as :class:`~repro.obs.slo.SLOEvaluator`, so
    a :class:`~repro.obs.slo.HealthMonitor` can drive both. Detector
    hits are edge-triggered into the bus and mirrored to
    ``anomaly_active{detector=...}`` gauges."""

    def __init__(self, detectors: Optional[List[Detector]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 bus: Optional[AlertBus] = None,
                 max_samples: int = 512):
        self.detectors = (default_detectors() if detectors is None
                          else list(detectors))
        self.registry = registry if registry is not None else REGISTRY
        self.bus = bus
        self.window = SampleWindow(maxlen=max_samples)
        self._active: Dict[str, bool] = {}
        self._lock = threading.Lock()

    def step(self, now: Optional[float] = None) -> List[Alert]:
        with self._lock:
            self.window.sample(self.registry, now)
            t = self.window.latest.t
            alerts: List[Alert] = []
            for det in self.detectors:
                try:
                    hit = det.check(self.window)
                except Exception:
                    hit = None  # a broken detector must not stop the rest
                active = hit is not None
                was = self._active.get(det.name, False)
                self._active[det.name] = active
                self.registry.gauge("anomaly_active",
                                    detector=det.name).set(
                    1.0 if active else 0.0)
                if active and not was:
                    alerts.append(Alert(
                        name=det.name, severity=det.severity,
                        source="anomaly", message=hit["message"],
                        value=float(hit.get("value", 0.0)),
                        threshold=float(hit.get("threshold", 0.0)),
                        t=t, wall_time=time.time(),
                        labels={"detector": det.name},
                        evidence=dict(hit.get("evidence", {}))))
        if self.bus is not None:
            for a in alerts:
                self.bus.publish(a)
        return alerts

    def status(self) -> Dict[str, bool]:
        with self._lock:
            return dict(self._active)
