"""Per-request trace spans for the serving stack.

A :class:`RequestTrace` is minted when a :class:`RequestHandle` is
created (``MicroBatchScheduler.submit`` / ``ClusterPool.submit`` /
``submit_chunk``) and rides the handle through queueing, flushes,
escalation re-runs, and failover requeues until ``_resolve`` finishes
it. The span model is a *tiling* state machine:

- the root span covers exactly ``[t_submit, t_done]``;
- child spans (``queue`` / ``serve``) partition that interval with no
  gaps and no overlap, because ``begin(name, now)`` closes the open
  child at the same ``now`` it opens the next one, and ``finish(now)``
  closes the last child and the root at the same ``now`` that
  ``RequestHandle._resolve`` stamps into ``t_done``.

So "child durations sum to the end-to-end latency" is structural, not
a timing-noise property. Escalation hops (``EscalationRecord``),
failover requeues, guardrail flags, and session checkpoints attach as
span *events*; each re-entry into a queue bumps the trace's ``hop``
counter so a latency report can attribute first-attempt time vs
escalation/requeue time.

Everything here is stdlib-only and thread-safe. Tracing is **off** by
default: ``Tracer.start_request`` returns ``None`` and every hook in
the hot path is a ``handle.trace is not None`` check — the clean-path
overhead gate in ``BENCH_obs.json`` pins this at <= 1.05x.

All span timestamps are ``time.monotonic()`` (duration math); the only
wall-clock field is ``wall_time``, stamped once at ``finish`` for
export/correlation (see the time-base policy in docs/observability.md).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

__all__ = ["Span", "RequestTrace", "Tracer", "TRACER",
           "configure_tracing", "get_tracer"]


class Span:
    __slots__ = ("span_id", "parent_id", "name", "t0", "t1", "attrs")

    def __init__(self, span_id: str, parent_id: Optional[str], name: str,
                 t0: float, attrs: Optional[Dict] = None):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs or {}

    @property
    def duration_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def to_json(self) -> Dict:
        return {"span_id": self.span_id, "parent_id": self.parent_id,
                "name": self.name, "t0": self.t0, "t1": self.t1,
                "duration_s": self.duration_s, "attrs": dict(self.attrs)}


class RequestTrace:
    """Span tree for one request/chunk. See module docstring for the
    tiling invariant. All methods are no-ops after ``finish`` — late
    writers (a stalled worker completing a flush the watchdog already
    expropriated and a survivor already resolved) cannot corrupt a
    delivered trace, mirroring ``RequestHandle``'s first-resolution-wins
    rule."""

    __slots__ = ("trace_id", "kind", "attrs", "hop", "status",
                 "wall_time", "root", "spans", "events",
                 "_open", "_seq", "_lock", "_finished", "_on_finish")

    def __init__(self, trace_id: str, kind: str, t0: float,
                 attrs: Optional[Dict] = None,
                 on_finish: Optional[Callable[["RequestTrace"], None]] = None):
        self.trace_id = trace_id
        self.kind = kind
        self.attrs: Dict = dict(attrs or {})
        self.hop = 0
        self.status = "open"
        self.wall_time: Optional[float] = None
        self.root = Span("0", None, kind, t0)
        self.spans: List[Span] = []
        self.events: List[Dict] = []
        self._open: Optional[Span] = None
        self._seq = 0
        self._lock = threading.Lock()
        self._finished = False
        self._on_finish = on_finish
        # every request is born queued
        self._begin_locked("queue", t0, {})

    # -- span state machine ---------------------------------------------

    def _begin_locked(self, name: str, now: float, attrs: Dict) -> None:
        if self._open is not None:
            self._open.t1 = now
        self._seq += 1
        attrs = dict(attrs)
        attrs.setdefault("hop", self.hop)
        span = Span(str(self._seq), self.root.span_id, name, now, attrs)
        self.spans.append(span)
        self._open = span

    def begin(self, name: str, now: Optional[float] = None,
              **attrs) -> None:
        """Close the open segment and start ``name`` at the same
        instant (segments tile by construction)."""
        now = time.monotonic() if now is None else now
        with self._lock:
            if self._finished:
                return
            self._begin_locked(name, now, attrs)

    def event(self, name: str, now: Optional[float] = None,
              **attrs) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            if self._finished:
                return
            self.events.append({"t": now, "name": name,
                                "attrs": dict(attrs)})

    def bump_hop(self) -> int:
        """A re-entry into a queue (escalation / failover requeue)."""
        with self._lock:
            if not self._finished:
                self.hop += 1
            return self.hop

    def set_attr(self, key: str, value) -> None:
        with self._lock:
            if not self._finished:
                self.attrs[key] = value

    def finish(self, now: Optional[float] = None, status: str = "ok",
               **attrs) -> None:
        """Close the open segment and the root at the same instant.
        Idempotent; first finish wins."""
        now = time.monotonic() if now is None else now
        with self._lock:
            if self._finished:
                return
            self._finished = True
            if self._open is not None:
                self._open.t1 = now
                self._open = None
            self.root.t1 = now
            self.status = status
            self.attrs.update(attrs)
            self.wall_time = time.time()  # export timestamp only
        if self._on_finish is not None:
            self._on_finish(self)

    @property
    def finished(self) -> bool:
        return self._finished

    # -- readout ----------------------------------------------------------

    def to_json(self) -> Dict:
        with self._lock:
            return {
                "trace_id": self.trace_id,
                "kind": self.kind,
                "status": self.status,
                "wall_time": self.wall_time,
                "t0": self.root.t0,
                "t1": self.root.t1,
                "duration_s": self.root.duration_s,
                "hops": self.hop,
                "attrs": dict(self.attrs),
                "spans": [self.root.to_json()] + [s.to_json()
                                                  for s in self.spans],
                "events": [dict(e) for e in self.events],
            }


class Tracer:
    """Process-wide trace collector.

    Disabled by default — ``start_request`` returns ``None`` so every
    instrumentation site degrades to one attribute check. When enabled,
    finished traces land in a bounded ring buffer (``drain()``) and,
    if configured, a sink's ``write(dict)`` (e.g.
    :class:`repro.obs.export.JsonlTraceSink`).

    Sink export is **asynchronous**: ``_complete`` (called from the
    serving worker's ``_resolve``) only appends the finished trace to a
    queue; a background thread does the ``to_json`` + serialization +
    file I/O, overlapping with engine compute instead of stalling the
    flush loop. ``flush()`` blocks until the queue is drained;
    ``configure`` flushes before disabling or swapping the sink, so
    "disable then read the sink file" sees every finished trace.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._enabled = False
        self._sink = None
        self._completed: deque = deque(maxlen=4096)
        self._ids = itertools.count(1)
        self.n_started = 0
        self.n_finished = 0
        self.n_sink_errors = 0
        # async sink export (see class docstring)
        self._export_cv = threading.Condition()
        self._export_q: deque = deque()
        self._export_busy = False
        self._export_thread: Optional[threading.Thread] = None

    @property
    def enabled(self) -> bool:
        return self._enabled

    def configure(self, enabled: Optional[bool] = None, sink=None,
                  capacity: Optional[int] = None) -> "Tracer":
        if enabled is False or sink is not None:
            # drain pending exports into the *old* sink before it is
            # detached/replaced
            self.flush()
        with self._lock:
            if enabled is not None:
                self._enabled = bool(enabled)
            if sink is not None or enabled is False:
                self._sink = sink
            if capacity is not None:
                self._completed = deque(self._completed, maxlen=capacity)
        return self

    def start_request(self, kind: str = "request",
                      t0: Optional[float] = None,
                      **attrs) -> Optional[RequestTrace]:
        if not self._enabled:
            return None
        t0 = time.monotonic() if t0 is None else t0
        trace_id = f"{kind[:1]}-{next(self._ids):08d}"
        with self._lock:
            self.n_started += 1
        return RequestTrace(trace_id, kind, t0, attrs,
                            on_finish=self._complete)

    def _complete(self, trace: RequestTrace) -> None:
        # hot path (worker thread inside _resolve): two appends, no
        # serialization — to_json happens lazily in drain()/the export
        # thread; a finished trace is immutable so deferral is safe
        with self._lock:
            self.n_finished += 1
            self._completed.append(trace)
            sink = self._sink
        if sink is not None:
            with self._export_cv:
                self._export_q.append(trace)
                if (self._export_thread is None
                        or not self._export_thread.is_alive()):
                    self._export_thread = threading.Thread(
                        target=self._export_loop, name="trace-export",
                        daemon=True)
                    self._export_thread.start()
                self._export_cv.notify()

    def _export_loop(self) -> None:
        while True:
            with self._export_cv:
                while not self._export_q:
                    self._export_busy = False
                    self._export_cv.notify_all()
                    self._export_cv.wait()
                trace = self._export_q.popleft()
                self._export_busy = True
            sink = self._sink
            if sink is None:
                continue
            try:
                sink.write(trace.to_json())
            except Exception:
                with self._lock:
                    self.n_sink_errors += 1

    def flush(self, timeout: float = 30.0) -> bool:
        """Block until every queued trace has been handed to the sink.
        Returns False on timeout."""
        deadline = time.monotonic() + timeout
        with self._export_cv:
            while self._export_q or self._export_busy:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._export_cv.wait(remaining)
        return True

    def drain(self) -> List[Dict]:
        """Pop and return every buffered finished trace."""
        with self._lock:
            out = list(self._completed)
            self._completed.clear()
        return [t.to_json() for t in out]

    def reset(self) -> None:
        with self._export_cv:
            self._export_q.clear()
        with self._lock:
            self._completed.clear()
            self.n_started = 0
            self.n_finished = 0
            self.n_sink_errors = 0


#: The process-wide tracer every handle mints from.
TRACER = Tracer()


def configure_tracing(enabled: Optional[bool] = None, sink=None,
                      capacity: Optional[int] = None) -> Tracer:
    return TRACER.configure(enabled=enabled, sink=sink, capacity=capacity)


def get_tracer() -> Tracer:
    return TRACER
