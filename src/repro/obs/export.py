"""Exporters for the obs plane: JSONL trace sink, Prometheus text
exposition, and a periodic background exporter for ``launch serve
--metrics-out/--trace-out``.

Wall-clock (``time.time``) appears here and only here — exporters stamp
export timestamps; every duration upstream is monotonic.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Dict, Optional

from repro.obs import metrics as _metrics

__all__ = ["prometheus_text", "write_metrics", "JsonlTraceSink",
           "PeriodicExporter"]


def _prom_name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def prometheus_text(snapshot: Optional[Dict] = None,
                    registry: Optional[_metrics.MetricsRegistry] = None
                    ) -> str:
    """Render a registry snapshot in Prometheus text exposition format.

    Counters/gauges emit one sample each; histograms emit summary-style
    ``_count`` / ``_sum`` plus ``quantile``-labelled samples from the
    log-bucket readout.
    """
    if snapshot is None:
        snapshot = (registry or _metrics.REGISTRY).snapshot()
    lines = []
    typed = set()

    def _type_line(name: str, kind: str) -> None:
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for e in snapshot.get("counters", ()):
        name = _prom_name(e["name"])
        _type_line(name, "counter")
        lines.append(f"{name}{_metrics.label_suffix(e['labels'])} "
                     f"{e['value']:.10g}")
    for e in snapshot.get("gauges", ()):
        name = _prom_name(e["name"])
        _type_line(name, "gauge")
        lines.append(f"{name}{_metrics.label_suffix(e['labels'])} "
                     f"{e['value']:.10g}")
    for e in snapshot.get("histograms", ()):
        name = _prom_name(e["name"])
        _type_line(name, "summary")
        for q in ("p50", "p95", "p99"):
            labels = dict(e["labels"])
            labels["quantile"] = {"p50": "0.5", "p95": "0.95",
                                  "p99": "0.99"}[q]
            lines.append(f"{name}{_metrics.label_suffix(labels)} "
                         f"{e[q]:.10g}")
        sfx = _metrics.label_suffix(e["labels"])
        lines.append(f"{name}_count{sfx} {e['count']}")
        lines.append(f"{name}_sum{sfx} {e['sum']:.10g}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_metrics(path: str,
                  registry: Optional[_metrics.MetricsRegistry] = None
                  ) -> None:
    """Atomically write the current exposition to ``path``."""
    text = prometheus_text(registry=registry)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(f"# exported_at {time.time():.3f}\n")
        f.write(text)
    os.replace(tmp, path)


class JsonlTraceSink:
    """Append-only JSONL sink for finished traces (one trace per line).

    Thread-safe; lines are flushed as written so a crash loses at most
    the in-flight line. Pass to ``configure_tracing(sink=...)``.

    With ``max_bytes`` set the sink rotates: when the active file would
    exceed the cap it is renamed to ``<path>.1`` (shifting ``.1`` ->
    ``.2`` and so on, dropping the oldest past ``keep``) and a fresh
    file is opened — a week-long MD session keeps at most
    ``(keep + 1) * max_bytes`` of trace on disk instead of one
    unbounded JSONL.
    """

    def __init__(self, path: str, max_bytes: Optional[int] = None,
                 keep: int = 3):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self.max_bytes = None if max_bytes is None else int(max_bytes)
        self.keep = max(0, int(keep))
        self._lock = threading.Lock()
        self._f = open(path, "a")
        self._bytes = os.path.getsize(path)
        self.n_written = 0
        self.n_rotations = 0

    def _rotate_locked(self) -> None:
        self._f.close()
        oldest = f"{self.path}.{self.keep}"
        if self.keep > 0 and os.path.exists(oldest):
            os.remove(oldest)
        for i in range(self.keep - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        if self.keep > 0:
            os.replace(self.path, f"{self.path}.1")
        else:
            os.remove(self.path)
        self._f = open(self.path, "a")
        self._bytes = 0
        self.n_rotations += 1

    def write(self, trace_doc: Dict) -> None:
        data = json.dumps(trace_doc, separators=(",", ":"),
                          sort_keys=True) + "\n"
        with self._lock:
            if self._f is None:
                return
            if (self.max_bytes is not None and self._bytes > 0
                    and self._bytes + len(data) > self.max_bytes):
                self._rotate_locked()
            self._f.write(data)
            self._f.flush()
            self._bytes += len(data)
            self.n_written += 1

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def load_traces(path: str):
    """Read a JSONL trace file back into a list of trace dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class PeriodicExporter:
    """Background thread writing the Prometheus exposition to a file on
    an interval (plus a final write on ``stop``). This is the
    ``launch serve --metrics-out`` plumbing; trace export is push-based
    via :class:`JsonlTraceSink` so it needs no thread.

    ``stop()`` is idempotent and also registered via :mod:`atexit`, and
    when a ``tracer`` / ``trace_sink`` are attached it drains the
    tracer's export queue and closes the sink after the final metrics
    write — an interpreter exit can no longer drop the trace tail."""

    def __init__(self, metrics_path: str, interval_s: float = 5.0,
                 registry: Optional[_metrics.MetricsRegistry] = None,
                 tracer=None, trace_sink: Optional[JsonlTraceSink] = None):
        self.metrics_path = metrics_path
        self.interval_s = max(0.05, float(interval_s))
        self._registry = registry
        self._tracer = tracer
        self._trace_sink = trace_sink
        self._stop = threading.Event()
        self._stopped = False
        self._stop_lock = threading.Lock()
        self._thread = threading.Thread(target=self._run,
                                        name="obs-exporter", daemon=True)
        self.n_exports = 0

    def _export(self) -> None:
        write_metrics(self.metrics_path, registry=self._registry)
        self.n_exports += 1

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self._export()
            except Exception:
                pass  # a failed export must never take down serving

    def start(self) -> "PeriodicExporter":
        self._thread.start()
        atexit.register(self.stop)
        return self

    def stop(self) -> None:
        with self._stop_lock:
            if self._stopped:
                return
            self._stopped = True
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)
        try:
            self._export()  # final snapshot
        except Exception:
            pass
        if self._tracer is not None:
            try:
                self._tracer.flush(timeout=10.0)
            except Exception:
                pass
        if self._trace_sink is not None:
            try:
                self._trace_sink.close()
            except Exception:
                pass
        try:
            atexit.unregister(self.stop)
        except Exception:
            pass
