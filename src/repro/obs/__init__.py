"""repro.obs — unified metrics plane + end-to-end request tracing,
plus the active health plane (SLOs, burn-rate alerting, anomaly
detection, Chrome-trace timeline export).

Dependency leaf (stdlib only, like ``repro.guardrails``): everything in
the stack can import it. See docs/observability.md.
"""
from repro.obs.metrics import (MetricsRegistry, Counter, Gauge, Histogram,
                               REGISTRY, get_registry, snapshot)
from repro.obs.trace import (Span, RequestTrace, Tracer, TRACER,
                             configure_tracing, get_tracer)
from repro.obs.export import (prometheus_text, write_metrics,
                              JsonlTraceSink, PeriodicExporter,
                              load_traces)
from repro.obs.slo import (Alert, AlertBus, SLO, SLOEvaluator,
                           HealthMonitor, SampleWindow, default_slos)
from repro.obs.anomaly import (AnomalyMonitor, Detector, EwmaZScore,
                               QueueDepthRunaway, CompileStorm,
                               ReplicaLatencySkew, EscalationTrend,
                               default_detectors, robust_zscore)
from repro.obs.timeline import (chrome_trace, write_chrome_trace,
                                validate_chrome_trace)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "REGISTRY",
    "get_registry", "snapshot",
    "Span", "RequestTrace", "Tracer", "TRACER", "configure_tracing",
    "get_tracer",
    "prometheus_text", "write_metrics", "JsonlTraceSink",
    "PeriodicExporter", "load_traces",
    "Alert", "AlertBus", "SLO", "SLOEvaluator", "HealthMonitor",
    "SampleWindow", "default_slos",
    "AnomalyMonitor", "Detector", "EwmaZScore", "QueueDepthRunaway",
    "CompileStorm", "ReplicaLatencySkew", "EscalationTrend",
    "default_detectors", "robust_zscore",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
]
