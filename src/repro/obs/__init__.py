"""repro.obs — unified metrics plane + end-to-end request tracing.

Dependency leaf (stdlib only, like ``repro.guardrails``): everything in
the stack can import it. See docs/observability.md.
"""
from repro.obs.metrics import (MetricsRegistry, Counter, Gauge, Histogram,
                               REGISTRY, get_registry, snapshot)
from repro.obs.trace import (Span, RequestTrace, Tracer, TRACER,
                             configure_tracing, get_tracer)
from repro.obs.export import (prometheus_text, write_metrics,
                              JsonlTraceSink, PeriodicExporter,
                              load_traces)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "REGISTRY",
    "get_registry", "snapshot",
    "Span", "RequestTrace", "Tracer", "TRACER", "configure_tracing",
    "get_tracer",
    "prometheus_text", "write_metrics", "JsonlTraceSink",
    "PeriodicExporter", "load_traces",
]
