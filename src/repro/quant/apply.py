"""Model-level quantization: convert a trained fp param tree into the
serve-time W8/W4 representation consumed by `qlinear`'s serve modes.

Policy (branch-separated, the paper's §III-D applied to LMs):
  * every qlinear-consumed projection matrix -> (int8 | packed-int4, scale),
    per-output-channel scales, computed per stacked matrix;
  * precision-critical leaves stay fp: embeddings / lm head (accuracy),
    norms/biases (tiny), MoE router (the "direction" analogue), conv taps;
  * MoE expert tensors are quantized too (they dominate MoE bytes).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import pack_int4, qmax
from repro.models.lm.config import LMConfig

# paths (regex) of weights that go through qlinear or the expert einsums
_QUANT_PATTERNS = [
    r"attn/w[qkvo]$",
    r"mlp/(wg|wu|wi|wd)$",
    r"moe/(wg|wu|wd)$",
    r"(^|/)m/(w_z|w_x|w_B|w_C|w_dt|out_proj)$",
    r"b/(w_gate|w_up|wq|wk|wv|down|w_in)$",
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _per_matrix_scale(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-output-channel scale over the contracting (-2) axis only, so
    stacked (depth, K, N) weights get independent scales per matrix."""
    amax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax(bits)


def quantize_matrix(w: jnp.ndarray, mode: str):
    if mode == "serve_w8a8":
        s = _per_matrix_scale(w, 8)
        q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        return (q, s.astype(jnp.float32))
    if mode == "serve_w4a8":
        s = _per_matrix_scale(w, 4)
        q = jnp.clip(jnp.round(w / s), -7, 7).astype(jnp.int8)
        return (pack_int4(q), s.astype(jnp.float32))
    raise ValueError(mode)


def quantize_params_tree(params, cfg: LMConfig):
    mode = cfg.quant_mode
    assert mode in ("serve_w8a8", "serve_w4a8")

    def leaf(path, x):
        p = _path_str(path)
        if x.ndim >= 2 and any(re.search(pat, p) for pat in _QUANT_PATTERNS):
            if mode == "serve_w4a8" and x.shape[-1] % 2:
                return x  # odd minor dim: leave fp (none in assigned archs)
            return quantize_matrix(x, mode)
        return x

    return jax.tree_util.tree_map_with_path(leaf, params)


def quantized_bytes(tree) -> int:
    return int(sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree)))
