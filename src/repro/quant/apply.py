"""Model-level quantization: convert a trained fp param tree into the
serve-time W8/W4 representation consumed by `qlinear`'s serve modes.

Policy (branch-separated, the paper's §III-D applied to LMs):
  * every qlinear-consumed projection matrix -> (int8 | packed-int4, scale),
    per-output-channel scales, computed per stacked matrix;
  * precision-critical leaves stay fp: embeddings / lm head (accuracy),
    norms/biases (tiny), MoE router (the "direction" analogue), conv taps;
  * MoE expert tensors are quantized too (they dominate MoE bytes).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import pack_int4, qmax
from repro.models.lm.config import LMConfig

# paths (regex) of weights that go through qlinear or the expert einsums
_QUANT_PATTERNS = [
    r"attn/w[qkvo]$",
    r"mlp/(wg|wu|wi|wd)$",
    r"moe/(wg|wu|wd)$",
    r"(^|/)m/(w_z|w_x|w_B|w_C|w_dt|out_proj)$",
    r"b/(w_gate|w_up|wq|wk|wv|down|w_in)$",
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _per_matrix_scale(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-output-channel scale over the contracting (-2) axis only, so
    stacked (depth, K, N) weights get independent scales per matrix."""
    amax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax(bits)


def quantize_matrix(w: jnp.ndarray, mode: str):
    """One fp weight matrix -> its serving representation.

    ``serve_w8a8``: (int8 (..., K, N), f32 scale (..., 1, N)) — symmetric
    per-output-channel over the contracting axis.
    ``serve_w4a8``: (uint8 (..., K, N//2) nibble-packed, f32 scale) — the
    int4 grid is [-7, 7]; packing follows
    ``repro.core.quantizers.pack_int4`` (low nibble first), matching the
    in-kernel unpack of ``repro.kernels.quant_matmul.w4a8_matmul``.
    """
    if mode == "serve_w8a8":
        s = _per_matrix_scale(w, 8)
        q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        return (q, s.astype(jnp.float32))
    if mode == "serve_w4a8":
        s = _per_matrix_scale(w, 4)
        q = jnp.clip(jnp.round(w / s), -7, 7).astype(jnp.int8)
        return (pack_int4(q), s.astype(jnp.float32))
    raise ValueError(mode)


def quantize_params_tree(params, cfg: LMConfig):
    """Convert a trained fp LM param tree into serve-time quantized form.

    Leaves whose path matches ``_QUANT_PATTERNS`` (every qlinear-consumed
    projection and MoE expert tensor) become ``(q, scale)`` tuples via
    :func:`quantize_matrix`; all other leaves (embeddings, lm head, norms,
    biases, router, conv taps) pass through unchanged. The result is what
    ``repro.launch.serve --workload lm`` feeds the decode loop.
    """
    mode = cfg.quant_mode
    assert mode in ("serve_w8a8", "serve_w4a8")

    def leaf(path, x):
        p = _path_str(path)
        if x.ndim >= 2 and any(re.search(pat, p) for pat in _QUANT_PATTERNS):
            if mode == "serve_w4a8" and x.shape[-1] % 2:
                return x  # odd minor dim: leave fp (none in assigned archs)
            return quantize_matrix(x, mode)
        return x

    return jax.tree_util.tree_map_with_path(leaf, params)


def quantized_bytes(tree) -> int:
    """Total bytes of a (possibly quantized) param tree as stored —
    int8/uint8 leaves count 1 byte per element, so the fp32-vs-served
    ratio is the memory-compression factor reported by the launchers."""
    return int(sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree)))
