"""repro.guardrails — runtime physics/numerics health layer.

The paper's core claim is that naive low-bit quantization *silently*
violates SO(3) symmetry and conservation laws: LEE blows up on some
inputs, MD stops conserving energy, and a w4a8 forward can emit NaN
forces for geometries the calibration never saw. This package is the
serving stack's runtime defense — every result is checked before a
caller sees it, and suspect work degrades gracefully instead of
failing:

* :mod:`repro.guardrails.detectors` — cheap host-side checks fused into
  the forward's result path: non-finite energy/forces (fatal),
  force-norm outliers against a calibrated per-bucket
  :class:`ForceEnvelope` (suspect), and a sampled per-batch LEE probe
  (suspect). :class:`GuardrailConfig` configures them per engine;
  :class:`GuardrailViolation` is the typed error every surface raises —
  a caller never receives a silent NaN.
* :mod:`repro.guardrails.escalation` — the precision ladder
  (:data:`TIER_ORDER` = w4a8 -> w8a8 -> fp32) and the typed
  :class:`EscalationRecord` stamped into a
  :class:`~repro.serving.engine.MoleculeResult` when a flagged request
  was transparently re-run one tier up by a mixed-tier
  :class:`~repro.cluster.pool.ClusterPool`.

This package is a dependency leaf (numpy only): ``repro.serving``,
``repro.md``, ``repro.server``, ``repro.cluster``, and
``repro.sessions`` all import it, never the reverse. See
docs/guardrails.md for the detector catalog, the escalation ladder, the
breaker/quarantine state machine, and the pool watchdog.
"""
from repro.guardrails.detectors import (Flag, ForceEnvelope, GuardrailConfig,
                                        GuardrailViolation, check_finite_tree,
                                        check_result)
from repro.guardrails.escalation import (EscalationRecord, TIER_ORDER,
                                         next_tier, tier_rank)

__all__ = [
    "Flag", "ForceEnvelope", "GuardrailConfig", "GuardrailViolation",
    "check_finite_tree", "check_result",
    "EscalationRecord", "TIER_ORDER", "next_tier", "tier_rank",
]
