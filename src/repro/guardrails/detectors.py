"""Runtime result detectors: the checks every serving surface runs.

Three detectors, cheapest first, all host-side numpy over arrays the
result path already materializes (``np.asarray`` of energy/forces —
zero extra device work except the sampled LEE probe):

* **non-finite** (``reason="nonfinite"``, fatal) — NaN/Inf anywhere in
  a molecule's energy or forces. Fatal: the value is garbage, a caller
  must never receive it as a result.
* **force outlier** (``reason="force_outlier"``, suspect) — the max
  per-atom force norm exceeds the calibrated per-bucket
  :class:`ForceEnvelope`. Suspect: the value is finite but physically
  implausible for traffic the envelope was calibrated on — the
  quantized model is likely out of its trust region for this geometry.
* **LEE probe** (``reason="lee"``, suspect) — every
  ``lee_probe_every``-th batch is re-run under one seeded rotation and
  compared: ``||f(R.G) - R f(G)||`` per molecule against
  ``lee_limit``. This is the paper's Eq. 1 run *online*, sampled so its
  cost amortizes to ``1/lee_probe_every`` extra forwards.

Severity decides what a degradation ladder may do with the result:
**fatal** results are never delivered (escalate or raise a typed
:class:`GuardrailViolation`); **suspect** results escalate when a
higher-precision tier exists and are otherwise delivered annotated
(``MoleculeResult.flags``) — fp32 is the top of the ladder and its
suspect results are still the best answer the fleet has.

Everything here is plain numpy + dataclasses: this module must stay
importable by ``repro.serving``, ``repro.md``, and ``repro.cluster``
without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Flag", "ForceEnvelope", "GuardrailConfig", "GuardrailViolation",
           "check_finite_tree", "check_result"]

FATAL = "fatal"
SUSPECT = "suspect"


class GuardrailViolation(RuntimeError):
    """A guardrail refused to deliver a result. Typed so callers (and
    the session manager's tier-escalation retry) can tell physics
    failures from infrastructure failures.

    ``reason`` is the detector that fired (``"nonfinite"``,
    ``"force_outlier"``, ``"lee"``, ``"energy_drift"``), ``severity``
    is ``"fatal"`` or ``"suspect"``, and ``detail`` carries
    detector-specific context (measured value, limit, serving mode).
    """

    def __init__(self, msg: str, reason: str = "", severity: str = FATAL,
                 detail: Optional[Dict] = None):
        super().__init__(msg)
        self.reason = reason
        self.severity = severity
        self.detail = dict(detail or {})


@dataclasses.dataclass(frozen=True)
class Flag:
    """One detector firing on one molecule. ``value``/``limit`` are the
    measured quantity and the threshold it crossed (0 for nonfinite —
    there is no meaningful magnitude)."""
    reason: str                 # "nonfinite" | "force_outlier" | "lee"
    severity: str               # "fatal" | "suspect"
    value: float = 0.0
    limit: float = 0.0

    @property
    def fatal(self) -> bool:
        return self.severity == FATAL


@dataclasses.dataclass(frozen=True)
class ForceEnvelope:
    """Calibrated per-bucket force-norm ceiling.

    ``limits`` maps bucket capacity -> max admissible per-atom force
    norm (eV/A), stored as a sorted tuple of pairs so the config stays
    hashable (engines are compared by their configs in the cluster).
    Calibrate on clean traffic through the *same* quantized engine that
    will serve — the envelope captures what "ordinary" looks like for
    this model at this precision, so an excursion means the input is
    outside the calibration set's trust region.
    """
    limits: Tuple[Tuple[int, float], ...] = ()

    @classmethod
    def calibrate(cls, results: Sequence, factor: float = 4.0,
                  floor: float = 1.0) -> "ForceEnvelope":
        """Build from clean ``MoleculeResult``s: per bucket capacity,
        ``factor`` x the max observed per-atom force norm (floored so a
        near-zero calibration set cannot produce a hair-trigger
        envelope)."""
        peak: Dict[int, float] = {}
        for r in results:
            norms = np.linalg.norm(np.asarray(r.forces), axis=-1)
            m = float(norms.max()) if norms.size else 0.0
            cap = int(r.bucket_capacity)
            peak[cap] = max(peak.get(cap, 0.0), m)
        return cls(limits=tuple(sorted(
            (cap, max(m * factor, floor)) for cap, m in peak.items())))

    def limit_for(self, capacity: int) -> Optional[float]:
        for cap, lim in self.limits:
            if cap == capacity:
                return lim
        return None


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    """Per-engine detector configuration (hashable, like ServeConfig).

    ``on_flag`` is the engine-level default for what ``infer_batch``
    does when a detector fires: ``"raise"`` (the direct-call surface —
    a typed :class:`GuardrailViolation` instead of a bad result) or
    ``"mark"`` (the scheduler/cluster surface — results come back with
    ``flags`` set and the caller decides: resolve a typed error,
    deliver annotated, or escalate a precision tier).
    """
    check_finite: bool = True
    envelope: Optional[ForceEnvelope] = None
    # sampled LEE probe: every Nth infer_batch call re-runs the batch
    # under one seeded rotation (0 = off; cost ~ 1/N extra forwards)
    lee_probe_every: int = 0
    lee_limit: float = 1.0
    lee_seed: int = 0
    on_flag: str = "raise"      # "raise" | "mark"

    def __post_init__(self):
        if self.on_flag not in ("raise", "mark"):
            raise ValueError(f"unknown on_flag {self.on_flag!r}")
        if self.lee_probe_every < 0:
            raise ValueError("lee_probe_every must be >= 0")

    @property
    def active(self) -> bool:
        """Whether any detector can fire (an all-off config lets the
        result path skip guardrail work entirely — the A/B baseline of
        benchmarks/guardrails_bench.py)."""
        return (self.check_finite or self.envelope is not None
                or self.lee_probe_every > 0)


def check_result(energy: float, forces: np.ndarray, capacity: int,
                 config: GuardrailConfig) -> Tuple[Flag, ...]:
    """Run the per-molecule detectors (non-finite + envelope) on one
    result's arrays. Returns the flags that fired, fatal first."""
    flags = []
    if config.check_finite:
        if not (np.isfinite(energy) and bool(np.isfinite(forces).all())):
            flags.append(Flag("nonfinite", FATAL))
    env = config.envelope
    if env is not None and not flags:     # garbage norms are meaningless
        lim = env.limit_for(capacity)
        if lim is not None:
            m = float(np.linalg.norm(forces, axis=-1).max()) \
                if forces.size else 0.0
            if m > lim:
                flags.append(Flag("force_outlier", SUSPECT, value=m,
                                  limit=lim))
    return tuple(flags)


def check_finite_tree(arrays: Dict[str, np.ndarray]) -> Optional[str]:
    """Name of the first non-finite array in a dict of host arrays
    (None when all finite) — the MD per-chunk finite check."""
    for name, a in arrays.items():
        if not bool(np.isfinite(np.asarray(a)).all()):
            return name
    return None
