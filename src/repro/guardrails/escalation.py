"""The precision ladder and the escalation audit record.

ROADMAP item 3's tiered serving shape: w4a8 replicas carry the traffic,
w8a8/fp32 replicas stand behind them as escalation targets. A request
flagged by a detector is transparently re-run one tier up; the result
the caller finally receives carries the full audit trail as
:class:`EscalationRecord`\\ s in ``MoleculeResult.escalations``.

"One tier up" means the next tier *present in the fleet* above the
flagging replica's — a w4a8 -> fp32 pool escalates straight to fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["TIER_ORDER", "tier_rank", "next_tier", "EscalationRecord"]

# precision tiers, cheapest first — the escalation ladder climbs right
TIER_ORDER = ("w4a8", "w8a8", "fp32")


def tier_rank(mode: str) -> int:
    """Position of a serving mode on the ladder (higher = more
    precise). Raises for modes that are not tiers."""
    try:
        return TIER_ORDER.index(mode)
    except ValueError:
        raise ValueError(f"{mode!r} is not a precision tier "
                         f"(ladder: {TIER_ORDER})") from None


def next_tier(mode: str) -> Optional[str]:
    """The tier directly above ``mode`` (None at the top — fp32 is
    ground truth, there is nowhere left to escalate)."""
    r = tier_rank(mode)
    return TIER_ORDER[r + 1] if r + 1 < len(TIER_ORDER) else None


@dataclasses.dataclass(frozen=True)
class EscalationRecord:
    """One hop up the ladder, stamped into the delivered result.

    ``reason`` is the detector that triggered it (``Flag.reason``),
    ``from_replica`` the replica whose result was flagged. The tier
    that finally answered is the result's own ``replica_id`` /
    ``path`` — a result with N records was re-run N times.
    """
    from_tier: str
    to_tier: str
    reason: str
    from_replica: int = -1
