"""chameleon-34b [vlm]: early-fusion over VQ image tokens (backbone only;
the VQ-VAE frontend is a stub -- input_specs provides patch embeddings).
48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536 [arXiv:2405.09818].
Chameleon uses QK-norm natively -- the paper's robust attention.
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="chameleon-34b", block_pattern="transformer",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab=65536, head_dim=128, mlp_kind="swiglu",
        qk_norm=True, frontend="image_patches",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="chameleon-smoke", block_pattern="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=256, head_dim=16, mlp_kind="swiglu",
        qk_norm=True, frontend="image_patches",
    )
