"""qwen1.5-110b [dense]: GQA + QKV bias.
80L d_model=8192 64H (kv=8) d_ff=49152 vocab=152064 [hf:Qwen/Qwen1.5-0.5B; hf].
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b", block_pattern="transformer",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=49152, vocab=152064, head_dim=128, qkv_bias=True,
        mlp_kind="swiglu",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen1.5-smoke", block_pattern="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=256, head_dim=16, qkv_bias=True, mlp_kind="swiglu",
    )
