"""nemotron-4-15b [dense]: GQA, squared-ReLU MLP.
32L d_model=6144 48H (kv=8) d_ff=24576 vocab=256000 [arXiv:2402.16819].
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="nemotron-4-15b", block_pattern="transformer",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab=256000, head_dim=128, mlp_kind="squared_relu",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="nemotron-smoke", block_pattern="transformer",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
        d_ff=96, vocab=256, head_dim=8, mlp_kind="squared_relu",
    )
