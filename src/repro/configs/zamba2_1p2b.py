"""zamba2-1.2b [hybrid]: Mamba2 backbone + one shared attention block.
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]. Sub-quadratic (SSM) -> runs long_500k.
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="zamba2-1.2b", block_pattern="zamba2",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000, head_dim=64,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        zamba_mamba_per_attn=2, mlp_kind="swiglu",
        sub_quadratic=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="zamba2-smoke", block_pattern="zamba2",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_groups=1,
        zamba_mamba_per_attn=2, mlp_kind="swiglu", ssm_chunk=32,
        sub_quadratic=True,
    )
