"""llama3.2-3b [dense]: small llama3.
28L d_model=3072 24H (kv=8) d_ff=8192 vocab=128256 [hf:meta-llama/Llama-3.2-1B].
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="llama3.2-3b", block_pattern="transformer",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, head_dim=128, mlp_kind="swiglu",
        rope_theta=500000.0,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="llama3.2-smoke", block_pattern="transformer",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=8, mlp_kind="swiglu",
    )
