"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks at the published 7:1 ratio.
48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
Recurrent (O(1) state) -> runs long_500k.
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="xlstm-1.3b", block_pattern="xlstm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, mlp_kind="none",
        xlstm_mlstm_per_slstm=7, xlstm_proj_factor=1,  # pf=1 hits 1.3B at the assigned 48L
        sub_quadratic=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="xlstm-smoke", block_pattern="xlstm",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256, mlp_kind="none",
        xlstm_mlstm_per_slstm=7, xlstm_proj_factor=2, ssm_chunk=32,
        sub_quadratic=True,
    )
