"""musicgen-large [audio]: decoder-only over EnCodec tokens (backbone only;
the EnCodec frontend is a stub -- input_specs provides frame embeddings).
48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284; hf].
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="musicgen-large", block_pattern="transformer",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64, mlp_kind="swiglu",
        frontend="audio_frames",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="musicgen-smoke", block_pattern="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, head_dim=16, mlp_kind="swiglu",
        frontend="audio_frames",
    )
