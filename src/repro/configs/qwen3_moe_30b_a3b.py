"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, fine-grained (d_ff=768).
48L d_model=2048 32H (kv=4) vocab=151936 [hf:Qwen/Qwen3-30B-A3B].
Qwen3 uses QK-norm natively -- which IS the paper's robust attention.
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen3-moe-30b-a3b", block_pattern="transformer",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=768, vocab=151936, head_dim=128, mlp_kind="swiglu",
        moe=True, n_experts=128, top_k=8, qk_norm=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen3-moe-smoke", block_pattern="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=256, head_dim=16, mlp_kind="swiglu",
        moe=True, n_experts=8, top_k=2, qk_norm=True,
    )
