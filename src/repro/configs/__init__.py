"""Architecture registry: `--arch <id>` ids map to LMConfig factories.

Every assigned architecture has its own module with the exact published
config plus a `smoke()` reduced config of the same family for CPU tests.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.models.lm.config import LMConfig, SHAPES, ShapeCell

from . import (
    zamba2_1p2b,
    musicgen_large,
    xlstm_1p3b,
    qwen1p5_110b,
    llama3p2_3b,
    nemotron4_15b,
    qwen2_0p5b,
    moonshot_v1_16b_a3b,
    qwen3_moe_30b_a3b,
    chameleon_34b,
    so3krates_paper,
)

_MODULES = {
    "zamba2-1.2b": zamba2_1p2b,
    "musicgen-large": musicgen_large,
    "xlstm-1.3b": xlstm_1p3b,
    "qwen1.5-110b": qwen1p5_110b,
    "llama3.2-3b": llama3p2_3b,
    "nemotron-4-15b": nemotron4_15b,
    "qwen2-0.5b": qwen2_0p5b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "chameleon-34b": chameleon_34b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, **overrides) -> LMConfig:
    cfg = _MODULES[arch].config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str) -> LMConfig:
    return _MODULES[arch].smoke()


def shapes_for(arch: str) -> tuple:
    """The assigned input shapes for this arch; long_500k only for
    sub-quadratic (SSM/hybrid) families."""
    cfg = _MODULES[arch].config()
    return tuple(s for s in SHAPES
                 if s.shape_name != "long_500k" or cfg.sub_quadratic)
