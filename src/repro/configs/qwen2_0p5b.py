"""qwen2-0.5b [dense]: GQA, QKV bias, tied embeddings.
24L d_model=896 14H (kv=2) d_ff=4864 vocab=151936 [arXiv:2407.10671; hf].
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen2-0.5b", block_pattern="transformer",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151936, head_dim=64, qkv_bias=True,
        mlp_kind="swiglu", tie_embeddings=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen2-smoke", block_pattern="transformer",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=128, vocab=256, head_dim=8, qkv_bias=True,
        mlp_kind="swiglu", tie_embeddings=True,
    )
