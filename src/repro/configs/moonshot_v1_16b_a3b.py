"""moonshot-v1-16b-a3b [moe]: kimi/moonlight-style 64-expert top-6 MoE.
48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B]. (Published model keeps layer 0 dense;
we use all-MoE for scan homogeneity -- noted in DESIGN.md.)
"""
from repro.models.lm.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="moonshot-v1-16b-a3b", block_pattern="transformer",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=163840, head_dim=128, mlp_kind="swiglu",
        moe=True, n_experts=64, top_k=6,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="moonshot-smoke", block_pattern="transformer",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256, head_dim=16, mlp_kind="swiglu",
        moe=True, n_experts=8, top_k=2,
    )
