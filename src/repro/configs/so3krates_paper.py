"""The paper's own model config (So3krates + GAQ), for the benchmark suite."""
from repro.models.so3krates import So3kratesConfig


def config(quant: str = "gaq_w4a8") -> So3kratesConfig:
    return So3kratesConfig(feat=64, vec_feat=16, n_layers=3, quant=quant)


def smoke() -> So3kratesConfig:
    return So3kratesConfig(feat=16, vec_feat=4, n_layers=1, quant="gaq_w4a8")
