"""Pallas TPU kernel: fused edge-list attention (segment softmax + scatter).

The hot loop of the sparse serving path: for every directed cutoff-graph
edge e = (j -> i), compute the attention logit q_i . k_e + bias_e, take a
numerically stable softmax over each receiver's segment, and scatter the
alpha-weighted per-edge values back to the receiver nodes — all in one
pass over the edge stream, never materializing an (n, n) pairwise tensor.

Layout contract (produced by ``repro.serving.bucketing.build_edge_list``):

* nodes are flat ``(B * cap, F)`` with molecule b owning rows
  ``[b*cap, (b+1)*cap)``;
* edges are flat ``(B * ec, .)`` with molecule b owning slots
  ``[b*ec, (b+1)*ec)``, real edges first, **receiver-sorted**, padding
  slots masked;
* receiver indices arrive *molecule-local* (in ``[0, cap)``);
* the attention bias rides in the **last feature column** of the key
  (matched by a constant-1 column in the query), with masked edges set to
  a large negative bias — so one row-sum produces ``logit + bias`` and
  masking at once.

The grid is (B, ec/be) with the edge axis innermost. TPU grids execute
sequentially, so the kernel keeps an **online-softmax state** per node in
VMEM scratch — running max m, running denominator l, running weighted
accumulator acc — exactly the flash-attention recurrence, but over ragged
receiver segments instead of dense rows. Scatter within a block uses a
one-hot (be, cap) matrix: per-node max via a masked reduction, gather and
scatter via MXU matmuls. Output for molecule b is written once, on b's
last edge block.

``interpret=True`` runs the identical kernel on CPU (same pattern as
``quant_matmul``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BE = 128   # edges per block; EDGE_LANE in serving.bucketing
NEG_INF = -1e30    # online-softmax init; well below the -1e9 edge mask


def _edge_softmax_kernel(q_ref, k_ref, r_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref):
    e = pl.program_id(1)
    n_eb = pl.num_programs(1)

    @pl.when(e == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                                  # (cap, Fp) node queries
    k = k_ref[...]                                  # (be, Fp) edge keys+bias
    r = r_ref[...]                                  # (be,) local receiver idx
    cap = q.shape[0]
    be = k.shape[0]

    # one-hot receiver matrix: R[e, i] = 1 iff edge e scatters to node i
    iota = jax.lax.broadcasted_iota(jnp.int32, (be, cap), 1)
    onehot = r[:, None] == iota                     # (be, cap) bool
    R = onehot.astype(jnp.float32)

    # gather receiver queries and take the fused logit row-sum (the last
    # q column is 1, the last k column carries bias / the -1e9 edge mask)
    q_e = jnp.dot(R, q, preferred_element_type=jnp.float32)   # (be, Fp)
    logit = jnp.sum(q_e * k, axis=1)                          # (be,)

    # online softmax per receiver segment (flash recurrence over blocks)
    blk = jnp.where(onehot, logit[:, None], NEG_INF)          # (be, cap)
    m_blk = jnp.max(blk, axis=0)                              # (cap,)
    m_old = m_ref[:, 0]
    m_new = jnp.maximum(m_old, m_blk)
    corr = jnp.exp(m_old - m_new)                             # (cap,)
    p = jnp.exp(logit - jnp.dot(R, m_new,
                                preferred_element_type=jnp.float32))
    l_new = l_ref[:, 0] * corr + jnp.dot(
        R.T, p, preferred_element_type=jnp.float32)           # (cap,)
    acc_new = acc_ref[...] * corr[:, None] + jnp.dot(
        R.T, p[:, None] * v_ref[...],
        preferred_element_type=jnp.float32)                   # (cap, W)

    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new
    acc_ref[...] = acc_new

    @pl.when(e == n_eb - 1)
    def _done():
        # nodes that never appeared as receivers keep l == 0 -> output 0
        o_ref[...] = acc_new / jnp.maximum(l_new, 1e-20)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cap", "be", "interpret"))
def edge_softmax_kernel(q, k_e, recv_local, values, *, cap: int,
                        be: int = DEFAULT_BE, interpret: bool = False):
    """Fused segment softmax + weighted scatter over per-molecule edges.

    q:          (B * cap, Fp) f32 — node queries, scale folded in, last
                column constant 1 (bias pickup).
    k_e:        (B * ec, Fp) f32 — gathered sender keys; last column is
                the attention bias, -1e9 on masked edge slots.
    recv_local: (B * ec,) int32 — receiver index within the molecule.
    values:     (B * ec, W) f32 — per-edge values, zero on masked slots.

    Returns (B * cap, W) f32: out[i] = sum_e alpha_e * values[e] over
    edges received by node i, alpha the segment softmax of the logits.
    ec must be a multiple of ``be``; Fp and W should be lane-aligned
    (multiples of 128) for the compiled path — the ops wrapper pads.
    """
    n_nodes, fp = q.shape
    n_edges, w = values.shape
    assert n_nodes % cap == 0, (n_nodes, cap)
    b = n_nodes // cap
    assert n_edges % b == 0, (n_edges, b)
    ec = n_edges // b
    assert ec % be == 0, f"edge capacity {ec} not a multiple of block {be}"
    n_eb = ec // be
    grid = (b, n_eb)
    return pl.pallas_call(
        _edge_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cap, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((be, fp), lambda i, j, n_eb=n_eb: (i * n_eb + j, 0)),
            pl.BlockSpec((be,), lambda i, j, n_eb=n_eb: (i * n_eb + j,)),
            pl.BlockSpec((be, w), lambda i, j, n_eb=n_eb: (i * n_eb + j, 0)),
        ],
        out_specs=pl.BlockSpec((cap, w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, w), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((cap, 1), jnp.float32),   # running max m
            pltpu.VMEM((cap, 1), jnp.float32),   # running denom l
            pltpu.VMEM((cap, w), jnp.float32),   # running numerator acc
        ],
        interpret=interpret,
    )(q, k_e, recv_local, values)
