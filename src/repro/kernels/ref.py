"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizers import unpack_int4


# --- quant_matmul ----------------------------------------------------------

def w8a8_matmul_ref(a_q, a_scale, w_q, w_scale):
    """int8 x int8 matmul with row/col scales. All math in f32/int32."""
    acc = jnp.matmul(a_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return acc.astype(jnp.float32) * a_scale * w_scale


def w4a8_matmul_ref(a_q, a_scale, w_packed, w_scale):
    w_q = unpack_int4(w_packed)  # unpack along N: (K, N//2) -> (K, N)
    return w8a8_matmul_ref(a_q, a_scale, w_q, w_scale)


# --- mddq -------------------------------------------------------------------

def mddq_encode_ref(v, codebook, mag_bits=8, m_min=1e-6, m_max=1e3):
    """v: (N, 3) -> (dir_idx int32 (N,), mag_code int32 (N,))."""
    m = jnp.linalg.norm(v, axis=-1)
    u = v / jnp.maximum(m[..., None], 1e-12)
    idx = jnp.argmax(u @ codebook.T, axis=-1).astype(jnp.int32)
    levels = 2 ** mag_bits - 1
    lo, hi = jnp.log(m_min), jnp.log(m_max)
    t = (jnp.log(jnp.clip(m, m_min, m_max)) - lo) / (hi - lo)
    mag = jnp.clip(jnp.round(t * levels), 0, levels).astype(jnp.int32)
    return idx, mag


# --- edge softmax (sparse serving path) --------------------------------------

def edge_softmax_ref(q_scaled, k, bias, senders, receivers, edge_mask,
                     values, n_nodes):
    """Segment softmax + weighted segment-sum over an edge list.

    q_scaled/k: (N, F) node features (attention scale folded into q);
    bias/senders/receivers/edge_mask: (E,); values: (E, W).
    Returns (N, W): out[i] = sum_{e: recv=i} alpha_e * values[e] with
    alpha the per-receiver softmax of q[recv] . k[send] + bias. Masked
    edges get logit -1e9 and zeroed values; receivers with no real edges
    yield exactly zero.
    """
    logits = jnp.sum(q_scaled[receivers] * k[senders], axis=-1) + bias
    logits = jnp.where(edge_mask, logits, -1e9)
    seg_max = jax.ops.segment_max(logits, receivers, n_nodes)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    p = jnp.exp(logits - seg_max[receivers])
    denom = jax.ops.segment_sum(p, receivers, n_nodes)
    num = jax.ops.segment_sum(p[:, None] * (values * edge_mask[:, None]),
                              receivers, n_nodes)
    return num / jnp.maximum(denom, 1e-20)[:, None]


# --- int8-KV decode attention ------------------------------------------------

def decode_attention_int8kv_ref(q, k_q, k_scale, v_q, v_scale, *, softmax_scale):
    """One-token flash-decode with int8 KV cache.

    q: (BH, D) f32; k_q/v_q: (BH, S, D) int8; k_scale/v_scale: (BH, S) f32.
    Returns (BH, D) f32.
    """
    k = k_q.astype(jnp.float32) * k_scale[..., None]
    v = v_q.astype(jnp.float32) * v_scale[..., None]
    logits = jnp.einsum("bd,bsd->bs", q, k) * softmax_scale
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bs,bsd->bd", w, v)
