"""Pallas TPU kernel: MDDQ encode (direction codebook argmax + log-magnitude).

The hot loop of GAQ serving: for a block of l=1 feature vectors, find the
nearest spherical codeword (argmax of dot products against the codebook) and
the log-domain magnitude code. Memory layout is TPU-native: vectors arrive as
three planar components (N,) each (so the minor dimension is the N lane axis,
128-aligned), the codebook sits VMEM-resident as (3, C) with C a multiple of
128, and the score matrix (bn, C) is a VPU-friendly outer product.

Compression: 3x f32 (96 bits) -> dir_bits + mag_bits (16 bits) = 6x.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 1024  # vectors per block


def _mddq_kernel(vx_ref, vy_ref, vz_ref, cb_ref, idx_ref, mag_ref, *,
                 mag_bits: int, m_min: float, m_max: float):
    vx, vy, vz = vx_ref[...], vy_ref[...], vz_ref[...]      # (bn,)
    m = jnp.sqrt(vx * vx + vy * vy + vz * vz)               # (bn,)
    inv = 1.0 / jnp.maximum(m, 1e-12)
    ux, uy, uz = vx * inv, vy * inv, vz * inv

    cb = cb_ref[...]                                         # (3, C)
    # scores (bn, C): outer products on the VPU. The 128-alignment padding
    # of the codebook (ops.pad_codebook) appends COPIES OF CODEWORD 0, so
    # a padded column can only ever tie codeword 0's score — and argmax
    # returns the first maximizing index, i.e. the real index 0, never a
    # padded slot. (Padding with zero vectors would NOT be safe: score 0
    # beats every real codeword in the half-sphere opposite to u.)
    scores = (ux[:, None] * cb[0][None, :]
              + uy[:, None] * cb[1][None, :]
              + uz[:, None] * cb[2][None, :])
    idx_ref[...] = jnp.argmax(scores, axis=-1).astype(jnp.int32)

    levels = 2 ** mag_bits - 1
    lo = jnp.log(m_min)
    hi = jnp.log(m_max)
    t = (jnp.log(jnp.clip(m, m_min, m_max)) - lo) / (hi - lo)
    mag_ref[...] = jnp.clip(jnp.round(t * levels), 0, levels).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bn", "mag_bits", "m_min",
                                             "m_max", "interpret"))
def mddq_encode_kernel(vx, vy, vz, codebook_t, *, bn=DEFAULT_BN, mag_bits=8,
                       m_min=1e-6, m_max=1e3, interpret=False):
    """vx/vy/vz: (N,) f32 planar components; codebook_t: (3, C) f32.

    N must be a multiple of bn; C a multiple of 128 (pad with copies of the
    first codeword). Returns (idx int32 (N,), mag int32 (N,)).
    """
    n = vx.shape[0]
    assert n % bn == 0, f"N={n} not divisible by block {bn}"
    c = codebook_t.shape[1]
    assert c % 128 == 0, f"codebook size {c} must be 128-aligned (pad it)"
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_mddq_kernel, mag_bits=mag_bits, m_min=m_min,
                          m_max=m_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((3, c), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(vx, vy, vz, codebook_t)
