"""Pallas TPU kernel: fused dequantize-matmul for W8A8 and W4A8.

The paper's speedup comes from streaming quantized weights (4x / 8x fewer
HBM bytes) and dequantizing on the fly next to the compute unit. On TPU that
means: int8/int4 weight tiles live in VMEM, nibble-unpack + scale happen in
registers, and the MXU consumes int8 x int8 -> int32 (W8A8) or bf16 (after
in-kernel dequant, W4A8).

Layouts (MXU-aligned, multiples of 128 on the minor dims):
  a_q     (M, K)  int8     per-row-quantized activations
  a_scale (M, 1)  f32
  w_q     (K, N)  int8     (W8 path)   per-column scales w_scale (1, N) f32
  w_p     (K, N//2) uint8  (W4 path)   two nibbles per byte along N
  out     (M, N)  f32

Grid = (M/bm, N/bn, K/bk), K innermost; partial products accumulate into an
f32 VMEM scratch tile and are written out on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _w8a8_kernel(a_ref, as_ref, w_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                        # (bm, bk) int8
    w = w_ref[...]                        # (bk, bn) int8
    # int8 x int8 -> int32 on the MXU
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...] * as_ref[...] * ws_ref[...]


def _unpack_nibbles(p: jnp.ndarray) -> jnp.ndarray:
    """(bk, bn//2) uint8 -> (bk, bn) int8, low nibble first."""
    p = p.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    # interleave: out[:, 2i] = lo[:, i], out[:, 2i+1] = hi[:, i]
    stacked = jnp.stack([lo, hi], axis=-1)          # (bk, bn//2, 2)
    return stacked.reshape(p.shape[0], p.shape[1] * 2).astype(jnp.int8)


def _w4a8_kernel(a_ref, as_ref, wp_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                        # (bm, bk) int8
    w = _unpack_nibbles(wp_ref[...])      # (bk, bn) int8
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...] * as_ref[...] * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def w8a8_matmul(a_q, a_scale, w_q, w_scale, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                bk=DEFAULT_BK, interpret=False):
    """Fused int8 x int8 -> f32 matmul with on-the-fly dequantization.

    out[m, n] = sum_k a_q[m, k] * w_q[k, n] * a_scale[m, 0] * w_scale[0, n]

    Accumulation is int32 on the MXU (exact); the scale multiply happens
    once per output tile in f32. All of (M, N, K) must be divisible by the
    block sizes — callers that cannot guarantee that should go through
    ``repro.kernels.ops.matmul_w8a8``, which zero-pads to the 128-aligned
    contract and slices the result. ``interpret=True`` runs the identical
    kernel through the Pallas interpreter on CPU (no TPU required).
    """
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape {(m, k, n)} not divisible by blocks {(bm, bn, bk)}"
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w8a8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_q, a_scale, w_q, w_scale)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def w4a8_matmul(a_q, a_scale, w_packed, w_scale, *, bm=DEFAULT_BM,
                bn=DEFAULT_BN, bk=DEFAULT_BK, interpret=False):
    """W4A8 variant of :func:`w8a8_matmul`: weights arrive nibble-packed.

    ``w_packed`` holds two signed 4-bit values per uint8 along N (low
    nibble first, see ``repro.core.quantizers.pack_int4``), so HBM traffic
    for weights is 1/8 of fp32. Nibbles are sign-extended to int8 inside
    the kernel (in VMEM/registers) and fed to the MXU as int8 x int8 ->
    int32, identical to the W8 path from there on. Same 128-alignment
    contract and ``interpret`` fallback as :func:`w8a8_matmul`.
    """
    m, k = a_q.shape
    k2, n_half = w_packed.shape
    n = n_half * 2
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape {(m, k, n)} not divisible by blocks {(bm, bn, bk)}"
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w4a8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_q, a_scale, w_packed, w_scale)
