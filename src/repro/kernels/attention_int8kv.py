"""Pallas TPU kernel: flash-decode attention with an int8-quantized KV cache.

Beyond-paper extension of the memory-wall argument: at decode time the KV
cache dominates HBM traffic (it is read in full for every generated token).
Quantizing K/V to int8 with per-token scales quarters that traffic; this
kernel streams int8 KV tiles into VMEM, dequantizes in-register, and runs an
online-softmax (flash) reduction over sequence tiles.

Shapes (one decoded token):
  q        (BH, D)      f32   (BH = batch*kv_heads*q_per_kv collapsed)
  k_q,v_q  (BH, S, D)   int8
  k_s,v_s  (BH, S)      f32   per-token scales
  out      (BH, D)      f32

Grid = (BH, S/bs) with S innermost; running max/sum/acc live in VMEM scratch
and persist across the S iterations (TPU grid order is sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
_NEG_INF = -1e30


def _decode_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_s: int, softmax_scale: float):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                                  # (1, D)
    k = kq_ref[0].astype(jnp.float32) * ks_ref[0][:, None]   # (bs, D)
    v = vq_ref[0].astype(jnp.float32) * vs_ref[0][:, None]   # (bs, D)

    logits = (k @ q[0]) * softmax_scale             # (bs,)
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                     # (bs,)

    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + (p @ v)[None, :]
    m_ref[0, 0] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[...] = acc_ref[...] / l_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_int8kv(q, k_q, k_scale, v_q, v_scale, *,
                            softmax_scale: float | None = None,
                            bs: int = DEFAULT_BS, interpret: bool = False):
    bh, d = q.shape
    bh2, seq, d2 = k_q.shape
    assert bh == bh2 and d == d2 and seq % bs == 0, \
        f"bad shapes q{q.shape} k{k_q.shape} bs={bs}"
    if softmax_scale is None:
        softmax_scale = 1.0 / (d ** 0.5)
    n_s = seq // bs
    grid = (bh, n_s)
    return pl.pallas_call(
        functools.partial(_decode_kernel, n_s=n_s,
                          softmax_scale=float(softmax_scale)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda b, s: (b, 0)),
            pl.BlockSpec((1, bs, d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs), lambda b, s: (b, s)),
            pl.BlockSpec((1, bs, d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs), lambda b, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, s: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running denom
            pltpu.VMEM((1, d), jnp.float32),   # running numerator
        ],
        interpret=interpret,
    )(q, k_q, k_scale, v_q, v_scale)
