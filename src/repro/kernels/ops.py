"""Jit'd public wrappers around the Pallas kernels.

Each wrapper handles: dynamic activation quantization, padding to block
multiples, platform dispatch (interpret=True on CPU so the same code runs in
this container; compiled path on TPU), and the packing/layout transforms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import abs_max_scale, pack_int4, quantize
from . import quant_matmul as _qm
from . import mddq_kernel as _mk
from . import attention_int8kv as _ak


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --- weight preparation (offline) -------------------------------------------

def prepare_w8(w: jnp.ndarray):
    """fp32 (K, N) -> (w_q int8 (K, N), w_scale f32 (1, N)) per-column."""
    scale = abs_max_scale(w, 8, channel_axis=1)
    return quantize(w, scale, 8), scale


def prepare_w4(w: jnp.ndarray):
    """fp32 (K, N) -> (packed uint8 (K, N//2), w_scale f32 (1, N))."""
    scale = abs_max_scale(w, 4, channel_axis=1)
    q = quantize(w, scale, 4)
    return pack_int4(q), scale


def quantize_activations(x: jnp.ndarray, bits: int = 8):
    """fp (M, K) -> (int8 (M, K), scale f32 (M, 1)) per-row dynamic."""
    scale = abs_max_scale(x, bits, channel_axis=0)
    return quantize(x, scale, bits), scale


# --- quantized matmul --------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block",))
def matmul_w8a8(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                block: tuple = (128, 128, 128)) -> jnp.ndarray:
    """y = x @ dequant(w). x: (M, K) fp; w_q: (K, N) int8."""
    m, k = x.shape
    n = w_q.shape[1]
    bm, bn, bk = block
    a_q, a_scale = quantize_activations(x)
    a_q = _pad_to(_pad_to(a_q, 0, bm), 1, bk)
    a_scale = _pad_to(a_scale, 0, bm)
    w_pad = _pad_to(_pad_to(w_q, 0, bk), 1, bn)
    s_pad = _pad_to(w_scale, 1, bn)
    out = _qm.w8a8_matmul(a_q, a_scale, w_pad, s_pad, bm=bm, bn=bn, bk=bk,
                          interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block",))
def matmul_w4a8(x: jnp.ndarray, w_packed: jnp.ndarray, w_scale: jnp.ndarray,
                block: tuple = (128, 128, 128)) -> jnp.ndarray:
    """y = x @ dequant(w). w_packed: (K, N//2) uint8 nibbles."""
    m, k = x.shape
    n = w_packed.shape[1] * 2
    bm, bn, bk = block
    a_q, a_scale = quantize_activations(x)
    a_q = _pad_to(_pad_to(a_q, 0, bm), 1, bk)
    a_scale = _pad_to(a_scale, 0, bm)
    w_pad = _pad_to(_pad_to(w_packed, 0, bk), 1, bn // 2)
    s_pad = _pad_to(w_scale, 1, bn)
    out = _qm.w4a8_matmul(a_q, a_scale, w_pad, s_pad, bm=bm, bn=bn, bk=bk,
                          interpret=_interpret())
    return out[:m, :n]


# --- MDDQ encode --------------------------------------------------------------

def pad_codebook(codebook: jnp.ndarray) -> jnp.ndarray:
    """(C, 3) -> transposed (3, C128) padded with copies of codeword 0."""
    c = codebook.shape[0]
    pad = (-c) % 128
    if pad:
        codebook = jnp.concatenate(
            [codebook, jnp.tile(codebook[:1], (pad, 1))], axis=0)
    return codebook.T.copy()


@functools.partial(jax.jit, static_argnames=("bn",))
def mddq_encode(v: jnp.ndarray, codebook_t: jnp.ndarray, bn: int = 1024):
    """v: (..., 3) fp -> (dir_idx int32, mag_code int32) of shape (...)."""
    lead = v.shape[:-1]
    flat = v.reshape(-1, 3)
    n = flat.shape[0]
    npad = (-n) % bn
    if npad:
        flat = jnp.concatenate([flat, jnp.ones((npad, 3), flat.dtype)], 0)
    idx, mag = _mk.mddq_encode_kernel(
        flat[:, 0].copy(), flat[:, 1].copy(), flat[:, 2].copy(), codebook_t,
        bn=min(bn, flat.shape[0]), interpret=_interpret())
    return idx[:n].reshape(lead), mag[:n].reshape(lead)


# --- int8-KV decode attention --------------------------------------------------

def prepare_kv_int8(k: jnp.ndarray, v: jnp.ndarray):
    """(BH, S, D) fp -> int8 caches + per-token scales (BH, S)."""
    ks = jnp.maximum(jnp.max(jnp.abs(k), axis=-1), 1e-8) / 127.0
    vs = jnp.maximum(jnp.max(jnp.abs(v), axis=-1), 1e-8) / 127.0
    k_q = jnp.clip(jnp.round(k / ks[..., None]), -127, 127).astype(jnp.int8)
    v_q = jnp.clip(jnp.round(v / vs[..., None]), -127, 127).astype(jnp.int8)
    return k_q, ks, v_q, vs


@functools.partial(jax.jit, static_argnames=("bs",))
def decode_attention_int8kv(q, k_q, k_scale, v_q, v_scale, bs: int = 512):
    """q: (BH, D); int8 KV (BH, S, D) with (BH, S) scales -> (BH, D)."""
    seq = k_q.shape[1]
    bs = min(bs, seq)
    pad = (-seq) % bs
    if pad:
        k_q = _pad_to(k_q, 1, bs)
        v_q = _pad_to(v_q, 1, bs)
        # padded tokens get zero scale -> dequantized to 0; logits = 0 would
        # still get softmax mass, so push them to -inf via a large-negative
        # k scale trick: zero K gives logit 0; instead mask via v_scale=0 and
        # renormalize? Cleanest: set k_scale pad to 0 and subtract mass of
        # pad tokens is wrong. We require S % bs == 0 for exactness.
        raise ValueError(f"S={seq} must be a multiple of bs={bs}")
    return _ak.decode_attention_int8kv(q, k_q, k_scale, v_q, v_scale, bs=bs,
                                       interpret=_interpret())
