"""Jit'd public wrappers around the Pallas kernels.

Each wrapper handles: dynamic activation quantization, padding to block
multiples, platform dispatch (interpret=True on CPU so the same code runs in
this container; compiled path on TPU), and the packing/layout transforms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import (abs_max_scale, dequantize_log_magnitude,
                                   pack_int4, quantize)
from . import quant_matmul as _qm
from . import mddq_kernel as _mk
from . import attention_int8kv as _ak
from . import edge_softmax as _es
from . import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --- weight preparation (offline) -------------------------------------------

def prepare_w8(w: jnp.ndarray):
    """fp32 (K, N) -> (w_q int8 (K, N), w_scale f32 (1, N)) per-column."""
    scale = abs_max_scale(w, 8, channel_axis=1)
    return quantize(w, scale, 8), scale


def prepare_w4(w: jnp.ndarray):
    """fp32 (K, N) -> (packed uint8 (K, N//2), w_scale f32 (1, N))."""
    scale = abs_max_scale(w, 4, channel_axis=1)
    q = quantize(w, scale, 4)
    return pack_int4(q), scale


def quantize_activations(x: jnp.ndarray, bits: int = 8):
    """fp (M, K) -> (int8 (M, K), scale f32 (M, 1)) per-row dynamic."""
    scale = abs_max_scale(x, bits, channel_axis=0)
    return quantize(x, scale, bits), scale


# --- quantized matmul --------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block",))
def matmul_w8a8(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                block: tuple = (128, 128, 128)) -> jnp.ndarray:
    """y = x @ dequant(w). x: (M, K) fp; w_q: (K, N) int8."""
    m, k = x.shape
    n = w_q.shape[1]
    bm, bn, bk = block
    a_q, a_scale = quantize_activations(x)
    a_q = _pad_to(_pad_to(a_q, 0, bm), 1, bk)
    a_scale = _pad_to(a_scale, 0, bm)
    w_pad = _pad_to(_pad_to(w_q, 0, bk), 1, bn)
    s_pad = _pad_to(w_scale, 1, bn)
    out = _qm.w8a8_matmul(a_q, a_scale, w_pad, s_pad, bm=bm, bn=bn, bk=bk,
                          interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block",))
def matmul_w4a8(x: jnp.ndarray, w_packed: jnp.ndarray, w_scale: jnp.ndarray,
                block: tuple = (128, 128, 128)) -> jnp.ndarray:
    """y = x @ dequant(w). w_packed: (K, N//2) uint8 nibbles."""
    m, k = x.shape
    n = w_packed.shape[1] * 2
    bm, bn, bk = block
    a_q, a_scale = quantize_activations(x)
    a_q = _pad_to(_pad_to(a_q, 0, bm), 1, bk)
    a_scale = _pad_to(a_scale, 0, bm)
    w_pad = _pad_to(_pad_to(w_packed, 0, bk), 1, bn // 2)
    s_pad = _pad_to(w_scale, 1, bn)
    out = _qm.w4a8_matmul(a_q, a_scale, w_pad, s_pad, bm=bm, bn=bn, bk=bk,
                          interpret=_interpret())
    return out[:m, :n]


# --- MDDQ encode --------------------------------------------------------------

def pad_codebook(codebook: jnp.ndarray) -> jnp.ndarray:
    """(C, 3) -> transposed (3, C128) padded with copies of codeword 0."""
    c = codebook.shape[0]
    pad = (-c) % 128
    if pad:
        codebook = jnp.concatenate(
            [codebook, jnp.tile(codebook[:1], (pad, 1))], axis=0)
    return codebook.T.copy()


@functools.partial(jax.jit,
                   static_argnames=("bn", "mag_bits", "m_min", "m_max"))
def mddq_encode(v: jnp.ndarray, codebook_t: jnp.ndarray, bn: int = 1024,
                mag_bits: int = 8, m_min: float = 1e-6, m_max: float = 1e3):
    """v: (..., 3) fp -> (dir_idx int32, mag_code int32) of shape (...)."""
    lead = v.shape[:-1]
    flat = v.reshape(-1, 3)
    n = flat.shape[0]
    npad = (-n) % bn
    if npad:
        flat = jnp.concatenate([flat, jnp.ones((npad, 3), flat.dtype)], 0)
    idx, mag = _mk.mddq_encode_kernel(
        flat[:, 0].copy(), flat[:, 1].copy(), flat[:, 2].copy(), codebook_t,
        bn=min(bn, flat.shape[0]), mag_bits=mag_bits, m_min=m_min,
        m_max=m_max, interpret=_interpret())
    return idx[:n].reshape(lead), mag[:n].reshape(lead)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mddq_qdq_kernel(v, mddq_cfg, codebook):
    """Serve-time MDDQ quantize-dequantize through the Pallas encode kernel.

    Forward: ``mddq_encode_kernel`` (codebook argmax + log-magnitude code)
    followed by the table decode — the value the serving engine would
    reconstruct from stored codes. Backward: the Geometric-STE gradients
    of the pure-jnp reference ``core.mddq.mddq_fake_quant`` (same pattern
    as ``qmatmul``: integer forward, straight-through backward), so forces
    differentiate through the kernel path. Zero vectors map to exactly
    zero, matching the reference (isolated atoms, padded slots).

    v: (..., 3); mddq_cfg: ``core.mddq.MDDQConfig`` (static, hashable);
    codebook: (C, 3). ``ServeConfig.mddq_kernel`` selects this over the
    fake-quant reference.
    """
    return _mddq_qdq_impl(v, mddq_cfg, codebook)


def _mddq_qdq_impl(v, mddq_cfg, codebook):
    if mddq_cfg.magnitude_domain != "log":
        raise NotImplementedError(
            "mddq_encode_kernel quantizes magnitudes on the log grid only; "
            "use the fake-quant reference for linear-domain configs")
    idx, mag = mddq_encode(v, pad_codebook(codebook),
                           mag_bits=mddq_cfg.magnitude_bits,
                           m_min=mddq_cfg.m_min, m_max=mddq_cfg.m_max)
    m_q = dequantize_log_magnitude(mag, mddq_cfg.magnitude_bits,
                                   mddq_cfg.m_min, mddq_cfg.m_max)
    out = codebook[idx] * m_q[..., None]
    m2 = jnp.sum(v * v, axis=-1, keepdims=True)
    return jnp.where(m2 <= 1e-24, 0.0, out)  # 1e-24 = core.mddq._EPS ** 2


def _mddq_qdq_fwd(v, mddq_cfg, codebook):
    return _mddq_qdq_impl(v, mddq_cfg, codebook), (v, codebook)


def _mddq_qdq_bwd(mddq_cfg, res, g):
    from repro.core.mddq import mddq_fake_quant
    v, codebook = res
    _, vjp = jax.vjp(lambda v_: mddq_fake_quant(v_, mddq_cfg, codebook), v)
    (gv,) = vjp(g)
    return gv, jnp.zeros_like(codebook)  # codebook frozen at serve time


mddq_qdq_kernel.defvjp(_mddq_qdq_fwd, _mddq_qdq_bwd)


# --- fused edge softmax (sparse serving path) ---------------------------------

_NEG_BIAS = -1e9  # masked-edge logit; matches the dense forward's pair mask


def _edge_onehot(idx: jnp.ndarray, cap: int, n_edges: int, n_nodes: int,
                 dtype) -> jnp.ndarray:
    """(B, cap, ec) one-hot of local node index per edge slot — the
    segment-reduction operand of the blocked CPU path: a segment sum over
    receivers (or a gather backward over senders) becomes one batched
    matmul against this, which XLA lowers to gemm instead of the
    serialized scatters ``jax.ops.segment_*`` produce on CPU. Valid only
    under the ``bucketing.EdgeList`` layout (every slot's node index
    inside its molecule's range)."""
    B = n_nodes // cap
    ec = n_edges // B
    local = (idx % cap).reshape(B, 1, ec)
    return (local == jnp.arange(cap, dtype=idx.dtype)[None, :, None]) \
        .astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _edge_gather_blocked(x, idx, cap):
    return x[idx]


def _edge_gather_fwd(x, idx, cap):
    return x[idx], (idx, x.shape[0])


def _edge_gather_bwd(cap, res, g):
    idx, n_nodes = res
    onehot = _edge_onehot(idx, cap, idx.shape[0], n_nodes, g.dtype)
    gx = jnp.matmul(onehot, g.reshape(onehot.shape[0], onehot.shape[2], -1))
    return gx.reshape(n_nodes, *g.shape[1:]), np.zeros(idx.shape,
                                                       jax.dtypes.float0)


_edge_gather_blocked.defvjp(_edge_gather_fwd, _edge_gather_bwd)


def edge_gather(x, idx, cap):
    """``x[idx]`` for edge lists in the ``bucketing.EdgeList`` layout.

    On CPU the gather carries a blocked backward: its VJP is a segment
    sum of the cotangent over ``idx``, implemented as a per-molecule
    one-hot matmul (gemm, B·cap·ec·W MACs) instead of the scatter-add
    XLA emits — CPU backends serialize scatters, so the arithmetic
    inflation wins there; same sums, different (still deterministic)
    summation order. Other backends (TPU/GPU compile scatters natively)
    keep the plain gather and its native scatter-add VJP. x: (N, W)
    node features, idx: (E,) int32 slot indices respecting per-molecule
    ranges; cap static. The sparse forward routes its sender/receiver
    gathers through this.
    """
    if jax.default_backend() == "cpu":
        return _edge_gather_blocked(x, idx, cap)
    return x[idx]


def _edge_softmax_blocked(q_scaled, k, bias, values, senders, receivers,
                          edge_mask, cap):
    """CPU implementation of ``edge_softmax`` under the EdgeList
    layout contract: the W-wide segment reductions (numerator and
    denominator) run blocked per molecule as one batched matmul against
    the (B, cap, ec) one-hot, carrying the value matrix and the
    denominator column together; only the scalar stabilizing max stays a
    scatter. Matches ``ref.edge_softmax_ref`` to ~1e-6 (summation order
    differs; the max subtraction is stop-gradiented, which cancels
    analytically).
    """
    N = q_scaled.shape[0]
    E, w = values.shape
    B = N // cap
    ec = E // B

    logits = jnp.sum(edge_gather(q_scaled, receivers, cap)
                     * edge_gather(k, senders, cap), axis=-1) + bias
    logits = jnp.where(edge_mask, logits, _NEG_BIAS)
    onehot = _edge_onehot(receivers, cap, E, N, values.dtype)
    # the max stays a scatter (one scalar per edge, and stop-gradiented
    # so it has no backward); only the W-wide sums go through the matmul
    seg_max = jax.ops.segment_max(jax.lax.stop_gradient(logits),
                                  receivers, N)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    p = jnp.exp(logits - seg_max[receivers])               # (E,)
    pv = jnp.concatenate([p[:, None] * (values * edge_mask[:, None]),
                          p[:, None]], axis=1)             # (E, w + 1)
    out = jnp.matmul(onehot, pv.reshape(B, ec, w + 1))     # (B, cap, w+1)
    num = out[..., :w].reshape(N, w)
    denom = out[..., w].reshape(N)
    # double-where: receivers with no edges (denom == 0) must yield 0
    # without 1/denom^2 ever being evaluated in the backward (the
    # oracle's maximum(denom, 1e-20) overflows f32 there: 1e40 * 0 = nan)
    safe = jnp.where(denom > 0, denom, 1.0)[:, None]
    return jnp.where(denom[:, None] > 0, num / safe, 0.0)


def refine_edge_mask(coords_flat: jnp.ndarray, senders: jnp.ndarray,
                     receivers: jnp.ndarray, edge_mask: jnp.ndarray,
                     cutoff: float) -> jnp.ndarray:
    """Dynamic cutoff refinement for Verlet-skin neighbour lists.

    A skin list is built once with an enlarged ``cutoff + skin`` radius
    and reused across MD steps; before each force evaluation the mask is
    tightened to the *true* cutoff at the current coordinates, so the
    edge set entering ``edge_softmax`` is exactly the fresh-rebuild set
    (the predicate ``d^2 < cutoff^2`` matches ``device_edge_list``).
    Lives here because it is mask-layout prep on the kernel input path —
    the same masking ``_edge_softmax_pallas`` folds into the key matrix.
    Boolean output: carries no gradient, like the dense path's pair mask.

    coords_flat: (N, 3) flat node coordinates; senders/receivers:
    (E,) int32; edge_mask: (E,) bool (the skin list's validity bits).
    """
    rij = coords_flat[senders] - coords_flat[receivers]
    d2 = jnp.sum(rij * rij, axis=-1)
    return edge_mask & (d2 < cutoff * cutoff)


def _edge_softmax_pallas(q_scaled, k, bias, values, senders, receivers,
                         edge_mask, cap):
    """Layout prep + kernel launch. Folds the bias into the key's last
    column (queries get a constant-1 column), zeroes masked keys/values,
    localizes receiver indices, and pads feature dims to the 128-lane
    contract before calling ``edge_softmax_kernel``."""
    n, _ = q_scaled.shape
    w = values.shape[1]
    qp = _pad_to(jnp.concatenate(
        [q_scaled, jnp.ones((n, 1), q_scaled.dtype)], axis=1), 1, 128)
    k_e = k[senders] * edge_mask[:, None]
    bias_m = jnp.where(edge_mask, bias, _NEG_BIAS)
    kp = _pad_to(jnp.concatenate([k_e, bias_m[:, None]], axis=1), 1, 128)
    vp = _pad_to(values * edge_mask[:, None], 1, 128)
    recv_local = (receivers % cap).astype(jnp.int32)
    out = _es.edge_softmax_kernel(qp, kp, recv_local, vp, cap=cap,
                                  interpret=_interpret())
    return out[:, :w]


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _edge_softmax_fused(q_scaled, k, bias, values, senders, receivers,
                        edge_mask, cap):
    return _edge_softmax_pallas(q_scaled, k, bias, values, senders,
                                receivers, edge_mask, cap)


def _edge_softmax_fwd(q_scaled, k, bias, values, senders, receivers,
                      edge_mask, cap):
    out = _edge_softmax_pallas(q_scaled, k, bias, values, senders,
                               receivers, edge_mask, cap)
    return out, (q_scaled, k, bias, values, senders, receivers, edge_mask)


def _edge_softmax_bwd(cap, res, g):
    # true gradients via the jnp oracle (identical math to the kernel);
    # forces F = -dE/dr differentiate through the fused forward this way
    q_scaled, k, bias, values, senders, receivers, edge_mask = res

    def f(q_, k_, b_, v_):
        return _ref.edge_softmax_ref(q_, k_, b_, senders, receivers,
                                     edge_mask, v_, q_.shape[0])

    _, vjp = jax.vjp(f, q_scaled, k, bias, values)
    gq, gk, gb, gv = vjp(g)
    f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)  # int/bool inputs
    return gq, gk, gb, gv, f0(senders), f0(receivers), f0(edge_mask)


_edge_softmax_fused.defvjp(_edge_softmax_fwd, _edge_softmax_bwd)


def edge_softmax(q_scaled, k, bias, values, senders, receivers, edge_mask,
                 *, cap: int, use_kernel=None):
    """out[i] = sum_{e: recv(e)=i} alpha_e * values[e], alpha the segment
    softmax of q_scaled[recv] . k[send] + bias over each receiver.

    ``use_kernel=None`` auto-selects by backend: the fused Pallas kernel
    only on TPU (its block specs and VMEM scratch are TPU-specific); on
    CPU the blocked XLA path (``_edge_softmax_blocked``: per-molecule
    one-hot matmuls instead of the scatters CPU backends serialize —
    the interpreter has nothing to fuse *for* there); on GPU the
    scatter-based oracle (``ref.edge_softmax_ref``), whose segment ops
    compile natively — the blocked path's ~cap-fold arithmetic
    inflation only pays off where scatters are serialized. Pass
    True/False to force the kernel on/off (tests force True to exercise
    it under interpret). Inputs must follow the ``bucketing.EdgeList``
    layout (per-molecule slot ranges — the kernel and blocked paths
    localize indices with ``% cap``). All paths agree to ~1e-6 and all
    are differentiable (the kernel via a custom VJP whose backward runs
    the oracle's gradients).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return _edge_softmax_fused(q_scaled, k, bias, values, senders,
                                   receivers, edge_mask, cap)
    if jax.default_backend() == "cpu":
        return _edge_softmax_blocked(q_scaled, k, bias, values, senders,
                                     receivers, edge_mask, cap)
    return _ref.edge_softmax_ref(q_scaled, k, bias, senders, receivers,
                                 edge_mask, values, q_scaled.shape[0])


# --- int8-KV decode attention --------------------------------------------------

def prepare_kv_int8(k: jnp.ndarray, v: jnp.ndarray):
    """(BH, S, D) fp -> int8 caches + per-token scales (BH, S)."""
    ks = jnp.maximum(jnp.max(jnp.abs(k), axis=-1), 1e-8) / 127.0
    vs = jnp.maximum(jnp.max(jnp.abs(v), axis=-1), 1e-8) / 127.0
    k_q = jnp.clip(jnp.round(k / ks[..., None]), -127, 127).astype(jnp.int8)
    v_q = jnp.clip(jnp.round(v / vs[..., None]), -127, 127).astype(jnp.int8)
    return k_q, ks, v_q, vs


@functools.partial(jax.jit, static_argnames=("bs",))
def decode_attention_int8kv(q, k_q, k_scale, v_q, v_scale, bs: int = 512):
    """q: (BH, D); int8 KV (BH, S, D) with (BH, S) scales -> (BH, D)."""
    seq = k_q.shape[1]
    bs = min(bs, seq)
    pad = (-seq) % bs
    if pad:
        k_q = _pad_to(k_q, 1, bs)
        v_q = _pad_to(v_q, 1, bs)
        # padded tokens get zero scale -> dequantized to 0; logits = 0 would
        # still get softmax mass, so push them to -inf via a large-negative
        # k scale trick: zero K gives logit 0; instead mask via v_scale=0 and
        # renormalize? Cleanest: set k_scale pad to 0 and subtract mass of
        # pad tokens is wrong. We require S % bs == 0 for exactness.
        raise ValueError(f"S={seq} must be a multiple of bs={bs}")
    return _ak.decode_attention_int8kv(q, k_q, k_scale, v_q, v_scale, bs=bs,
                                       interpret=_interpret())
