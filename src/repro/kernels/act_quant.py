"""Pallas TPU kernel: fused per-row activation quantization (the A8 step).

Dynamic activation quantization runs before every quantized matmul; unfused
it costs one full read (abs-max) + one read/write (quantize) of the
activation tensor. This kernel fuses both into a single VMEM-resident pass
per (bm, K) row block: one HBM read, int8 write, f32 scale write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256


def _act_quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]                                   # (bm, K) f32
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def act_quant(x: jnp.ndarray, *, bm: int = DEFAULT_BM,
              interpret: bool = False):
    """x: (M, K) f32 -> (q int8 (M, K), scale f32 (M, 1)), per-row abs-max."""
    m, k = x.shape
    bm = min(bm, m)
    assert m % bm == 0, f"M={m} % block {bm} != 0"
    grid = (m // bm,)
    return pl.pallas_call(
        _act_quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
