"""NVE molecular dynamics (velocity Verlet) for stability experiments (Fig. 3).

Units: eV, Angstrom, and a time unit t* chosen so that masses are in amu:
with E in eV, m in amu, 1 t* = 10.1805 fs; we express dt in fs and convert.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

# 1 fs in sqrt(amu * A^2 / eV)
_FS = 1.0 / 10.180505


class MDState(NamedTuple):
    coords: jnp.ndarray    # (n, 3) Angstrom
    veloc: jnp.ndarray     # (n, 3) A / t*
    forces: jnp.ndarray    # (n, 3) eV / A


def kinetic_energy(state: MDState, masses: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * jnp.sum(masses[:, None] * state.veloc ** 2)


def init_state(key: jax.Array, coords: jnp.ndarray, masses: jnp.ndarray,
               force_fn: Callable[[jnp.ndarray], jnp.ndarray],
               temperature_K: float = 300.0) -> MDState:
    """Maxwell-Boltzmann velocities at the given temperature (kB in eV/K)."""
    kb = 8.617333e-5
    std = jnp.sqrt(kb * temperature_K / masses)[:, None]
    v = jax.random.normal(key, coords.shape) * std
    v = v - v.mean(0, keepdims=True)  # remove CoM drift
    return MDState(coords=coords, veloc=v, forces=force_fn(coords))


def nve_trajectory(state: MDState, masses: jnp.ndarray,
                   force_fn: Callable[[jnp.ndarray], jnp.ndarray],
                   energy_fn: Callable[[jnp.ndarray], jnp.ndarray],
                   dt_fs: float, n_steps: int, record_every: int = 10):
    """Run velocity-Verlet; returns (final_state, recorded total energies).

    Uses lax.scan. All ``n_steps`` are integrated: when ``record_every``
    does not divide ``n_steps`` the remainder is run as a final shorter
    segment with one extra energy sample at its end, so the record has
    length ``ceil(n_steps / record_every)`` and the last interval may be
    shorter than the others (callers fitting a drift slope on uniform
    spacing should pass a divisible ``record_every``).
    """
    dt = dt_fs * _FS
    inv_m = (1.0 / masses)[:, None]

    def step(s: MDState, _):
        v_half = s.veloc + 0.5 * dt * s.forces * inv_m
        r_new = s.coords + dt * v_half
        f_new = force_fn(r_new)
        v_new = v_half + 0.5 * dt * f_new * inv_m
        return MDState(r_new, v_new, f_new), None

    def segment(s: MDState, length: int):
        s, _ = jax.lax.scan(step, s, None, length=length)
        e_tot = energy_fn(s.coords) + kinetic_energy(s, masses)
        return s, e_tot

    state, energies = jax.lax.scan(lambda s, _: segment(s, record_every),
                                   state, None,
                                   length=n_steps // record_every)
    rem = n_steps % record_every
    if rem:
        state, e_tail = segment(state, rem)
        energies = jnp.concatenate([energies, e_tail[None]])
    return state, energies


def energy_drift_rate(energies: jnp.ndarray, dt_fs: float,
                      record_every: int, n_atoms: int) -> float:
    """Least-squares slope of total energy, in eV/atom/ps.

    Assumes uniform ``record_every`` spacing between samples — when a
    trajectory ran a shorter remainder segment (``n_steps`` not a
    multiple of ``record_every``), drop its final sample before fitting.
    """
    t_ps = jnp.arange(energies.shape[0]) * dt_fs * record_every * 1e-3
    t = t_ps - t_ps.mean()
    slope = jnp.sum(t * (energies - energies.mean())) / jnp.sum(t * t)
    return float(slope) / n_atoms
