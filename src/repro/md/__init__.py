"""repro.md — molecular dynamics on the quantized force field.

Two layers (see docs/md.md):

* :mod:`repro.md.nve` — the minimal velocity-Verlet integrator used by
  the training pipeline's stability evaluation (single molecule, caller
  supplies ``force_fn``/``energy_fn``).
* :mod:`repro.md.engine` — the device-resident :class:`MDEngine`:
  batched replica NVE inside one ``lax.scan`` over the quantized sparse
  forward, with Verlet-skin neighbour lists (:mod:`repro.md.neighbor`)
  rebuilt on device under ``lax.cond`` and zero host sync per step.
"""
from repro.md.engine import MDConfig, MDEngine, ReplicaState, pad_replicas
from repro.md.neighbor import (NeighborList, build_neighbor_list,
                               maybe_rebuild, needs_rebuild)
from repro.md.nve import (MDState, energy_drift_rate, init_state,
                          kinetic_energy, nve_trajectory)

__all__ = [
    "MDConfig", "MDEngine", "ReplicaState", "pad_replicas",
    "NeighborList", "build_neighbor_list", "maybe_rebuild", "needs_rebuild",
    "MDState", "energy_drift_rate", "init_state", "kinetic_energy",
    "nve_trajectory",
]
