"""Verlet-skin neighbour lists for device-resident MD.

The sparse serving path consumes static-shape ``(senders, receivers,
edge_mask)`` edge lists (``serving/bucketing.py``); MD needs the same
contract but *inside* ``jax.lax.scan`` — rebuilding a neighbour list on
the host every step would sync the device per force call and dominate
wall clock at MD step counts (10^4-10^6 calls).

The classic fix is a **skin** (Verlet) list: build the edge list once
with an enlarged ``cutoff + skin`` radius and reuse it while no atom has
moved more than ``skin / 2`` from its position at build time — under
that bound no pair can have closed by more than ``skin``, so every pair
now inside the true cutoff was inside ``cutoff + skin`` at build time
and is guaranteed to be in the list (zero missed edges; pinned over
1000+ steps in ``tests/test_md_engine.py``). Before each force
evaluation the mask is tightened back to the true cutoff at the current
coordinates (``kernels.ops.refine_edge_mask``), so the edge set entering
the forward is *exactly* the fresh-rebuild set — the skin changes when
we rebuild, never the physics.

Everything here is jittable: rebuilds happen on device under
``lax.cond`` (``maybe_rebuild``), and capacity overflow is a sticky
boolean flag in the list (checked by the MD engine at record
checkpoints — the only host sync points) instead of the host builder's
``None`` fallback.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.serving.bucketing import device_edge_list

__all__ = ["NeighborList", "build_neighbor_list", "needs_rebuild",
           "maybe_rebuild"]


class NeighborList(NamedTuple):
    """A skin edge list plus the state needed to decide when it expires.

    senders/receivers/edge_mask follow the ``bucketing.EdgeList`` layout
    contract exactly (flat ``(B * edge_capacity,)`` arrays, per-molecule
    slot ranges, receiver-sorted real edges, masked self-loop padding);
    ``edge_mask`` marks edges within ``cutoff + skin`` *at build time*
    and must be refined to the true cutoff before use.
    """
    senders: jnp.ndarray     # (B * ec,) int32 flat node index of atom j
    receivers: jnp.ndarray   # (B * ec,) int32 flat node index of atom i
    edge_mask: jnp.ndarray   # (B * ec,) bool, True = within cutoff + skin
    ref_coords: jnp.ndarray  # (B, cap, 3) coordinates at build time
    overflow: jnp.ndarray    # () bool, sticky: some rebuild overflowed ec
    n_rebuilds: jnp.ndarray  # () int32, rebuilds since build_neighbor_list

    @property
    def edge_capacity(self) -> int:
        return self.senders.shape[0] // self.ref_coords.shape[0]


def build_neighbor_list(coords: jnp.ndarray, mask: jnp.ndarray,
                        cutoff: float, skin: float,
                        edge_capacity: int) -> NeighborList:
    """Build a fresh skin list at ``cutoff + skin``. Jittable.

    coords: (B, cap, 3); mask: (B, cap) bool. ``skin = 0`` degenerates
    to a plain cutoff list that ``needs_rebuild`` expires on any motion
    — the fresh-rebuild-every-step reference the skin path is tested
    against.
    """
    senders, receivers, edge_mask, counts = device_edge_list(
        coords, mask, cutoff + skin, edge_capacity)
    return NeighborList(senders=senders, receivers=receivers,
                        edge_mask=edge_mask, ref_coords=coords,
                        overflow=jnp.any(counts > edge_capacity),
                        n_rebuilds=jnp.zeros((), jnp.int32))


def needs_rebuild(nlist: NeighborList, coords: jnp.ndarray,
                  mask: jnp.ndarray, skin: float) -> jnp.ndarray:
    """() bool: has any real atom moved more than skin/2 since build?

    The conservative expiry criterion: while False, no pair can have
    closed by more than ``skin``, so the list still covers the true
    cutoff graph. ``>=`` makes ``skin = 0`` expire on any motion.
    """
    disp2 = jnp.sum((coords - nlist.ref_coords) ** 2, axis=-1)  # (B, cap)
    disp2 = jnp.where(mask, disp2, 0.0)
    return jnp.max(disp2) >= (0.5 * skin) ** 2


def maybe_rebuild(nlist: NeighborList, coords: jnp.ndarray,
                  mask: jnp.ndarray, cutoff: float,
                  skin: float) -> NeighborList:
    """Rebuild the skin list under ``lax.cond`` iff it has expired.

    Both branches return identical pytree shapes (static edge capacity),
    so this composes with ``lax.scan``; the O(cap^2) rebuild work is
    only *executed* when the displacement criterion fires. ``overflow``
    is sticky across rebuilds, ``n_rebuilds`` counts them.
    """
    ec = nlist.edge_capacity

    def rebuild(_):
        fresh = build_neighbor_list(coords, mask, cutoff, skin, ec)
        return fresh._replace(
            overflow=fresh.overflow | nlist.overflow,
            n_rebuilds=nlist.n_rebuilds + 1)

    return jax.lax.cond(needs_rebuild(nlist, coords, mask, skin),
                        rebuild, lambda _: nlist, operand=None)
